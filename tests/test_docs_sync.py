"""Docs stay in sync with the code they describe.

Two layers of protection against docs drift:

* the documented ``QueryStats``/``HotSetStats`` metric tables in
  docs/graph_query_engine.md must be a SUBSET of the real
  ``as_dict()`` keys — renaming or dropping a counter without
  updating the table fails tier-1, not just the CI docs lane;
* the per-prefix metric tables in docs/observability.md must equal
  ``repro.obs.metrics.NAMESPACE`` EXACTLY (both directions), and the
  namespace itself must match every live ``as_dict()`` surface — the
  same check ``.github/scripts/metrics_drift.py`` gates in the docs CI
  lane;
* ``.github/scripts/docs_check.py`` (paths, ``file.py::symbol``
  anchors, dotted symbols, CLI flags across all of docs/ + README)
  must come back clean when run against the working tree.
"""

import importlib.util
import re
from pathlib import Path

import numpy as np

from repro.query.engine import QueryStats
from repro.query.hotset import HotSetStats

ROOT = Path(__file__).resolve().parents[1]
ENGINE_DOC = ROOT / "docs" / "graph_query_engine.md"
OBS_DOC = ROOT / "docs" / "observability.md"


def _table_keys(section_heading: str) -> set:
    """Backticked tokens from the first column of the table under a heading."""
    text = ENGINE_DOC.read_text()
    m = re.search(rf"^## {re.escape(section_heading)}.*?(?=^## |\Z)",
                  text, flags=re.S | re.M)
    assert m, f"section {section_heading!r} missing from {ENGINE_DOC.name}"
    keys = set()
    for line in m.group(0).splitlines():
        if not line.startswith("|"):
            continue
        first_cell = line.split("|")[1]
        keys.update(re.findall(r"`(\w+)`", first_cell))
    assert keys, f"no table rows found under {section_heading!r}"
    return keys


def test_querystats_table_is_subset_of_as_dict():
    documented = _table_keys("QueryStats: the engine's accounting contract")
    real = set(QueryStats().as_dict().keys())
    missing = documented - real
    assert not missing, (
        f"docs/graph_query_engine.md documents QueryStats keys that "
        f"as_dict() no longer returns: {sorted(missing)}"
    )
    # the table is the *serving contract*: the load-bearing counters
    # must actually be documented, not just not-wrong
    for key in ("requests", "batches", "close_reasons", "device_batches",
                "p50_s", "p99_s"):
        assert key in documented, f"contract key {key!r} undocumented"


def test_querystats_as_dict_matches_live_engine_fold():
    # the documented invariant: sum(close_reasons.values()) == batches
    s = QueryStats()
    s.batches = 3
    s.close_reasons = {"full": 2, "flush": 1}
    d = s.as_dict()
    assert sum(d["close_reasons"].values()) == d["batches"]
    # merge associativity over the documented keys
    a, b = QueryStats(requests=5), QueryStats(requests=7)
    assert a.merge(b).as_dict()["requests"] == 12


def test_hotset_stats_documented_contract_holds():
    documented_doc = ENGINE_DOC.read_text()
    assert "HotSetStats" in documented_doc
    s = HotSetStats()
    s.lookups, s.hits, s.misses = 4, 3, 1
    s.fills, s.admitted, s.bypassed, s.rejected = 5, 2, 2, 1
    assert s.conserved
    keys = set(s.as_dict().keys())
    for key in ("lookups", "hits", "misses", "fills", "admitted",
                "bypassed", "rejected", "resident_bytes", "pinned"):
        assert key in keys


def _obs_table_keys(prefix: str) -> set:
    """Backticked first-column keys of the ``### `prefix` — ...``
    namespace table in docs/observability.md."""
    text = OBS_DOC.read_text()
    m = re.search(rf"^### `{re.escape(prefix)}`.*?(?=^#{{2,3}} |\Z)",
                  text, flags=re.S | re.M)
    assert m, f"namespace table for {prefix!r} missing from {OBS_DOC.name}"
    keys = set()
    for line in m.group(0).splitlines():
        if line.startswith("|"):
            keys.update(re.findall(r"`(\w+)`", line.split("|")[1]))
    assert keys, f"no table rows under {prefix!r} in {OBS_DOC.name}"
    return keys


def test_observability_namespace_tables_match_exactly():
    """docs/observability.md documents EVERY key of every prefix of
    repro.obs.metrics.NAMESPACE, and nothing else — equality, not
    subset: the doc is the human-readable rendering of the literal the
    CI drift gate enforces."""
    from repro.obs.metrics import NAMESPACE
    for prefix, keys in NAMESPACE.items():
        documented = _obs_table_keys(prefix)
        assert documented == set(keys), (
            f"docs/observability.md table for {prefix!r} drifted: "
            f"missing {sorted(set(keys) - documented)}, "
            f"stale {sorted(documented - set(keys))}")


def test_metrics_namespace_matches_live_surfaces():
    """The other half of the chain: the namespace literal itself agrees
    with the live as_dict() surfaces (metrics_drift is the function
    .github/scripts/metrics_drift.py gates on in CI)."""
    from repro.obs.metrics import metrics_drift
    assert metrics_drift() == []


def test_docs_check_script_is_clean():
    spec = importlib.util.spec_from_file_location(
        "docs_check", ROOT / ".github" / "scripts" / "docs_check.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.main() == 0, "dangling references in docs/ (see stdout)"


def test_readme_tier1_command_is_current():
    readme = (ROOT / "README.md").read_text()
    assert "PYTHONPATH=src python -m pytest -x -q" in readme
    assert "docs/architecture.md" in readme
