"""PG-Fuse (paper §III): byte-correct caching, state machine, eviction."""

import os
import threading

import numpy as np
import pytest

from repro.core import pgfuse
from tests._prop import prop


@pytest.fixture
def datafile(tmp_path):
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, 300_000, dtype=np.uint8).tobytes()
    p = tmp_path / "blob.bin"
    p.write_bytes(data)
    return str(p), data


def test_basic_reads_and_hits(datafile):
    path, data = datafile
    fs = pgfuse.PGFuseFS(block_size=4096)
    cf = fs.mount(path)
    assert cf.pread(0, 100) == data[:100]
    assert cf.pread(50, 100) == data[50:150]          # same block -> hit
    assert cf.pread(len(data) - 10, 100) == data[-10:]  # clipped at EOF
    st = fs.stats()
    assert st.cache_hits >= 1
    assert st.underlying_bytes >= 4096  # large-granularity request
    fs.unmount()


@prop(10)
def test_random_read_schedule_byte_identical(draw):
    import tempfile
    data = draw.rng.integers(0, 256, draw.int(1, 100_000), dtype=np.uint8).tobytes()
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "f.bin")
        with open(p, "wb") as f:
            f.write(data)
        bs = draw.choice([1, 7, 512, 4096, 1 << 16])
        budget = draw.choice([None, 8 * bs])
        with pgfuse.PGFuseFS(block_size=bs, max_resident_bytes=budget) as fs:
            cf = fs.mount(p)
            for _ in range(30):
                off = draw.int(0, max(0, len(data)))
                n = draw.int(0, 5000)
                assert cf.pread(off, n) == data[off:off + n], (off, n, bs)


def test_handle_interface(datafile):
    path, data = datafile
    with pgfuse.PGFuseFS(block_size=1024) as fs:
        h = fs.open(path)
        h.seek(1000)
        assert h.read(64) == data[1000:1064]
        assert h.tell() == 1064
        h.seek(-8, os.SEEK_END)
        assert h.read() == data[-8:]


def test_eviction_respects_budget_and_recency(datafile):
    path, data = datafile
    bs = 4096
    with pgfuse.PGFuseFS(block_size=bs, max_resident_bytes=3 * bs) as fs:
        cf = fs.mount(path)
        for b in range(8):
            cf.pread(b * bs, 10)
        assert fs.resident_bytes <= 3 * bs
        assert fs.stats().evictions >= 5
        # most recently used block should still be resident
        resident = set(cf.resident_blocks().tolist())
        assert 7 in resident


def test_state_machine_transitions(datafile):
    path, _ = datafile
    with pgfuse.PGFuseFS(block_size=4096) as fs:
        cf = fs.mount(path)
        st = cf._statuses
        assert st.load(0) == pgfuse.NOT_LOADED
        data = cf.acquire_block(0)
        assert st.load(0) == 1            # one pinned reader
        cf.acquire_block(0)
        assert st.load(0) == 2            # counter semantics
        cf.release_block(0)
        cf.release_block(0)
        assert st.load(0) == pgfuse.LOADED
        # pinned blocks cannot be revoked
        cf.acquire_block(0)
        assert cf.try_revoke(0) == 0
        cf.release_block(0)
        assert cf.try_revoke(0) > 0
        assert st.load(0) == pgfuse.NOT_LOADED


def test_concurrent_reader_stress(datafile):
    """Many threads, random reads, small cache: data must stay
    byte-identical and the status array must end fully idle."""
    path, data = datafile
    bs = 2048
    with pgfuse.PGFuseFS(block_size=bs, max_resident_bytes=4 * bs) as fs:
        cf = fs.mount(path)
        errors = []

        def worker(seed):
            rng = np.random.default_rng(seed)
            try:
                for _ in range(200):
                    off = int(rng.integers(0, len(data)))
                    n = int(rng.integers(1, 3 * bs))
                    if cf.pread(off, n) != data[off:off + n]:
                        errors.append((seed, off, n))
            except Exception as e:  # pragma: no cover
                errors.append((seed, repr(e)))

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        snap = cf._statuses.snapshot()
        assert ((snap == pgfuse.LOADED) | (snap == pgfuse.NOT_LOADED)).all()


def test_eviction_vs_acquisition_stress(datafile):
    """Fig. 1 state machine under fire: N threads hammer pread over a tiny
    max_resident_bytes budget so eviction (0 -> -3 -> -1) races acquisition
    (-1 -> -2 -> 1) on every block.  Required invariants: no deadlock, no
    stale bytes served, statuses fully idle at the end, and the FS-level
    resident_bytes accounting agrees exactly with what is actually cached."""
    path, data = datafile
    bs = 1024
    n_threads = 12
    with pgfuse.PGFuseFS(block_size=bs, max_resident_bytes=2 * bs) as fs:
        cf = fs.mount(path)
        errors = []
        start = threading.Barrier(n_threads)

        def worker(seed):
            rng = np.random.default_rng(seed)
            start.wait()
            try:
                for _ in range(150):
                    off = int(rng.integers(0, len(data)))
                    n = int(rng.integers(1, 4 * bs))
                    got = cf.pread(off, n)
                    if got != data[off:off + n]:
                        errors.append(("stale", seed, off, n))
            except Exception as e:
                errors.append(("raised", seed, repr(e)))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not any(t.is_alive() for t in threads), "deadlocked workers"
        assert not errors, errors[:5]
        snap = cf._statuses.snapshot()
        assert ((snap == pgfuse.LOADED) | (snap == pgfuse.NOT_LOADED)).all()
        # accounting must agree with reality, not drift under races
        actual = sum(len(cf._blocks[b]) for b in cf.resident_blocks())
        assert fs.resident_bytes == actual
        assert fs.resident_bytes <= 2 * bs


def test_close_races_concurrent_readers(datafile):
    """close() must drain readers through status transitions, not free
    pinned blocks from under them (the seed freed unconditionally)."""
    path, data = datafile
    bs = 4096
    for _ in range(5):
        fs = pgfuse.PGFuseFS(block_size=bs)
        cf = fs.mount(path)
        errors = []
        stop = threading.Event()

        def reader(seed):
            rng = np.random.default_rng(seed)
            try:
                while not stop.is_set():
                    off = int(rng.integers(0, len(data) - 1))
                    n = int(rng.integers(1, 2 * bs))
                    got = cf.pread(off, n)
                    if got != data[off:off + min(n, len(data) - off)]:
                        errors.append(("stale", off, n))
            except ValueError:
                return  # read on closed CachedFile: the expected signal
            except Exception as e:
                errors.append(("raised", repr(e)))

        threads = [threading.Thread(target=reader, args=(i,)) for i in range(6)]
        for t in threads:
            t.start()
        cf.pread(0, 100)  # ensure some blocks are resident before closing
        fs.unmount()
        stop.set()
        for t in threads:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in threads), "reader hung on close"
        assert not errors, errors[:5]
        assert fs.resident_bytes == 0, "close leaked resident accounting"


def test_async_read_error_recording_is_locked():
    """AsyncRead must collect producer errors under a lock (the seed
    appended bare from N threads) and still surface the first one."""
    from repro.core import paragrapher

    class Boom(RuntimeError):
        pass

    g = type("G", (), {})()  # duck-typed handle: every read raises

    def read_partition(v0, v1):
        raise Boom(f"{v0}:{v1}")

    g.read_partition = read_partition
    ar = paragrapher.AsyncRead(g, [(i, i + 1) for i in range(32)],
                               lambda buf: None, n_buffers=4, n_workers=8)
    with pytest.raises(Boom):
        ar.wait(30)
    with ar._err_lock:
        assert len(ar._errors) == 32


def test_sequential_readahead_reduces_underlying_reads(datafile):
    """readahead=r must cut underlying calls ~(1+r)x on a sequential scan
    and serve byte-identical data."""
    path, data = datafile
    bs = 4096
    counts = {}
    for ra in (0, 3):
        with pgfuse.PGFuseFS(block_size=bs, readahead=ra) as fs:
            cf = fs.mount(path)
            out = b"".join(cf.pread(off, 1000)
                           for off in range(0, len(data), 1000))
            assert out == data
            counts[ra] = fs.stats().underlying_reads
            if ra:
                assert fs.stats().readahead_blocks > 0
    n_blocks = -(-len(data) // bs)
    assert counts[0] == n_blocks
    assert counts[3] <= -(-n_blocks // 4) + 1, counts


def test_readahead_under_eviction_budget(datafile):
    """Readahead + tiny budget: prefetched blocks are evictable (status 0)
    and the budget still holds."""
    path, data = datafile
    bs = 2048
    with pgfuse.PGFuseFS(block_size=bs, readahead=4,
                         max_resident_bytes=3 * bs) as fs:
        cf = fs.mount(path)
        for off in range(0, len(data), bs):
            assert cf.pread(off, 100) == data[off:off + 100]
        assert fs.resident_bytes <= 3 * bs


def test_underlying_read_count_vs_naive(datafile):
    """The point of §III: far fewer underlying calls than consumer reads."""
    path, data = datafile
    with pgfuse.PGFuseFS(block_size=1 << 16) as fs:
        cf = fs.mount(path)
        n_consumer_reads = 500
        rng = np.random.default_rng(0)
        for _ in range(n_consumer_reads):
            off = int(rng.integers(0, len(data) - 128))
            cf.pread(off, 128)
        st = fs.stats()
        assert st.underlying_reads <= cf.n_blocks
        assert st.underlying_reads < n_consumer_reads / 10
