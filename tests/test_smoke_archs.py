"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config and runs one forward/train step on CPU,
asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch

pytestmark = pytest.mark.slow  # one init+step per arch; excluded from tier-1

rng = np.random.default_rng(0)


def _finite_tree(t):
    return all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree.leaves(t))


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_arch(a).family == "lm"])
def test_lm_smoke(arch_id):
    from repro.models import transformer as tf
    cfg = get_arch(arch_id).make_reduced()
    p = tf.init_params(cfg, jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)))
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)))
    loss, grads = jax.value_and_grad(tf.loss_fn)(p, toks, labels, cfg)
    assert np.isfinite(float(loss)) and _finite_tree(grads)
    logits, _, _ = tf.forward(p, toks, cfg)
    assert logits.shape == (2, 17, cfg.vocab)
    # serve path
    last, cache = tf.prefill(p, toks, cfg, max_len=20)
    step, cache = tf.decode_step(p, labels[:, :1], cache, cfg)
    assert step.shape == (2, cfg.vocab) and _finite_tree(step)


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_arch(a).family == "gnn"])
def test_gnn_smoke(arch_id):
    from repro.launch.data_gnn import full_graph_batch
    from repro.launch.steps import _GNN_MODULES
    from repro.graph import erdos_renyi
    mod = _GNN_MODULES[arch_id]
    cfg = get_arch(arch_id).make_reduced()
    csr = erdos_renyi(60, 300, seed=4)
    batch = full_graph_batch(arch_id, cfg, csr, rng, n_classes=4)
    loss, grads = jax.value_and_grad(mod.loss_fn)(
        mod.init_params(cfg, jax.random.key(0)), batch, cfg)
    assert np.isfinite(float(loss)) and _finite_tree(grads)
    out = mod.forward(mod.init_params(cfg, jax.random.key(0)), batch, cfg)
    assert out.shape[0] > 0 and _finite_tree(out)


def test_din_smoke():
    from repro.models.recsys import din
    cfg = get_arch("din").make_reduced()
    p = din.init_params(cfg, jax.random.key(0))
    B = 8
    batch = {
        "hist_items": jnp.asarray(rng.integers(-1, cfg.n_items, (B, cfg.seq_len))),
        "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (B, cfg.seq_len))),
        "cand_item": jnp.asarray(rng.integers(0, cfg.n_items, B)),
        "cand_cate": jnp.asarray(rng.integers(0, cfg.n_cates, B)),
        "labels": jnp.asarray(rng.integers(0, 2, B).astype(np.float32)),
    }
    loss, grads = jax.value_and_grad(din.loss_fn)(p, batch, cfg)
    assert np.isfinite(float(loss)) and _finite_tree(grads)
    logits = din.forward(p, batch, cfg)
    assert logits.shape == (B,)
    # retrieval path
    q = {
        "hist_items": jnp.asarray(rng.integers(0, cfg.n_items, cfg.seq_len)),
        "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, cfg.seq_len)),
        "cand_items": jnp.asarray(rng.integers(0, cfg.n_items, 200)),
        "cand_cates": jnp.asarray(rng.integers(0, cfg.n_cates, 200)),
    }
    scores = din.score_candidates(p, q, cfg)
    assert scores.shape == (200,) and _finite_tree(scores)


def test_all_40_cells_enumerate():
    from repro.configs import all_cells
    cells = all_cells()
    assert len(cells) == 40
    fams = {}
    for a, s in cells:
        fams.setdefault(get_arch(a).family, set()).add(s)
    assert len(fams["lm"]) == 4 and len(fams["gnn"]) == 4
    assert len(fams["recsys"]) == 4
