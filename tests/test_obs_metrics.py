"""Metrics registry, namespace sync, and exposition lockdown.

The registry's fold-on-register semantics must match each stats class's
associative ``merge()`` (sum-kind keys add, ratios recompute from the
folded parts, quantile summaries keep the max), the
``repro.obs.metrics.NAMESPACE`` table must stay bidirectionally in sync
with every live ``as_dict()`` surface (the same check
``.github/scripts/metrics_drift.py`` gates in CI), and the exposition
surfaces (Prometheus text, JSON snapshot, bench sidecar flattening)
must be deterministic and re-parseable.
"""

import json

import pytest

from repro.obs.metrics import (MAX_KEYS, NAMESPACE, RATIO_SPECS,
                               STATS_SOURCES, Counter, Gauge, Histogram,
                               LatencyHistogram, MetricsRegistry,
                               flatten_numeric, metrics_drift)
from repro.query import QueryStats, TraversalStats
from repro.query.window import CLOSE_REASONS, close_reason_counts


def _qstats(requests, unique, batches, reasons, lat):
    st = QueryStats()
    st.requests, st.unique_vertices, st.batches = requests, unique, batches
    for r in reasons:
        st.close_reasons[r] = st.close_reasons.get(r, 0) + 1
    for v in lat:
        st.latencies.add(v)
    return st


# -- namespace sync --------------------------------------------------------

def test_namespace_matches_every_live_stats_surface():
    """The CI drift gate's exact check: zero violations between
    NAMESPACE and the six live as_dict() surfaces, in either
    direction."""
    assert metrics_drift() == []


def test_namespace_internal_consistency():
    """Every ratio/max key must itself be a declared namespace key with
    declared numerator/denominator parts, and every prefix must name a
    loadable source."""
    declared = {f"{p}.{k}" for p, keys in NAMESPACE.items() for k in keys}
    for name, (nums, dens) in RATIO_SPECS.items():
        assert name in declared, name
        for part in nums + dens:
            assert part in declared, (name, part)
    for name in MAX_KEYS:
        assert name in declared, name
    assert set(NAMESPACE) == set(STATS_SOURCES)


# -- registry fold semantics ----------------------------------------------

def test_register_fold_matches_stats_merge():
    """Registering two QueryStats dicts one after the other must agree
    with registering their merge() once — for every key except the
    quantile summaries, where the registry keeps the max (an upper
    bound; a true merged quantile needs the histograms, which the
    sharded service folds before registering)."""
    a = _qstats(10, 4, 2, ["direct", "full"], [0.1, 0.2])
    b = _qstats(6, 3, 3, ["direct", "timeout", "direct"], [0.3])
    reg_seq = MetricsRegistry()
    reg_seq.register_stats("query", a.as_dict())
    reg_seq.register_stats("query", b.as_dict())
    reg_one = MetricsRegistry()
    reg_one.register_stats("query", a.merge(b).as_dict())
    seq = reg_seq.snapshot()["metrics"]
    one = reg_one.snapshot()["metrics"]
    assert set(seq) == set(one)
    for k in one:
        if k in ("query.p50_s", "query.p99_s"):
            continue
        assert seq[k] == one[k], k
    assert seq["query.p50_s"] == max(a.latency_quantile(0.5),
                                     b.latency_quantile(0.5))
    # ratio recomputed from folded parts == the merged dedup ratio
    assert seq["query.dedup_ratio"] == (10 + 6) / (4 + 3)
    # dict-valued keys flatten to per-subkey gauges and sum across folds
    assert seq["query.close_reasons.direct"] == 3
    assert reg_seq.snapshot()["sources"] == {"query": 2}


def test_register_fold_recomputes_hotset_ratios():
    """hit_rate / prefetch_hit_rate recompute from folded numerators
    and denominators — NOT by averaging per-shard rates."""
    reg = MetricsRegistry()
    reg.register_stats("hotset", {"lookups": 100, "hits": 90,
                                  "prefetch_fills": 10,
                                  "prefetch_hits": 1,
                                  "hit_rate": 0.9,
                                  "prefetch_hit_rate": 0.1})
    reg.register_stats("hotset", {"lookups": 900, "hits": 90,
                                  "prefetch_fills": 0,
                                  "prefetch_hits": 0,
                                  "hit_rate": 0.1,
                                  "prefetch_hit_rate": 0.0})
    assert reg.get("hotset.hit_rate") == 180 / 1000
    assert reg.get("hotset.prefetch_hit_rate") == 1 / 10
    # a denominator of zero yields 0, never a ZeroDivisionError
    empty = MetricsRegistry()
    empty.register_stats("hotset", {"lookups": 0, "hits": 0,
                                    "hit_rate": 0.0})
    assert empty.get("hotset.hit_rate") == 0.0


def test_register_handles_strings_and_max_keys():
    """Non-numeric values land in the info side-channel (last write
    wins), and MAX_KEYS fold by max (StreamStats' parallel wall
    clock)."""
    reg = MetricsRegistry()
    reg.register_stats("stream", {"decode_mode": "host", "wall_s": 2.0,
                                  "edges": 100})
    reg.register_stats("stream", {"decode_mode": "device", "wall_s": 1.5,
                                  "edges": 50})
    assert reg.info["stream.decode_mode"] == "device"
    assert reg.get("stream.wall_s") == 2.0
    assert reg.get("stream.edges") == 150
    # the stream rates recompute over the max wall clock
    assert reg.get("stream.edges_per_s") == 150 / 2.0


def test_registry_conservation_cross_checks():
    """The invariants exposition relies on survive the fold: close
    reasons sum to batches, and both traversal conservation identities
    hold on folded totals."""
    reg = MetricsRegistry()
    for i in range(3):
        reg.register_stats("query", _qstats(
            8, 4, 2, ["direct", "plateau"], [0.1]).as_dict())
        ts = TraversalStats()
        ts.submitted, ts.admitted, ts.shed = 5, 4, 1
        ts.completed, ts.failed, ts.inflight = 3, 1, 0
        reg.register_stats("traversal", ts.as_dict())
    close_total = sum(reg.get(f"query.close_reasons.{r}")
                      for r in CLOSE_REASONS)
    assert close_total == reg.get("query.batches") == 6
    assert reg.get("traversal.submitted") == \
        reg.get("traversal.admitted") + reg.get("traversal.shed")
    assert reg.get("traversal.admitted") == \
        reg.get("traversal.completed") + reg.get("traversal.failed") \
        + reg.get("traversal.inflight")


# -- exposition ------------------------------------------------------------

def test_prometheus_text_and_json_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.register_stats("query", _qstats(10, 4, 2, ["direct", "full"],
                                        [0.1, 0.2]).as_dict())
    reg.set("obs.sampled_traces", 5)
    text = reg.to_prometheus()
    assert "# TYPE repro_query_batches gauge\nrepro_query_batches 2" in text
    assert "repro_query_close_reasons_direct 1" in text
    assert "repro_obs_sampled_traces 5" in text
    assert text.endswith("\n")
    # every value line is "name number" and re-parses to the registry
    lines = [ln for ln in text.splitlines() if not ln.startswith("#")]
    assert len(lines) == len(reg.names()) == len(set(reg.names()))
    for ln in lines:
        name, val = ln.split(" ")
        assert float(val) == reg.get(name.replace("repro_", "", 1)
                                     .replace("_", ".")) \
            or name.count("_") > 2   # dotted subkeys un-map ambiguously
    path = tmp_path / "metrics.json"
    reg.write_json(path)
    snap = json.loads(path.read_text())
    assert snap == reg.snapshot()
    assert snap["metrics"]["query.requests"] == 10.0
    assert list(snap["metrics"]) == sorted(snap["metrics"])


def test_flatten_numeric_for_bench_sidecars():
    nested = {"bench": "hotset", "tracked": {"advantage": 2.5},
              "graph": {"scale": 13, "name": "rmat"},
              "arms": {"hot": {"p50_s": 1e-3, "ok": True}},
              "rows": [1, 2, 3]}
    flat = flatten_numeric(nested)
    assert flat == {"tracked.advantage": 2.5, "graph.scale": 13.0,
                    "arms.hot.p50_s": 1e-3, "arms.hot.ok": 1.0}


def test_metric_primitives():
    c = Counter()
    c.inc(), c.inc(2)
    assert c.value == 3 and c.kind == "counter"
    gauge = Gauge()
    gauge.set(4.5)
    assert gauge.value == 4.5 and gauge.kind == "gauge"
    h = Histogram()
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    assert h.kind == "histogram" and h.hist.n == 3
    assert h.value == h.hist.quantile(0.5)


# -- close-reason axis -----------------------------------------------------

def test_close_reason_counts_normalizes_and_rejects_unknown():
    full = close_reason_counts({"direct": 3, "full": 1})
    assert set(full) == set(CLOSE_REASONS)
    assert full["direct"] == 3 and full["plateau"] == 0
    assert sum(full.values()) == 4
    with pytest.raises(ValueError, match="unknown close reasons"):
        close_reason_counts({"direct": 1, "oops": 2})


# -- the serve-time fold over a live service -------------------------------

def test_collect_service_metrics_folds_all_surfaces(tmp_path):
    """``repro.launch.serve.collect_service_metrics`` registers every
    surface a live traversal service exposes (traversal, query, pgfuse
    — plus router on the sharded shape) and the snapshot satisfies the
    conservation cross-checks."""
    from repro.core import paragrapher
    from repro.graph import rmat
    from repro.launch.serve import collect_service_metrics
    from repro.query import (NeighborQueryEngine, ShardedQueryService,
                             TraversalService)

    csr = rmat(9, 7, seed=42)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    open_kw = dict(pgfuse_block_size=512, pgfuse_readahead=0,
                   pgfuse_eviction="clock")

    g = paragrapher.open_graph(gp, use_pgfuse=True, **open_kw)
    engine = NeighborQueryEngine(g, decode="host")
    svc = TraversalService(engine)
    try:
        svc.khop([3, 71], 2)
        reg = collect_service_metrics(svc)
        m = reg.snapshot()["metrics"]
        assert m["traversal.completed"] == 1
        assert m["query.batches"] >= 1
        assert m["pgfuse.underlying_reads"] >= 1
        assert sum(m.get(f"query.close_reasons.{r}", 0)
                   for r in CLOSE_REASONS) == m["query.batches"]
    finally:
        svc.close(), engine.close(), g.close()

    with ShardedQueryService(gp, n_shards=2, replication=2,
                             open_kwargs=open_kw) as sh:
        trav = TraversalService(sh)
        try:
            trav.khop([3, 71], 2)
            reg = collect_service_metrics(trav)
            m = reg.snapshot()["metrics"]
            assert m["router.requests"] >= 1
            # one pgfuse fold per replica mount (2 shards x 2 replicas)
            assert reg.snapshot()["sources"]["pgfuse"] == 4
            assert m["traversal.submitted"] == \
                m["traversal.admitted"] + m["traversal.shed"]
        finally:
            trav.close()
