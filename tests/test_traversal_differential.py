"""Property-based differential for the traversal service.

An independent pure in-memory CSR reference (dict/set BFS below — no
engine, no numpy vectorization tricks) re-implements the documented
traversal semantics, and the service must reproduce EVERY result field
bit for bit over arbitrary `_prop.Draw` graphs: cycles, self-loops,
duplicate seeds, isolated vertices, out-of-range seeds, ``k=0``, tight
edge/vertex budgets — host and device decode arms alike.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.core import paragrapher
from repro.graph import rmat
from repro.obs import (Tracer, event_counts, verify_span_tree,
                       window_close_counts)
from repro.query import (NeighborQueryEngine, TraversalError,
                         TraversalService, close_reason_counts)
from tests._prop import Draw, prop


def ref_traverse(csr, kind, seeds, *, k=None, target=None,
                 max_edges=1 << 20, max_vertices=None):
    """The in-memory reference: plain python sets/dicts, hop by hop,
    following the pinned semantics (stop-condition order, budget
    overshoot-then-stop, ascending-id trimming, smallest-adjacent-
    frontier-vertex parents) to the letter."""
    n = csr.n_vertices
    seeds = sorted({int(s) for s in np.asarray(seeds).ravel()})
    mv = max_vertices if max_vertices is not None else n
    truncated = False
    if len(seeds) > mv:
        seeds = seeds[:mv]
        truncated = True
    visited = {s: 0 for s in seeds}
    order, depths = list(seeds), [0] * len(seeds)
    parent = {}
    frontier = seeds
    found = target is not None and target in visited
    edges = hops = 0
    while True:
        if found or not frontier:
            break
        if k is not None and hops == k:
            break
        if edges > max_edges:
            truncated = True
            break
        if len(visited) >= mv:
            truncated = True
            break
        flat = [int(u) for v in frontier for u in csr.neighbors_of(v)]
        hops += 1
        edges += len(flat)
        new = sorted({u for u in flat if u not in visited})
        keep = mv - len(visited)
        if len(new) > keep:
            new = new[:keep]
            truncated = True
        if target is not None:
            for u in new:
                parent[u] = min(v for v in frontier
                                if u in set(int(x) for x in
                                            csr.neighbors_of(v)))
            if target in new:
                found = True
        for u in new:
            visited[u] = hops
        order.extend(new)
        depths.extend([hops] * len(new))
        frontier = new
    path = None
    if kind == "path" and found:
        chain = [target]
        while chain[-1] in parent:
            chain.append(parent[chain[-1]])
        path = chain[::-1]
    return {"vertices": order, "depths": depths, "found": found,
            "path": path, "truncated": truncated, "hops": hops,
            "edges_scanned": edges}


def _assert_matches(res, ref, ctx=""):
    assert res.vertices.tolist() == ref["vertices"], ctx
    assert res.depths.tolist() == ref["depths"], ctx
    assert res.truncated == ref["truncated"], ctx
    assert res.hops == ref["hops"], ctx
    assert res.edges_scanned == ref["edges_scanned"], ctx
    assert res.found == ref["found"], ctx
    if ref["path"] is None:
        assert res.path is None, ctx
    else:
        assert res.path.tolist() == ref["path"], ctx


def _service(path, draw_or_none, decode="host", **kw):
    g = paragrapher.open_graph(
        path, use_pgfuse=True,
        pgfuse_block_size=(draw_or_none.choice([512, 1 << 12])
                           if draw_or_none else 512),
        pgfuse_readahead=0, pgfuse_eviction="clock")
    # hot-set arm: frontier hub vertices answered from the resident
    # decoded-run tier must leave every traversal field bit-identical
    hotset = (draw_or_none.choice([None, 1 << 12, 1 << 16])
              if draw_or_none else None)
    # full-sampling tracer on every fuzzed service: the TraversalService
    # shares the engine's tracer, so _check_spans can reconcile the
    # retained span trees against the stats counters afterwards
    engine = NeighborQueryEngine(g, decode=decode, hotset=hotset,
                                 tracer=Tracer(max_traces=100_000))
    return TraversalService(engine, **kw), engine, g


def _check_spans(svc, engine) -> None:
    """Span/stats conservation after a fuzzed run: structurally valid
    trees, one ``"request"`` root per submitted traversal, ``"shed"``
    events equal to the shed counter, and per-reason ``window_close``
    event totals equal to the engine's ``close_reasons``."""
    traces = engine._tracer.drain()
    assert engine._tracer.dropped_traces == 0
    for root in traces:
        assert verify_span_tree(root) == [], root.name
    st = svc.stats
    assert sum(1 for r in traces if r.tier == "request") == st.submitted
    assert event_counts(traces, "shed") == st.shed
    counted = close_reason_counts(engine.stats.as_dict()["close_reasons"])
    assert window_close_counts(traces) == \
        {k: v for k, v in counted.items() if v}


@prop(10)
def test_khop_and_bfs_match_csr_reference(draw: Draw):
    """Arbitrary graphs (cycles/self-loops/isolated vertices), arbitrary
    duplicate-heavy seed batches, k=0 upward, tight budgets: k-hop and
    bounded-BFS results are identical to the pure reference."""
    csr = draw.csr(max_edges=1500)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        svc, engine, g = _service(gp, draw)
        try:
            for _ in range(4):
                seeds = draw.vertex_batch(csr.n_vertices, max_size=24)
                if seeds.size == 0:
                    continue
                k = draw.int(0, 4)
                max_edges = draw.choice(
                    [1 << 20, draw.int(0, max(1, csr.n_edges))])
                max_vertices = (None if draw.bool() else
                                draw.int(1, max(1, csr.n_vertices)))
                res = svc.khop(seeds, k, max_edges=max_edges,
                               max_vertices=max_vertices)
                ref = ref_traverse(csr, "khop", seeds, k=k,
                                   max_edges=max_edges,
                                   max_vertices=max_vertices)
                _assert_matches(res, ref, ("khop", k, max_edges))
                res = svc.bfs_visit(seeds, max_edges=max_edges,
                                    max_vertices=max_vertices)
                ref = ref_traverse(csr, "bfs", seeds,
                                   max_edges=max_edges,
                                   max_vertices=max_vertices)
                _assert_matches(res, ref, ("bfs", max_edges, max_vertices))
            # the frontier loop really batched: engine batches == hops
            # (each hop is exactly ONE neighbors_batch call) — hot-set
            # hits change where a frontier's runs come from, never how
            # many engine batches it takes
            assert engine.stats.batches == svc.stats.frontier_batches
            if engine.hotset is not None:
                assert engine.hotset.stats.conserved
                assert "hotset" in svc.as_dict()
            _check_spans(svc, engine)
        finally:
            svc.close(), engine.close(), g.close()


@prop(10)
def test_shortest_path_matches_csr_reference(draw: Draw):
    """BFS shortest paths — including unreachable targets, source ==
    target, self-loops and budget-limited searches — agree with the
    reference on found/path/distance exactly (deterministic parents)."""
    csr = draw.csr(max_edges=1200)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        svc, engine, g = _service(gp, draw)
        try:
            for _ in range(4):
                src = draw.int(0, csr.n_vertices - 1)
                dst = src if draw.bool() and draw.bool() else \
                    draw.int(0, csr.n_vertices - 1)
                max_edges = draw.choice(
                    [1 << 20, draw.int(0, max(1, csr.n_edges))])
                max_depth = None if draw.bool() else draw.int(0, 3)
                res = svc.shortest_path(src, dst, max_edges=max_edges,
                                        max_depth=max_depth)
                ref = ref_traverse(csr, "path", [src], k=max_depth,
                                   target=dst, max_edges=max_edges)
                _assert_matches(res, ref, (src, dst, max_edges))
                if res.found:
                    # the path is a real path of the claimed length
                    assert res.path[0] == src and res.path[-1] == dst
                    for a, b in zip(res.path[:-1], res.path[1:]):
                        assert int(b) in csr.neighbors_of(int(a)).tolist()
            _check_spans(svc, engine)
        finally:
            svc.close(), engine.close(), g.close()


@prop(5)
def test_device_decode_arm_matches_host_and_reference(draw: Draw):
    """The device-decode arm (merged packed runs through the Pallas
    kernel) answers every traversal identically to the host arm AND the
    reference — the differential covers the whole service stack."""
    csr = draw.csr(max_edges=1500)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        svc_h, eng_h, g_h = _service(gp, draw, decode="host")
        svc_d, eng_d, g_d = _service(gp, None, decode="device")
        try:
            for _ in range(3):
                seeds = draw.vertex_batch(csr.n_vertices, max_size=16)
                if seeds.size == 0:
                    continue
                k = draw.int(0, 3)
                ref = ref_traverse(csr, "khop", seeds, k=k)
                _assert_matches(svc_h.khop(seeds, k), ref, "host")
                _assert_matches(svc_d.khop(seeds, k), ref, "device")
            # the device service really decoded on the kernel whenever
            # it had edges to decode
            assert eng_d.stats.device_batches == eng_d.stats.batches
            _check_spans(svc_h, eng_h)
            _check_spans(svc_d, eng_d)
        finally:
            svc_h.close(), eng_h.close(), g_h.close()
            svc_d.close(), eng_d.close(), g_d.close()


def test_bad_seeds_are_clean_per_request_errors(tmp_path):
    """Out-of-range / empty seeds (and a bad path target) surface as
    TraversalError; the service keeps answering, the gate leaks no
    tokens, and the failure is accounted (conservation holds)."""
    csr = rmat(7, 5, seed=9)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    svc, engine, g = _service(gp, None)
    try:
        n = csr.n_vertices
        for bad in ([n], [-1], [0, n + 7], []):
            with pytest.raises(TraversalError):
                svc.khop(bad, k=1)
        with pytest.raises(TraversalError):
            svc.shortest_path(0, n)
        assert svc.gate.inflight == 0 and svc.gate.edges_inflight == 0
        # still serving, and correctly
        ref = ref_traverse(csr, "khop", [0, 1], k=2)
        _assert_matches(svc.khop([0, 1], 2), ref)
        st = svc.stats
        assert st.failed == 5 and st.completed == 1
        assert st.conserved
    finally:
        svc.close(), engine.close(), g.close()


def test_k0_and_duplicate_seeds(tmp_path):
    """k=0 returns exactly the deduplicated sorted seeds at depth 0 and
    scans zero edges — on both decode arms."""
    csr = rmat(6, 4, seed=1)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    for decode in ("host", "device"):
        svc, engine, g = _service(gp, None, decode=decode)
        try:
            res = svc.khop([5, 3, 5, 5, 3], k=0)
            assert res.vertices.tolist() == [3, 5]
            assert res.depths.tolist() == [0, 0]
            assert res.edges_scanned == 0 and res.hops == 0
            assert not res.truncated
        finally:
            svc.close(), engine.close(), g.close()
