"""Fault isolation and failover for the sharded serving path.

Each shard replica owns its OWN PG-Fuse mount, so a storage fault is a
*per-mount* event: an EIO burst on one shard's mount must leave every
other shard answering byte-identically (their mounts never saw the
fault), surface on the failed shard as a clean per-request error with
router/gate/stat conservation intact after the drain, and — when the
shard is replicated — be absorbed entirely by failover to a sibling
replica (``router.reroutes`` counting the trips, ``retried_reads``
counting per-mount retry healing underneath).
"""

import errno
import threading

import numpy as np
import pytest

from repro.core import paragrapher
from repro.graph import rmat
from repro.query import (ShardedQueryService, TraversalError,
                         TraversalService)
from tests.conftest import FaultyStorage

BLOCK = 512
OPEN_KW = dict(pgfuse_block_size=BLOCK, pgfuse_readahead=0,
               pgfuse_eviction="clock", pgfuse_retry_backoff_s=0.0)


@pytest.fixture
def graph_file(tmp_path):
    csr = rmat(9, 7, seed=42)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp, csr


def _burst(fs: FaultyStorage, n: int = 400) -> FaultyStorage:
    """A persistent EIO burst: the next ``n`` underlying calls on the
    instrumented mount all fail (fail_at entries pop as they fire)."""
    start = fs.n_calls
    for i in range(start + 1, start + 1 + n):
        fs.fail_at[i] = OSError(errno.EIO, "dead OST")
    return fs


def test_eio_burst_confined_to_one_shard(graph_file):
    """An EIO burst on shard 1's mount: shard-0 queries answer
    byte-identically throughout (their mount never saw the fault),
    shard-1 queries fail with a clean OSError that is accounted in
    ``failed_batches``, conservation holds mid-failure, and once the
    burst passes the shard serves again — no restart, no residue."""
    gp, csr = graph_file
    with ShardedQueryService(gp, n_shards=2, open_kwargs=OPEN_KW) as svc:
        (a0, a1), (b0, b1) = svc.ranges
        assert a1 == b0 and a0 < a1 < b1
        fs = _burst(FaultyStorage().install_graph(
            svc.replicas[1][0].graph))
        healthy = np.arange(a0, a1, dtype=np.int64)[:64]
        sick = np.arange(b0, b1, dtype=np.int64)[:64]
        for _ in range(3):
            got = svc.neighbors_batch(healthy)
            for v, nbrs in zip(healthy, got):
                assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
        with pytest.raises(OSError):
            svc.neighbors_batch(sick)
        # a mixed batch fails too, but the healthy shard's slice was
        # answered and folded before the sick shard raised:
        # conservation must hold MID-failure, not just after recovery
        mixed = np.concatenate([healthy[:4], sick[:4]])
        with pytest.raises(OSError):
            svc.neighbors_batch(mixed)
        rd = svc.router.as_dict()
        assert rd["failed_batches"] == 2 and rd["reroutes"] == 0
        assert svc.conserved
        fs.fail_at.clear()              # the burst passes
        got = svc.neighbors_batch(sick)
        for v, nbrs in zip(sick, got):
            assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
        assert svc.conserved
        assert svc.per_shard_stats()[0].requests == \
            svc.router.routed_by_shard[0]


def test_failed_shard_is_clean_per_request_traversal_error(graph_file):
    """Traversals through a sharded backend with one dead shard: a
    traversal confined to healthy shards answers byte-identically; one
    whose frontier crosses into the dead range fails as a clean
    per-request error — admission tokens drain, TraversalStats
    conserve, and concurrent healthy traversals never notice."""
    gp, csr = graph_file
    # fault-free reference answers
    with ShardedQueryService(gp, n_shards=2, open_kwargs=OPEN_KW) as ref:
        rtrav = TraversalService(ref)
        (h0, h1), (s0, _) = ref.ranges
        healthy_seeds = [int(h0), int(h0 + 1)]
        sick_seeds = [int(s0)]
        ref_res = rtrav.khop(healthy_seeds, 2)
        rtrav.close()
    with ShardedQueryService(gp, n_shards=2, open_kwargs=OPEN_KW) as svc:
        trav = TraversalService(svc)
        _burst(FaultyStorage().install_graph(svc.replicas[1][0].graph))
        try:
            results, errors = [], []

            def run(seeds):
                try:
                    results.append(trav.khop(seeds, 2))
                except (OSError, TraversalError) as e:
                    errors.append(e)

            ts = [threading.Thread(target=run, args=(s,))
                  for s in (healthy_seeds, sick_seeds, healthy_seeds)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            # k=2 from the healthy range may or may not cross the shard
            # boundary; seeds themselves guarantee at least the sick
            # seed's traversal died and the healthy ones that stayed
            # in-range survived byte-identically
            assert len(errors) >= 1
            for res in results:
                if res.vertices.tolist() == ref_res.vertices.tolist():
                    assert res.depths.tolist() == ref_res.depths.tolist()
            st = trav.stats
            assert st.conserved and st.inflight == 0
            assert st.failed == len(errors)
            assert st.completed == len(results)
            assert trav.gate.inflight == 0 and \
                trav.gate.edges_inflight == 0
            assert svc.conserved
        finally:
            trav.close()


def test_replicated_shard_fails_over_to_sibling(graph_file):
    """replication=2: an EIO burst on shard 0's replica-0 mount is
    invisible to callers — every batch that lands on the dead replica
    reroutes to its sibling and answers byte-identically, with
    ``router.reroutes`` counting exactly the failovers and
    ``failed_batches`` staying zero."""
    gp, csr = graph_file
    with ShardedQueryService(gp, n_shards=2, replication=2,
                             open_kwargs=OPEN_KW) as svc:
        assert svc.routing == "rr"
        _burst(FaultyStorage().install_graph(svc.replicas[0][0].graph))
        v0 = svc.ranges[0][0]
        batch = np.arange(v0, v0 + 8, dtype=np.int64)
        for _ in range(4):               # rr start alternates 0,1,0,1
            got = svc.neighbors_batch(batch)
            for v, nbrs in zip(batch, got):
                assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
        rd = svc.router.as_dict()
        # batches whose rr pointer started at the dead replica rerouted
        assert rd["reroutes"] == 2 and rd["failed_batches"] == 0
        assert rd["shard_batches"][0] == 4
        # the sibling answered everything
        assert svc.replicas[0][1].engine.stats.batches == 4
        assert svc.replicas[0][0].engine.stats.batches == 0
        assert svc.conserved


def test_all_replicas_dead_surfaces_last_error(graph_file):
    """Both replicas of a shard dead: the request raises the LAST
    replica's OSError after trying every sibling, and the batch counts
    as failed (one reroute per sibling tried, then the failure)."""
    gp, _ = graph_file
    with ShardedQueryService(gp, n_shards=2, replication=2,
                             open_kwargs=OPEN_KW) as svc:
        for r in range(2):
            _burst(FaultyStorage().install_graph(svc.replicas[0][r].graph))
        with pytest.raises(OSError, match="dead OST"):
            svc.neighbors_batch([svc.ranges[0][0]])
        rd = svc.router.as_dict()
        assert rd["reroutes"] == 1 and rd["failed_batches"] == 1
        assert svc.conserved


def test_per_mount_retries_heal_under_replication(graph_file):
    """Transient (single-shot) EIO with per-mount ``pgfuse_retries``:
    the replica heals itself underneath the router — ``retried_reads``
    on that mount counts the healing, and NO reroute happens (failover
    is for errors retry could not absorb)."""
    gp, csr = graph_file
    with ShardedQueryService(
            gp, n_shards=2, replication=2,
            open_kwargs=dict(OPEN_KW, pgfuse_retries=2)) as svc:
        target = svc.replicas[0][0]
        fs = FaultyStorage().install_graph(target.graph)
        fs.fail_at[1] = OSError(errno.EIO, "flaky OST")   # transient
        v0 = svc.ranges[0][0]
        got = svc.neighbors_batch([v0])
        assert np.array_equal(got[0], csr.neighbors_of(int(v0)))
        assert target.graph.pgfuse_stats().retried_reads == 1
        rd = svc.router.as_dict()
        assert rd["reroutes"] == 0 and rd["failed_batches"] == 0
        assert svc.conserved
