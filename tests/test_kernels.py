"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.compbin_decode import compbin_decode, compbin_decode_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.segment_sum import segment_sum, segment_sum_ref


@pytest.mark.parametrize("b", [1, 2, 3, 4])
@pytest.mark.parametrize("n", [1, 127, 128, 1000, 40000])
def test_compbin_decode_sweep(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    hi = min(2 ** (8 * b), 2**31)
    ids = rng.integers(0, hi, n, dtype=np.int64)
    packed = np.zeros((n, 8), np.uint8)
    for i in range(b):
        packed[:, i] = (ids >> (8 * i)) & 0xFF
    flat = jnp.asarray(packed[:, :b].reshape(-1))
    out_k = compbin_decode(flat, b, interpret=True)
    out_r = compbin_decode_ref(flat, b)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(out_k), ids.astype(np.int32))


@pytest.mark.parametrize("E,D,N", [(64, 16, 4), (513, 200, 7), (2048, 128, 1024),
                                   (100, 1, 100), (1, 8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_sweep(E, D, N, dtype):
    rng = np.random.default_rng(E + D + N)
    msgs = jnp.asarray(rng.standard_normal((E, D)).astype(np.float32)).astype(dtype)
    ids = jnp.asarray(rng.integers(-1, N, E).astype(np.int32))  # incl. padding
    out_k = segment_sum(msgs, ids, N, interpret=True)
    out_r = segment_sum_ref(msgs, ids, N)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,Dh,causal",
    [
        (2, 4, 2, 256, 256, 64, True),
        (1, 8, 8, 128, 128, 128, True),
        (1, 4, 1, 1, 384, 64, True),      # decode
        (2, 6, 3, 100, 100, 64, True),    # unaligned -> padding
        (1, 2, 2, 64, 256, 64, True),     # chunked prefill
        (1, 2, 2, 128, 128, 64, False),
        (1, 15, 5, 64, 64, 64, True),     # smollm-style heads
    ],
)
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, Dh, causal):
    rng = np.random.default_rng(Sq + Skv)
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, Dh)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, Dh)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, Dh)).astype(np.float32))
    out_k = flash_attention(q, k, v, causal=causal, interpret=True)
    out_r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)).astype(np.float32)).astype(jnp.bfloat16)
    out_k = flash_attention(q, k, v, causal=True, interpret=True)
    out_r = attention_ref(q, k, v, causal=True)
    assert out_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out_k, dtype=np.float32),
                               np.asarray(out_r), rtol=2e-2, atol=2e-2)


def test_segment_sum_kernel_vs_xla_fallback():
    # above MAX_KERNEL_SEGMENTS the op falls back to XLA scatter
    from repro.kernels.segment_sum.ops import MAX_KERNEL_SEGMENTS
    E, D, N = 256, 8, MAX_KERNEL_SEGMENTS + 1
    rng = np.random.default_rng(1)
    msgs = jnp.asarray(rng.standard_normal((E, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    out = segment_sum(msgs, ids, N)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(segment_sum_ref(msgs, ids, N)),
                               rtol=1e-5, atol=1e-5)
