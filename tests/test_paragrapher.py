"""ParaGrapher API (paper §II-A): full/partition/async loading, formats."""

import numpy as np
import pytest

from repro.core import paragrapher as pg
from repro.core.csr import csr_from_edges
from tests._prop import prop


@pytest.fixture(params=["compbin", "webgraph"])
def graph_file(request, tmp_path):
    rng = np.random.default_rng(3)
    nv, ne = 2000, 16000
    csr = csr_from_edges(rng.integers(0, nv, ne), rng.integers(0, nv, ne),
                         nv, dedupe=True)
    path = tmp_path / f"g.{request.param}"
    pg.save_graph(path, csr, format=request.param)
    return str(path), csr, request.param


def test_format_autodetect(graph_file):
    path, csr, fmt = graph_file
    g = pg.open_graph(path)
    assert g.format == fmt
    assert (g.n_vertices, g.n_edges) == (csr.n_vertices, csr.n_edges)
    g.close()


def test_read_full_and_partition(graph_file):
    path, csr, _ = graph_file
    with pg.open_graph(path) as g:
        full = g.read_full()
        assert np.array_equal(full.offsets, csr.offsets)
        np.testing.assert_array_equal(full.neighbors.astype(np.int64),
                                      csr.neighbors.astype(np.int64))
        offs, nbrs = g.read_partition(17, 1333)
        exp = csr.neighbors[csr.offsets[17]:csr.offsets[1333]]
        np.testing.assert_array_equal(nbrs.astype(np.int64), exp.astype(np.int64))
        assert offs[-1] == len(nbrs)


def test_async_read_covers_all_partitions(graph_file):
    path, csr, _ = graph_file
    with pg.open_graph(path, use_pgfuse=True, pgfuse_block_size=8192) as g:
        plan = g.partition_plan(9)
        assert plan[0][0] == 0 and plan[-1][1] == csr.n_vertices
        assert all(a < b for a, b in plan)
        got = {}

        def cb(buf):
            assert buf.error is None
            got[(buf.v0, buf.v1)] = buf.neighbors.copy()

        ar = g.read_async(plan, cb, n_buffers=2, n_workers=3)
        ar.wait(60)
        assert ar.done
        joined = np.concatenate([got[p] for p in sorted(got)])
        np.testing.assert_array_equal(joined.astype(np.int64),
                                      csr.neighbors.astype(np.int64))
        st = g.pgfuse_stats()
        assert st is not None and st.cache_hits > 0


def test_async_error_surfaces(graph_file):
    path, _, _ = graph_file
    with pg.open_graph(path) as g:
        def bad_cb(buf):
            raise RuntimeError("consumer exploded")

        ar = g.read_async([(0, 10)], bad_cb)
        with pytest.raises(RuntimeError, match="consumer exploded"):
            ar.wait(30)


def test_closed_graph_rejects_reads(graph_file):
    path, _, _ = graph_file
    g = pg.open_graph(path)
    g.close()
    with pytest.raises(ValueError):
        g.read_full()


@prop(5)
def test_partition_plan_edge_balance(draw):
    import tempfile, os
    nv = draw.int(100, 3000)
    ne = draw.int(nv, 20000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne), draw.ints(0, nv - 1, ne),
                         nv, dedupe=True)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "g.cbin")
        pg.save_graph(path, csr, format="compbin")
        with pg.open_graph(path) as g:
            n_parts = draw.int(2, 16)
            plan = g.partition_plan(n_parts)
            sizes = [int(csr.offsets[b] - csr.offsets[a]) for a, b in plan]
            assert sum(sizes) == csr.n_edges
            # no partition grossly above the fair share (+1 vertex slack)
            fair = csr.n_edges / len(plan)
            max_deg = int(np.max(csr.degrees())) if csr.n_edges else 0
            assert max(sizes) <= fair + max_deg + 1
