"""Differential fuzz layer over serving-path v2 (the PR-5 lockdown).

Three implementations answer every request trace simultaneously — the
host-decode engine, the device-decode engine (merged packed runs in one
transfer through the Pallas kernel), and the in-memory CSR reference —
and must agree BYTE-identically on neighbors, features, and logits.
Traces are adversarial by construction: zipf hot heads, duplicate-heavy
batches, empty batches, edge-less/isolated vertices.  The same
differential holds under storage-fault injection (transient EIO, short
reads, latency floors), so the retry/span-fetch machinery is exercised
on the device path too.
"""

import errno
import os
import tempfile

import numpy as np
import pytest

from repro.core import paragrapher
from repro.graph import rmat, synthesize_node_features
from repro.obs import (Tracer, event_counts, verify_span_tree,
                       window_close_counts)
from repro.query import (HotSetCache, NeighborQueryEngine,
                         close_reason_counts)
from tests._prop import Draw, prop
from tests.conftest import FaultyStorage


def _hot_cache(draw: Draw) -> HotSetCache:
    """A hot-set tier sized to be BUSY on Draw-scale graphs: admit from
    degree 1 so small-degree property graphs still exercise hits,
    fills, pins and (with the tiny budget arm) real eviction churn."""
    return HotSetCache(budget_bytes=draw.choice([1 << 10, 1 << 16]),
                       min_degree=1, pin_degree=draw.choice([4, 1 << 62]),
                       place=draw.choice(["host", "device"]),
                       prefetch_min_hits=2, prefetch_batch=4)


def _zipf_trace(draw: Draw, n_vertices: int, n_batches: int) -> list:
    """Adversarial request traces: zipf hot head + uniform tail +
    duplicate folds + occasional empty batches (Draw.vertex_batch), with
    a hot set shared ACROSS batches so cross-batch caching is hit."""
    hubs = draw.ints(0, n_vertices - 1, max(4, n_vertices // 16))
    trace = []
    for _ in range(n_batches):
        ids = draw.vertex_batch(n_vertices, max_size=96)
        if ids.size and draw.bool():  # re-point half the batch at hubs
            k = draw.int(1, max(1, ids.size // 2))
            ids[draw.ints(0, ids.size - 1, k)] = \
                hubs[draw.ints(0, len(hubs) - 1, k)]
        trace.append(ids)
    return trace


def _check_span_conservation(name, engine, g=None) -> None:
    """Per-arm span/stats books after a fuzzed trace: every retained
    span tree is structurally valid, the per-reason ``window_close``
    event totals equal the arm's ``close_reasons`` counters, and (when
    the arm's mount is passed) ``retry`` events equal the mount's
    ``retried_reads`` — faults the stats counted are trace-visible,
    one for one."""
    tracer = engine._tracer
    if not tracer.enabled:
        return
    traces = tracer.drain()
    assert tracer.dropped_traces == 0, name
    for root in traces:
        assert verify_span_tree(root) == [], (name, root.name)
    counted = close_reason_counts(engine.stats.as_dict()["close_reasons"])
    assert window_close_counts(traces) == \
        {k: v for k, v in counted.items() if v}, name
    if g is not None:
        assert event_counts(traces, "retry") == \
            g.pgfuse_stats().retried_reads, name


def _check_trace(trace, engines, csr) -> None:
    """Every engine's answer must equal the CSR reference, byte for byte
    (values, dtype, per-slot lengths), and the ragged form must slice to
    the same arrays."""
    for ids in trace:
        answers = {name: e.neighbors_batch(ids)
                   for name, e in engines.items()}
        for name, got in answers.items():
            assert len(got) == len(ids)
            for v, nbrs in zip(ids, got):
                ref = csr.neighbors_of(int(v)).astype(np.int64)
                assert nbrs.dtype == np.int64, name
                assert np.array_equal(nbrs, ref), (name, int(v))
        # ragged differential on one engine per batch (cheap; the lists
        # above already pinned the values)
        name, e = next(iter(engines.items()))
        offs, flat = e.neighbors_batch_ragged(ids)
        assert len(offs) == len(ids) + 1
        for i, nbrs in enumerate(answers[name]):
            assert np.array_equal(flat[offs[i]:offs[i + 1]], nbrs)


@prop(8)
def test_differential_host_device_csr(draw: Draw):
    """Arbitrary graphs (incl. empty rows / isolated vertices), arbitrary
    adversarial traces: host decode == device decode == in-memory CSR."""
    csr = draw.csr(max_edges=1500)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        kw = dict(use_pgfuse=True,
                  pgfuse_block_size=draw.choice([512, 1 << 12]),
                  pgfuse_eviction=draw.choice(["lru", "clock"]),
                  pgfuse_readahead=0)
        with paragrapher.open_graph(gp, **kw) as gh, \
                paragrapher.open_graph(gp, **kw) as gd, \
                paragrapher.open_graph(gp, **kw) as gs:
            engines = {
                "host": NeighborQueryEngine(gh, decode="host",
                                            tracer=Tracer()),
                "device": NeighborQueryEngine(gd, decode="device",
                                              tracer=Tracer()),
                "hotset": NeighborQueryEngine(gs, decode="host",
                                              hotset=_hot_cache(draw),
                                              tracer=Tracer()),
            }
            _check_trace(_zipf_trace(draw, csr.n_vertices, 4), engines, csr)
            # the device engine really took the kernel path whenever it
            # had edges to decode
            dev = engines["device"].stats
            assert dev.device_batches == dev.batches
            # the hot-set arm's accounting stayed conserved while its
            # answers (checked above) stayed byte-identical
            hs = engines["hotset"].hotset.stats
            assert hs.conserved
            assert hs.resident_bytes <= \
                engines["hotset"].hotset.plan.budget_bytes
            # each arm carries its own tracer: span books balance per arm
            for name, e in engines.items():
                _check_span_conservation(name, e)


@prop(6)
def test_differential_under_fault_injection(draw: Draw):
    """The same three-way differential with deterministic RETRYABLE
    storage faults on BOTH engines' mounts: transient EIOs are retried
    (and must leave answers byte-identical), latency floors change
    nothing.  Short reads are deliberately excluded here — they are
    contract violations the strict path must RAISE on (see
    test_short_read_on_span_fetch_recovers /
    test_device_path_surfaces_exhausted_retries for both sides of that
    contract)."""
    csr = draw.csr(max_edges=1200)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        kw = dict(use_pgfuse=True, pgfuse_block_size=512,
                  pgfuse_eviction="clock", pgfuse_readahead=0,
                  pgfuse_retries=3, pgfuse_retry_backoff_s=0.0)
        with paragrapher.open_graph(gp, **kw) as gh, \
                paragrapher.open_graph(gp, **kw) as gd, \
                paragrapher.open_graph(gp, **kw) as gs:
            injectors = {}
            for name, g in (("host", gh), ("device", gd), ("hotset", gs)):
                inj = FaultyStorage(latency_s=1e-5 if draw.bool() else 0.0)
                # spaced injection points: a transient EIO's retry (the
                # NEXT underlying call) must be clean, or the burst
                # rightly exhausts the budget (covered separately below)
                for k in (1, 4, 7):
                    if draw.bool():
                        inj.fail_at[k] = OSError(errno.EIO, "flaky OST")
                injectors[name] = inj.install_graph(g)
            engines = {
                "host": NeighborQueryEngine(gh, decode="host",
                                            tracer=Tracer()),
                "device": NeighborQueryEngine(gd, decode="device",
                                              tracer=Tracer()),
                "hotset": NeighborQueryEngine(gs, decode="host",
                                              hotset=_hot_cache(draw),
                                              tracer=Tracer()),
            }
            _check_trace(_zipf_trace(draw, csr.n_vertices, 3), engines, csr)
            assert engines["hotset"].hotset.stats.conserved
            # injected EIOs that fired were absorbed by the retry policy,
            # and every retry the mount counted is a trace-visible
            # "retry" event on a storage span of that arm
            for name, g in (("host", gh), ("device", gd), ("hotset", gs)):
                fired = sum(1 for (_, _, _, n) in injectors[name].calls
                            if n == -1)
                assert g.pgfuse_stats().retried_reads >= fired
                _check_span_conservation(name, engines[name], g)


@pytest.mark.parametrize("decode", ["host", "device"])
def test_short_read_on_span_fetch_recovers(tmp_path, decode):
    """A short read on the engine's announced span fetch (the FIRST
    underlying call of a cold query) drops the affected blocks silently;
    the strict pread path then re-fetches them whole — answers stay
    byte-identical and no error surfaces."""
    csr = rmat(7, 5, seed=4)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    with paragrapher.open_graph(gp, use_pgfuse=True, pgfuse_block_size=512,
                                pgfuse_readahead=0) as g:
        inj = FaultyStorage()
        inj.truncate_at[1] = 60  # the cold offsets span fetch comes first
        inj.install_graph(g)
        engine = NeighborQueryEngine(g, decode=decode)
        got = engine.neighbors_batch([0, 5, 9])
        for v, nbrs in zip([0, 5, 9], got):
            assert np.array_equal(nbrs, csr.neighbors_of(v))
        assert not inj.truncate_at  # the injected fault actually fired
        # the dropped span blocks were re-read by the strict path
        assert inj.n_calls >= 2


def test_device_path_surfaces_exhausted_retries(tmp_path):
    """A fault burst longer than the retry budget must surface loudly on
    the device path (no silent truncation), and the engine must answer
    correctly again afterwards."""
    csr = rmat(7, 5, seed=2)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    with paragrapher.open_graph(gp, use_pgfuse=True, pgfuse_block_size=512,
                                pgfuse_readahead=0, pgfuse_retries=1,
                                pgfuse_retry_backoff_s=0.0) as g:
        inj = FaultyStorage()
        for k in (1, 2):  # first call and its only retry both fail
            inj.fail_at[k] = OSError(errno.EIO, "dead OST")
        inj.install_graph(g)
        engine = NeighborQueryEngine(g, decode="device")
        with pytest.raises(OSError):
            engine.neighbors_batch([0, 1, 2])
        got = engine.neighbors_batch([0, 1, 2])  # transient: next try works
        for v, nbrs in zip([0, 1, 2], got):
            assert np.array_equal(nbrs, csr.neighbors_of(v))


@pytest.mark.parametrize("decode", ["host", "device"])
def test_served_logits_match_in_memory_reference(tmp_path, decode):
    """End-to-end differential: the server's logits — sample through the
    engine (host OR device decode), gather from the column families,
    one device_put, GCN forward — equal the in-memory reference bit for
    bit on a zipf request stream."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.launch.data_gnn import block_to_edges, ensure_gnn_assets
    from repro.launch.serve import make_gnn_server
    from repro.launch.steps import _GNN_MODULES
    from repro.graph import NeighborSampler

    cfg = get_arch("gcn-cora").make_reduced()
    d_in = cfg.d_in
    workdir = str(tmp_path)
    answer, engine, close = make_gnn_server(
        "gcn-cora", cfg, workdir, fanouts=(3, 2), seed=11, decode=decode)
    try:
        gp, _, _ = ensure_gnn_assets(workdir, d_in, cfg.n_classes)
        csr = paragrapher.open_graph(gp).read_full()
        x = synthesize_node_features(csr.n_vertices, d_in, seed=0)
        ref_sampler = NeighborSampler(csr, (3, 2), seed=11)
        mod = _GNN_MODULES["gcn-cora"]
        params = mod.init_params(cfg, jax.random.key(0))
        fwd = jax.jit(lambda p, b: mod.forward(p, b, cfg))
        rng = np.random.default_rng(5)
        n = csr.n_vertices
        for _ in range(2):
            hot = rng.integers(0, max(1, n // 16), 12)
            cold = rng.integers(0, n, 12)
            seeds = np.where(rng.random(12) < 0.5, hot, cold)
            got = answer(seeds)
            block = ref_sampler.sample(seeds)
            src, dst, nn = block_to_edges(block)
            nodes = np.concatenate(block.layer_nodes)
            valid = np.concatenate(block.layer_valid)
            xr = np.zeros((nn, d_in), np.float32)
            xr[valid] = x[nodes[valid]]
            ref = np.asarray(fwd(params, {
                "x": jnp.asarray(xr),
                "edge_src": jnp.asarray(src.astype(np.int32)),
                "edge_dst": jnp.asarray(dst.astype(np.int32)),
            })[:len(seeds)])
            assert np.array_equal(got, ref), decode
        if decode == "device":
            assert engine.stats.device_batches == engine.stats.batches
            assert engine.stats.bytes_h2d > 0
    finally:
        close()
