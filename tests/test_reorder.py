"""The graph compiler (:mod:`repro.graph.reorder`): orderings,
permutation plumbing, the sidecar format, policy selection, and the
compile_graph end-to-end contract (including the CLI)."""

import os
import struct

import numpy as np
import pytest

from repro.core import paragrapher, policy
from repro.core.csr import csr_from_edges
from repro.graph import reorder
from repro.graph.generators import rmat
from tests._prop import Draw


def _chain(n=8):
    """0-1-2-...-n-1 path plus a hub 0 touching everything."""
    src = np.concatenate([np.arange(n - 1), np.zeros(n - 1, np.int64)])
    dst = np.concatenate([np.arange(1, n), np.arange(1, n)])
    return csr_from_edges(src, dst, n, dedupe=True)


# ---------------------------------------------------------------------------
# orderings
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", reorder.ORDER_FNS)
@pytest.mark.parametrize("case", range(6))
def test_orders_are_valid_permutations_and_deterministic(strategy, case):
    draw = Draw(np.random.default_rng(1000 + case))
    nv = draw.int(1, 500)
    ne = draw.int(0, 2000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne),
                         draw.ints(0, nv - 1, ne), nv)
    fn = reorder.ORDER_FNS[strategy]
    perm = fn(csr)
    # a permutation of 0..n-1, computed deterministically
    np.testing.assert_array_equal(np.sort(perm), np.arange(nv))
    np.testing.assert_array_equal(perm, fn(csr))


def test_bfs_order_visits_levels_from_max_degree_root():
    csr = _chain(8)
    perm = reorder.bfs_order(csr)
    # vertex 0 is the hub => the BFS root => new id 0; every other
    # vertex is in level 1, renumbered in ascending old-id order
    np.testing.assert_array_equal(perm, np.arange(8))


def test_degree_order_puts_hubs_first():
    csr = _chain(8)
    perm = reorder.degree_order(csr)
    assert perm[0] == 0  # max-degree hub gets new id 0
    degrees = csr.degrees()
    ranked = degrees[reorder.invert_permutation(perm)]
    assert (np.diff(ranked) <= 0).all()  # non-increasing by new id


def test_identity_order_is_identity():
    csr = _chain(5)
    np.testing.assert_array_equal(reorder.identity_order(csr), np.arange(5))


# ---------------------------------------------------------------------------
# permutation plumbing
# ---------------------------------------------------------------------------


def test_invert_permutation_validates():
    np.testing.assert_array_equal(
        reorder.invert_permutation(np.array([2, 0, 1])),
        np.array([1, 2, 0]))
    with pytest.raises(ValueError, match="out of range"):
        reorder.invert_permutation(np.array([0, 3]))
    with pytest.raises(ValueError, match="out of range"):
        reorder.invert_permutation(np.array([-1, 0]))
    with pytest.raises(ValueError, match="duplicate"):
        reorder.invert_permutation(np.array([1, 1, 0]))


def test_permute_csr_relabels_rows():
    csr = csr_from_edges(np.array([0, 0, 1]), np.array([1, 2, 2]), 3)
    perm = np.array([2, 0, 1])  # old 0 -> new 2
    out = reorder.permute_csr(csr, perm)
    np.testing.assert_array_equal(out.neighbors_of(2),  # old vertex 0
                                  np.sort(perm[csr.neighbors_of(0)]))
    np.testing.assert_array_equal(out.neighbors_of(0),  # old vertex 1
                                  np.sort(perm[csr.neighbors_of(1)]))
    with pytest.raises(ValueError, match="entries"):
        reorder.permute_csr(csr, np.array([0, 1]))


def test_map_back_restores_original_ids():
    old_of_new = np.array([3, 1, 0, 2])
    got = reorder.map_back(old_of_new, np.array([2, 0, 3]))
    np.testing.assert_array_equal(got, np.array([0, 2, 3]))


# ---------------------------------------------------------------------------
# the sidecar
# ---------------------------------------------------------------------------


def test_sidecar_roundtrip(tmp_path):
    path = str(tmp_path / "g.lgsr.perm")
    perm = np.random.default_rng(3).permutation(257).astype(np.int64)
    n = reorder.write_sidecar(path, perm)
    assert n == os.path.getsize(path) == 16 + 8 * 257
    np.testing.assert_array_equal(reorder.read_sidecar(path), perm)
    assert reorder.sidecar_path_for("out.lgsr") == "out.lgsr.perm"


def test_sidecar_rejects_corruption(tmp_path):
    path = str(tmp_path / "p.perm")
    reorder.write_sidecar(path, np.array([1, 0, 2]))
    blob = open(path, "rb").read()

    bad = str(tmp_path / "bad.perm")
    with open(bad, "wb") as f:          # wrong magic
        f.write(b"NOPE" + blob[4:])
    with pytest.raises(ValueError, match="magic"):
        reorder.read_sidecar(bad)

    with open(bad, "wb") as f:          # unsupported version
        f.write(blob[:4] + struct.pack("<H", 9) + blob[6:])
    with pytest.raises(ValueError, match="version"):
        reorder.read_sidecar(bad)

    with open(bad, "wb") as f:          # body shorter than promised
        f.write(blob[:-8])
    with pytest.raises(IOError, match="truncated"):
        reorder.read_sidecar(bad)

    with open(bad, "wb") as f:          # body is not a permutation
        f.write(blob[:16] + struct.pack("<QQQ", 0, 0, 1))
    with pytest.raises(ValueError, match="duplicate"):
        reorder.read_sidecar(bad)

    with pytest.raises(ValueError):     # refuse to WRITE one too
        reorder.write_sidecar(bad, np.array([0, 0, 1]))


# ---------------------------------------------------------------------------
# policy selection
# ---------------------------------------------------------------------------


def test_choose_reorder_pins():
    assert policy.choose_reorder(100, 0).strategy == "identity"
    assert policy.choose_reorder(0, 0).strategy == "identity"
    assert policy.choose_reorder(1000, 400).strategy == "degree"
    assert policy.choose_reorder(1000, 8000).strategy == "bfs"
    # explicit override wins regardless of shape
    for s in policy.REORDER_STRATEGIES:
        plan = policy.choose_reorder(1000, 8000, strategy=s)
        assert plan.strategy == s and "explicit" in plan.reason
    with pytest.raises(ValueError, match="unknown reorder strategy"):
        policy.choose_reorder(10, 10, strategy="sort-by-vibes")


# ---------------------------------------------------------------------------
# compile_graph end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec_name", ["compbin", "logcsr"])
@pytest.mark.parametrize("strategy", [None, "identity", "degree"])
def test_compile_graph_end_to_end(tmp_path, codec_name, strategy):
    csr = rmat(scale=9, edge_factor=8, seed=4)
    src = str(tmp_path / "in.cbin")
    paragrapher.save_graph(src, csr, format="compbin")
    out = str(tmp_path / f"out.{codec_name}")
    report = reorder.compile_graph(src, out, codec=codec_name,
                                   strategy=strategy, verify_samples=32)
    assert report.codec == codec_name
    assert report.verified_vertices == 32
    assert report.out_bytes == os.path.getsize(out)
    assert report.compression_ratio > 0
    if strategy is not None:
        assert report.strategy == strategy
    d = report.as_dict()
    assert d["compression_ratio"] == report.compression_ratio
    # the sidecar round-trips and inverse-maps a spot-checked vertex
    old_of_new = reorder.read_sidecar(report.sidecar_path)
    new_of_old = reorder.invert_permutation(old_of_new)
    with paragrapher.open_graph(out) as g:
        assert g.n_vertices == csr.n_vertices
        got = reorder.map_back(old_of_new, g.neighbors_of(int(new_of_old[5])))
        np.testing.assert_array_equal(
            got, np.sort(csr.neighbors_of(5).astype(np.int64)))


def test_compile_graph_refuses_bad_compile(tmp_path, monkeypatch):
    """If verification EVER fails the outputs must be removed."""
    csr = rmat(scale=7, edge_factor=6, seed=1)
    src = str(tmp_path / "in.cbin")
    paragrapher.save_graph(src, csr, format="compbin")
    out = str(tmp_path / "out.lgsr")

    def sabotage(old_of_new, new_ids):
        return np.asarray(new_ids, dtype=np.int64) + 1

    monkeypatch.setattr(reorder, "map_back", sabotage)
    with pytest.raises(AssertionError, match="diverged"):
        reorder.compile_graph(src, out, codec="logcsr", verify_samples=4)
    assert not os.path.exists(out)
    assert not os.path.exists(out + ".perm")


def test_compile_graph_cli(tmp_path, capsys):
    import json

    from repro.launch.compile_graph import main

    csr = rmat(scale=8, edge_factor=6, seed=9)
    src = str(tmp_path / "in.cbin")
    paragrapher.save_graph(src, csr, format="compbin")
    out = str(tmp_path / "out.lgsr")
    rc = main(["--in", src, "--out", out, "--codec", "logcsr",
               "--strategy", "bfs", "--verify-samples", "16"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert report["codec"] == "logcsr"
    assert report["strategy"] == "bfs"
    assert report["verified_vertices"] == 16
    assert os.path.exists(out) and os.path.exists(out + ".perm")
