"""End-to-end tests for the streaming partition->device loader
(data/graph_stream.py): byte-exact reassembly, zero host decode on the
CompBin path, readahead effectiveness under injected storage latency,
mesh placement, and early-shutdown safety."""

import os
import time

import numpy as np
import pytest

from repro.core import compbin, paragrapher
from repro.data.graph_stream import assemble_csr, stream_partitions
from repro.graph import erdos_renyi, rmat


@pytest.fixture(scope="module")
def small_graph(tmp_path_factory):
    d = tmp_path_factory.mktemp("gs")
    csr = rmat(12, 8, seed=5)
    paths = {}
    for fmt in ("compbin", "webgraph"):
        p = str(d / f"g.{fmt}")
        paragrapher.save_graph(p, csr, format=fmt)
        paths[fmt] = p
    return csr, paths


def test_stream_compbin_device_decode_equals_read_full(small_graph):
    csr, paths = small_graph
    with paragrapher.open_graph(paths["compbin"], use_pgfuse=True,
                                pgfuse_block_size=1 << 18,
                                pgfuse_readahead=2) as g:
        before = compbin.host_decoded_bytes()
        with stream_partitions(g, None, n_buffers=2, readahead=2) as stream:
            shards = list(stream)
        st = stream.stats
        assert st.decode_mode == "device"
        # THE claim: zero packed bytes decoded on host for CompBin inputs
        assert compbin.host_decoded_bytes() - before == 0
        assert st.host_decode_bytes == 0
        assert assemble_csr(shards) == g.read_full() == csr
        assert st.partitions == len(stream.plan)
        assert st.edges == csr.n_edges
        assert st.vertices == csr.n_vertices
        # packed transfer must beat decoded transfer: b=2 of 4 bytes + pad
        assert st.bytes_h2d > 0
        assert st.decode_s > 0


def test_stream_webgraph_host_decode_equals_read_full(small_graph):
    csr, paths = small_graph
    with paragrapher.open_graph(paths["webgraph"], use_pgfuse=True) as g:
        with stream_partitions(g, None) as stream:
            out = assemble_csr(list(stream))
        assert stream.stats.decode_mode == "host"
        assert stream.stats.host_decode_bytes > 0
        assert out == csr


def test_stream_shards_are_device_resident(small_graph):
    import jax

    csr, paths = small_graph
    with paragrapher.open_graph(paths["compbin"]) as g:
        with stream_partitions(g, None, n_parts=4) as stream:
            for shard in stream:
                assert isinstance(shard.neighbors, jax.Array)
                assert isinstance(shard.offsets, jax.Array)
                assert shard.neighbors.shape == (shard.n_edges,)
                assert shard.offsets.shape == (shard.n_vertices + 1,)


def test_stream_on_data_mesh(small_graph):
    import jax
    from jax.sharding import Mesh

    csr, paths = small_graph
    mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))
    with paragrapher.open_graph(paths["compbin"]) as g:
        with stream_partitions(g, mesh, n_parts=4) as stream:
            shards = list(stream)
        for s in shards:
            assert s.neighbors.sharding.mesh.shape == mesh.shape
        assert assemble_csr(shards) == csr


def test_injected_latency_readahead_cuts_underlying_reads(tmp_path):
    """With a slow storage backend, PG-Fuse sequential readahead must
    reduce the number of underlying requests (fetched as enlarged runs)
    and therefore the charged latency."""
    csr = erdos_renyi(1 << 10, 1 << 14, seed=9)
    p = str(tmp_path / "g.cbin")
    paragrapher.save_graph(p, csr, format="compbin")

    def slow_pread(fd, n, off, _lat=2e-3):
        time.sleep(_lat)  # per-request latency floor (Lustre RPC style)
        return os.pread(fd, n, off)

    reads = {}
    for ra in (0, 4):
        g = paragrapher.open_graph(p, use_pgfuse=True,
                                   pgfuse_block_size=4096,
                                   pgfuse_readahead=ra,
                                   pgfuse_pread_fn=slow_pread)
        try:
            with stream_partitions(g, None, n_parts=4) as stream:
                out = assemble_csr(list(stream))
            assert out == csr
            reads[ra] = g.pgfuse_stats().underlying_reads  # incl. plan reads
        finally:
            g.close()
    # readahead=4 fetches runs of up to 5 blocks per request
    assert reads[4] < reads[0], reads
    assert reads[4] <= reads[0] // 2, reads


def test_stream_early_close_does_not_deadlock(small_graph):
    csr, paths = small_graph
    with paragrapher.open_graph(paths["compbin"], use_pgfuse=True) as g:
        stream = stream_partitions(g, None, n_parts=8, n_buffers=1,
                                   readahead=1)
        first = next(iter(stream))
        assert first.n_edges >= 0
        stream.close()  # producers must unblock and stop
        stream.close()  # idempotent
    # the async read pool must wind down (daemon threads; bounded wait)
    deadline = time.monotonic() + 30
    while any(t.is_alive() for t in stream._async._threads):
        assert time.monotonic() < deadline, "producer threads leaked"
        time.sleep(0.02)


def test_stream_empty_and_tiny_graphs(tmp_path):
    from repro.core.csr import CSR

    for i, csr in enumerate([
        CSR(offsets=np.zeros(2, np.int64), neighbors=np.zeros(0, np.int32)),
        CSR(offsets=np.array([0, 1], np.int64),
            neighbors=np.array([0], np.int32)),
    ]):
        p = str(tmp_path / f"tiny{i}.cbin")
        paragrapher.save_graph(p, csr, format="compbin")
        with paragrapher.open_graph(p) as g:
            with stream_partitions(g, None) as stream:
                assert assemble_csr(list(stream)) == csr


def test_stream_million_edge_graph_matches_read_full():
    """Acceptance-scale run: >= 1M-edge generated graph streamed through
    PG-Fuse + device decode reassembles to read_full() with zero host
    decode bytes."""
    import tempfile

    csr = rmat(16, 24, seed=0)
    assert csr.n_edges >= 1_000_000, csr.n_edges
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "g1m.cbin")
        paragrapher.save_graph(p, csr, format="compbin")
        with paragrapher.open_graph(p, use_pgfuse=True,
                                    pgfuse_readahead=2) as g:
            before = compbin.host_decoded_bytes()
            with stream_partitions(g, None, n_buffers=2,
                                   readahead=2) as stream:
                out = assemble_csr(list(stream))
            assert compbin.host_decoded_bytes() - before == 0
            assert out == g.read_full() == csr
            assert stream.stats.edges == csr.n_edges
