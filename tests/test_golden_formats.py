"""Byte-exact golden-file regression tests for the on-disk formats.

The CompBin and WebGraph encodings are WIRE FORMATS: files written by one
build must load under every later build, and the partition plan / raw
byte-range arithmetic in the streaming loader depends on exact header and
section layout.  These tests pin the encodings to fixtures checked into
``tests/golden/`` — if a single byte of an encoder's output changes, they
fail, turning silent format breaks into explicit, reviewed version bumps.

Regenerating (ONLY for an intentional format change, alongside a VERSION
bump and a loader migration path)::

    PYTHONPATH=src python tests/test_golden_formats.py --regenerate

The golden graphs are literal edge lists (not generated), so the fixtures
are independent of any RNG or generator code.
"""

import hashlib
import io
import pathlib

import numpy as np
import pytest

from repro.core import codec, compbin, featstore, paragrapher, webgraph
from repro.core.csr import CSR

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"


def golden_graphs() -> dict:
    """Canonical literal graphs, chosen to pin the format's edge cases:
    empty graph, isolated vertices (degree-0 rows), a row touching the
    max vertex ID, and a |V| just past the 256 fence (b=2 packing)."""
    six = CSR(
        offsets=np.array([0, 2, 5, 5, 6, 11, 12], dtype=np.int64),
        neighbors=np.array([1, 3,  0, 2, 5,  4,  0, 1, 2, 3, 5,  2],
                           dtype=np.int32),
    )
    empty = CSR(offsets=np.zeros(1, dtype=np.int64),
                neighbors=np.zeros(0, dtype=np.int32))
    # 300 vertices -> bytes_per_vertex = 2: pins the little-endian byte
    # order of multi-byte packed IDs and the u64 offsets of a sparse row
    # structure (only vertices 0, 150, 299 have edges)
    offs = np.zeros(301, dtype=np.int64)
    offs[1:151] = 2            # vertex 0 -> [150, 299]
    offs[151:300] = 4          # vertex 150 -> [0, 299]
    offs[300] = 5              # vertex 299 -> [150]
    fence = CSR(offsets=offs,
                neighbors=np.array([150, 299, 0, 299, 150], dtype=np.int32))
    return {"six": six, "empty": empty, "fence300": fence}


def golden_features() -> dict:
    """Canonical literal feature matrices pinning the FeatStore wire
    format's edge cases: exactly representable float32 values (so the
    fixture is byte-stable across platforms), a float16 store with a
    padded (aligned) data section, an empty store, and a uint8 store.
    Values are (matrix, data_align)."""
    f32 = np.array([[0.0, 0.5, -1.25],
                    [2.0, -0.75, 3.5],
                    [1.0, 0.0, -2.0],
                    [0.25, 4.0, -0.5],
                    [-3.0, 0.125, 1.5]], dtype=np.float32)
    f16 = np.array([[1.0, -0.5], [0.25, 2.0], [-4.0, 0.0], [0.5, -1.5]],
                   dtype=np.float16)
    empty = np.zeros((0, 7), dtype=np.float32)
    bytes8 = np.array([[0, 1, 255], [128, 64, 32]], dtype=np.uint8)
    return {"feat5x3": (f32, 64), "feat4x2h": (f16, 128),
            "featempty": (empty, 64), "feat2x3u8": (bytes8, 64)}


def _fixture(name: str, fmt: str) -> pathlib.Path:
    ext = {"compbin": "cbin", "webgraph": "wg", "logcsr": "lgsr",
           "featstore": "fst"}[fmt]
    return GOLDEN_DIR / f"{name}.{ext}"


def _encode(csr: CSR, fmt: str) -> bytes:
    buf = io.BytesIO()
    paragrapher.save_graph(buf, csr, format=fmt)
    return buf.getvalue()


@pytest.mark.parametrize("fmt", ["compbin", "webgraph", "logcsr"])
@pytest.mark.parametrize("name", sorted(golden_graphs()))
def test_encoder_matches_golden_bytes(name, fmt):
    """Encoding the canonical graph reproduces the checked-in fixture
    byte for byte (sha256 shown on mismatch for quick triage)."""
    csr = golden_graphs()[name]
    got = _encode(csr, fmt)
    want = _fixture(name, fmt).read_bytes()
    assert hashlib.sha256(got).hexdigest() == \
        hashlib.sha256(want).hexdigest(), (
            f"{fmt} wire format changed for {name!r}: "
            f"{len(got)}B vs golden {len(want)}B — if intentional, bump "
            f"VERSION and regenerate tests/golden (see module docstring)")
    assert got == want


@pytest.mark.parametrize("fmt", ["compbin", "webgraph", "logcsr"])
@pytest.mark.parametrize("name", sorted(golden_graphs()))
def test_decoder_reads_golden_fixture(name, fmt):
    """Old files stay loadable: decoding the fixture yields the canonical
    graph (guards against decoder drift independent of the encoder)."""
    csr = golden_graphs()[name]
    reader = {"compbin": compbin.read_compbin,
              "webgraph": webgraph.read_webgraph,
              "logcsr": codec.read_logcsr}[fmt]
    got = reader(io.BytesIO(_fixture(name, fmt).read_bytes()))
    assert got == csr


def test_golden_headers_pin_section_layout():
    """The streaming loader seeks to fixed section offsets; pin them."""
    hdr = compbin.read_header(io.BytesIO(_fixture("six", "compbin").read_bytes()))
    assert (hdr.b, hdr.n_vertices, hdr.n_edges) == (1, 6, 12)
    assert hdr.offsets_start == 24
    assert hdr.neighbors_start == 24 + 8 * 7
    assert hdr.total_size == _fixture("six", "compbin").stat().st_size
    hdr2 = compbin.read_header(
        io.BytesIO(_fixture("fence300", "compbin").read_bytes()))
    assert hdr2.b == 2  # 300 vertices needs 2 bytes/ID


def test_golden_logcsr_header_pins_section_layout():
    """LogCSR's bit-packed offsets arithmetic seeks from header fields;
    pin every derived quantity against the checked-in fixtures."""
    hdr = codec.read_logcsr_header(
        io.BytesIO(_fixture("six", "logcsr").read_bytes()))
    assert (hdr.b, hdr.obits, hdr.n_vertices, hdr.n_edges) == (1, 4, 6, 12)
    # 7 entries * 4 bits = 28 bits -> 4 bytes + 8 guard bytes
    assert hdr.offsets_nbytes == 12
    assert hdr.offsets_start == 36
    assert hdr.neighbors_start == 36 + 12
    assert hdr.total_size == _fixture("six", "logcsr").stat().st_size
    hdr2 = codec.read_logcsr_header(
        io.BytesIO(_fixture("fence300", "logcsr").read_bytes()))
    assert (hdr2.b, hdr2.obits) == (2, 3)  # 300 vertices, 5 edges


def _encode_features(x: np.ndarray, data_align: int) -> bytes:
    return featstore.roundtrip_bytes(x, data_align=data_align)


@pytest.mark.parametrize("name", sorted(golden_features()))
def test_featstore_encoder_matches_golden_bytes(name):
    x, data_align = golden_features()[name]
    got = _encode_features(x, data_align)
    want = _fixture(name, "featstore").read_bytes()
    assert got == want, (
        f"FeatStore wire format changed for {name!r}: "
        f"{len(got)}B sha256={hashlib.sha256(got).hexdigest()[:16]} vs "
        f"golden {len(want)}B "
        f"sha256={hashlib.sha256(want).hexdigest()[:16]} — if intentional, "
        f"bump VERSION and regenerate tests/golden (see module docstring)")


@pytest.mark.parametrize("name", sorted(golden_features()))
def test_featstore_decoder_reads_golden_fixture(name):
    x, _ = golden_features()[name]
    got = featstore.read_featstore(
        io.BytesIO(_fixture(name, "featstore").read_bytes()))
    assert got.dtype == x.dtype
    assert np.array_equal(got, x)


def test_golden_featstore_header_pins_layout():
    """stream_features seeks to data_start + v * row_stride; pin both,
    and pin that data_align pads the section start."""
    hdr = featstore.read_header(
        io.BytesIO(_fixture("feat5x3", "featstore").read_bytes()))
    assert (hdr.n_rows, hdr.d) == (5, 3)
    assert hdr.dtype == np.float32
    assert hdr.row_stride == 12
    assert hdr.data_start == 64  # one data_align unit past the header
    assert hdr.total_size == _fixture("feat5x3", "featstore").stat().st_size
    hdr16 = featstore.read_header(
        io.BytesIO(_fixture("feat4x2h", "featstore").read_bytes()))
    assert hdr16.dtype == np.float16
    assert hdr16.row_stride == 4
    assert hdr16.data_start == 128


def _regenerate() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name, csr in golden_graphs().items():
        for fmt in ("compbin", "webgraph", "logcsr"):
            p = _fixture(name, fmt)
            p.write_bytes(_encode(csr, fmt))
            print(f"wrote {p} ({p.stat().st_size}B "
                  f"sha256={hashlib.sha256(p.read_bytes()).hexdigest()[:16]})")
    for name, (x, data_align) in golden_features().items():
        p = _fixture(name, "featstore")
        p.write_bytes(_encode_features(x, data_align))
        print(f"wrote {p} ({p.stat().st_size}B "
              f"sha256={hashlib.sha256(p.read_bytes()).hexdigest()[:16]})")


if __name__ == "__main__":
    import sys

    if "--regenerate" in sys.argv:
        _regenerate()
    else:
        print(__doc__)
