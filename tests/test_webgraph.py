"""WebGraph-style codec (paper §II-A): codes, roundtrip, decoders agree."""

import io

import numpy as np
import pytest

from repro.core import webgraph as wg
from repro.core.csr import csr_from_edges
from tests._prop import prop


def test_gamma_known_values():
    # gamma(1)=1, gamma(2)=010, gamma(3)=011, gamma(4)=00100
    pats, bits = wg.gamma_code(np.array([1, 2, 3, 4], np.uint64))
    assert list(bits) == [1, 3, 3, 5]
    assert list(pats) == [1, 2, 3, 4]


def test_zeta3_known_value():
    # Boldi-Vigna: zeta_3(1) = 100 (unary '1' + minimal binary '00')
    pat, bits = wg.zeta_code(np.array([1], np.uint64), 3)
    assert bits[0] == 3 and pat[0] == 0b100


@prop()
def test_code_roundtrip_via_bitreader(draw):
    k = draw.choice([1, 2, 3, 4])
    vals = draw.rng.integers(1, 10**6, 200).astype(np.uint64)
    use_gamma = draw.bool()
    pats, nbits = (wg.gamma_code(vals) if use_gamma else wg.zeta_code(vals, k))
    packed, starts = wg.pack_codes(pats, nbits)
    bits = np.unpackbits(packed)
    rd = wg.BitReader(bits)
    for v in vals:
        got = rd.read_gamma() if use_gamma else rd.read_zeta(k)
        assert got == v


@prop(10)
def test_graph_roundtrip(draw):
    nv = draw.int(2, 3000)
    ne = draw.int(0, 12000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne), draw.ints(0, nv - 1, ne),
                         nv, dedupe=True)
    blob = wg.roundtrip_bytes(csr)
    got = wg.read_webgraph(io.BytesIO(blob))
    assert np.array_equal(got.offsets, csr.offsets)
    np.testing.assert_array_equal(got.neighbors.astype(np.int64),
                                  csr.neighbors.astype(np.int64))


@prop(5)
def test_scalar_oracle_matches_wavefront(draw):
    nv = draw.int(2, 500)
    ne = draw.int(0, 3000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne), draw.ints(0, nv - 1, ne),
                         nv, dedupe=True)
    f = wg.WebGraphFile(io.BytesIO(wg.roundtrip_bytes(csr)))
    for v in draw.ints(0, nv - 1, 8):
        np.testing.assert_array_equal(f.neighbors_of(int(v)),
                                      csr.neighbors_of(int(v)).astype(np.int64))


@prop(5)
def test_partition_read(draw):
    nv = draw.int(10, 1000)
    ne = draw.int(10, 5000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne), draw.ints(0, nv - 1, ne),
                         nv, dedupe=True)
    f = wg.WebGraphFile(io.BytesIO(wg.roundtrip_bytes(csr)))
    v0 = draw.int(0, nv - 1)
    v1 = draw.int(v0, nv)
    offs, nbrs = f.read_partition(v0, v1)
    exp = csr.neighbors[csr.offsets[v0]:csr.offsets[v1]]
    np.testing.assert_array_equal(nbrs, exp.astype(np.int64))


def test_duplicate_edges_rejected():
    csr = csr_from_edges(np.array([0, 0]), np.array([1, 1]), 3)
    with pytest.raises(ValueError, match="dedupe"):
        wg.roundtrip_bytes(csr)


def test_compression_beats_compbin_on_locality():
    """Web-like graphs (consecutive neighbor runs) compress well — the
    regime where the paper keeps WebGraph+PG-Fuse over CompBin."""
    from repro.core import compbin
    nv = 4096
    src = np.repeat(np.arange(nv), 16)
    dst = (src + np.tile(np.arange(1, 17), nv)) % nv  # tight local runs
    csr = csr_from_edges(src, dst, nv, dedupe=True)
    wg_size = len(wg.roundtrip_bytes(csr))
    cb_size = len(compbin.roundtrip_bytes(csr))
    assert wg_size < cb_size
