"""Property tests for partition-plan invariants under host splitting
(graph/partition.py::split_plan): the multi-host loader is only correct
if every vertex is streamed by exactly one process and the work split
stays balanced — these invariants are what the e2e tests lean on."""

import numpy as np

from repro.data.graph_stream import StreamStats
from repro.graph.partition import (host_vertex_range, resplit_from_stats,
                                   split_plan, stream_shares_from_stats,
                                   vertex_range_partition)
from tests._prop import Draw, prop


def _entry_edges(csr, entries):
    return sum(int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in entries)


@prop()
def test_split_plan_partitions_the_plan(draw: Draw):
    """Concatenating the per-host slices reproduces the plan exactly:
    entries are never dropped, duplicated, or reordered."""
    csr = draw.csr()
    plan = draw.plan(csr)
    k = draw.process_count()
    slices = split_plan(plan, k)
    assert len(slices) == k
    concat = [e for s in slices for e in s]
    assert concat == plan


@prop()
def test_split_plan_host_ranges_disjoint_and_cover(draw: Draw):
    """Per-host vertex ranges are contiguous, mutually disjoint, and
    cover [0, n_vertices) with no gaps."""
    csr = draw.csr()
    plan = draw.plan(csr)
    k = draw.process_count()
    slices = split_plan(plan, k)
    cursor = 0
    for s in slices:
        v0, v1 = host_vertex_range(s)
        if not s:
            continue
        assert v0 == cursor, "gap or overlap between host ranges"
        assert v1 >= v0
        # within one host the entries tile its range
        inner = v0
        for (a, b) in s:
            assert a == inner and b > a
            inner = b
        assert inner == v1
        cursor = v1
    if csr.n_vertices:
        assert cursor == csr.n_vertices, "hosts do not cover the graph"


@prop()
def test_split_plan_edge_balance_with_weights(draw: Draw):
    """Weighted splitting keeps every host within the greedy-cut bound:
    total/k + max entry weight (entries are atomic, so no contiguous
    split can beat the largest single entry)."""
    csr = draw.csr(max_edges=2048)
    plan = draw.plan(csr)
    if not plan:
        return
    k = draw.process_count()
    weights = [int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in plan]
    slices = split_plan(plan, k, weights=weights)
    total = sum(weights)
    bound = total / k + max(weights, default=0) + 1e-9
    for s in slices:
        assert _entry_edges(csr, s) <= bound


@prop()
def test_split_plan_unweighted_inherits_plan_balance(draw: Draw):
    """Default (equal-weight) splitting of an EDGE-BALANCED plan stays
    within the same tolerance: per-host edges <= total/k + the heaviest
    plan entry (the plan's own granularity)."""
    csr = draw.csr(max_edges=2048)
    if csr.n_vertices == 0 or csr.n_edges == 0:
        return
    plan = vertex_range_partition(csr, draw.int(1, 9))
    k = draw.process_count()
    slices = split_plan(plan, k)
    per_entry = [int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in plan]
    # equal-weight cuts put ceil/floor(len/k) ENTRIES per host; each entry
    # carries at most max(per_entry) edges beyond the even share
    max_entries = -(-len(plan) // k)
    bound = max_entries * max(per_entry)
    for s in slices:
        assert _entry_edges(csr, s) <= bound


def _assert_tiles(slices, plan):
    """Every vertex of the plan's coverage appears in exactly one host's
    entries, in order (the disjoint/cover invariant for split modes that
    may SPLIT plan entries at a cut)."""
    if not plan:
        assert all(not s for s in slices)
        return
    cursor = plan[0][0]
    for s in slices:
        for (a, b) in s:
            assert a == cursor and b > a, "gap/overlap in host entries"
            cursor = b
    assert cursor == plan[-1][1], "hosts do not cover the plan"


@prop()
def test_split_plan_aligned_cuts_are_block_multiples(draw: Draw):
    """align=: every inter-host cut vertex is a multiple of the block
    grid, and the (possibly entry-splitting) slices still tile the
    plan's coverage disjointly."""
    csr = draw.csr()
    plan = draw.plan(csr)
    k = draw.process_count()
    a = draw.align()
    slices = split_plan(plan, k, align=a)
    assert len(slices) == k
    _assert_tiles(slices, plan)
    nonempty = [s for s in slices if s]
    for s in nonempty[1:]:  # interior cuts only: the grid starts at 0
        assert host_vertex_range(s)[0] % a == 0, \
            f"cut {host_vertex_range(s)[0]} not a multiple of align={a}"


@prop()
def test_split_plan_aligned_stays_balanced_on_fine_grids(draw: Draw):
    """When the grid is fine enough to matter (>= 2 grid points per
    host), aligned splitting stays approximately edge-balanced: each
    host carries at most its ideal share + one plan entry + one aligned
    snap window of edges (the cut moved < align vertices)."""
    csr = draw.csr(max_edges=2048)
    if csr.n_vertices < 8 or csr.n_edges == 0:
        return
    plan = vertex_range_partition(csr, draw.int(2, 9))
    k = draw.process_count(hi=4)
    a = draw.int(1, max(1, csr.n_vertices // (2 * k)))
    weights = [int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in plan]
    slices = split_plan(plan, k, weights=weights, align=a)
    _assert_tiles(slices, plan)
    # worst extra edges any align-wide vertex window can add to a host
    degs = np.diff(csr.offsets)
    window = np.convolve(degs, np.ones(min(a, len(degs))), "valid").max() \
        if len(degs) else 0
    bound = csr.n_edges / k + max(weights, default=0) + window + 1e-9
    for s in slices:
        assert _entry_edges(csr, s) <= bound


@prop()
def test_split_plan_shares_follow_capacity(draw: Draw):
    """shares=: per-host work respects the greedy bound
    ``total * share_i + max(weights)`` — a host declared at half
    capacity cannot receive more than half-plus-one-entry of the work."""
    csr = draw.csr(max_edges=2048)
    plan = draw.plan(csr)
    if not plan:
        return
    k = draw.process_count()
    shares = draw.shares(k)
    weights = [int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in plan]
    slices = split_plan(plan, k, weights=weights, shares=shares)
    assert [e for s in slices for e in s] == plan  # no align: exact slices
    total = sum(weights)
    for i, s in enumerate(slices):
        assert _entry_edges(csr, s) <= \
            total * shares[i] + max(weights, default=0) + 1e-9


@prop()
def test_stream_shares_from_stats_properties(draw: Draw):
    """Shares from measured stats: normalized, floored (no starvation),
    and ordered inversely to measured wall time at equal work."""
    k = draw.process_count(hi=6)
    work = draw.int(100, 10_000)
    walls = [draw.float(0.1, 10.0) for _ in range(k)]
    stats = [StreamStats(edges=work, wall_s=w) for w in walls]
    shares = stream_shares_from_stats(stats, floor=0.25)
    assert shares.shape == (k,)
    assert abs(shares.sum() - 1.0) < 1e-9
    assert shares.min() >= 0.25 / k / 2  # floored, up to renormalization
    order = np.argsort(walls)  # fastest host first
    assert (np.diff(shares[order]) <= 1e-9).all(), \
        "a slower host received a larger share"


def test_resplit_from_stats_shrinks_the_straggler():
    """The between-epochs hook end to end: equal work, one host 4x
    slower -> its re-split slice carries measurably less work."""
    plan = [(i * 8, (i + 1) * 8) for i in range(16)]  # 128 vertices
    fast = StreamStats(edges=1000, wall_s=1.0)
    slow = StreamStats(edges=1000, wall_s=4.0)
    slices, shares = resplit_from_stats(plan, [slow, fast], floor=0.1)
    assert shares[0] < shares[1]
    n0 = sum(b - a for a, b in slices[0])
    n1 = sum(b - a for a, b in slices[1])
    assert n0 < n1, (n0, n1)
    assert n0 <= 128 * 0.3  # ~1/5 share, one-entry granularity slack
    _assert_tiles(slices, plan)
    # hosts with no measurement fall back to the measured mean
    empty = StreamStats()
    shares3 = stream_shares_from_stats([slow, fast, empty], floor=0.1)
    assert shares3[0] < shares3[2] < shares3[1]


@prop()
def test_split_plan_more_hosts_than_entries(draw: Draw):
    """k > len(plan): every entry still lands on exactly one host and the
    overflow hosts receive empty slices (they stream nothing) — never an
    error, never a duplicated range."""
    csr = draw.csr(max_edges=256)
    plan = draw.plan(csr, max_parts=3)
    k = len(plan) + draw.int(1, 5)
    slices = split_plan(plan, k)
    assert [e for s in slices for e in s] == plan
    assert sum(1 for s in slices if s) <= max(1, len(plan))
