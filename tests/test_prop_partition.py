"""Property tests for partition-plan invariants under host splitting
(graph/partition.py::split_plan): the multi-host loader is only correct
if every vertex is streamed by exactly one process and the work split
stays balanced — these invariants are what the e2e tests lean on."""

import numpy as np

from repro.graph.partition import (host_vertex_range, split_plan,
                                   vertex_range_partition)
from tests._prop import Draw, prop


def _entry_edges(csr, entries):
    return sum(int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in entries)


@prop()
def test_split_plan_partitions_the_plan(draw: Draw):
    """Concatenating the per-host slices reproduces the plan exactly:
    entries are never dropped, duplicated, or reordered."""
    csr = draw.csr()
    plan = draw.plan(csr)
    k = draw.process_count()
    slices = split_plan(plan, k)
    assert len(slices) == k
    concat = [e for s in slices for e in s]
    assert concat == plan


@prop()
def test_split_plan_host_ranges_disjoint_and_cover(draw: Draw):
    """Per-host vertex ranges are contiguous, mutually disjoint, and
    cover [0, n_vertices) with no gaps."""
    csr = draw.csr()
    plan = draw.plan(csr)
    k = draw.process_count()
    slices = split_plan(plan, k)
    cursor = 0
    for s in slices:
        v0, v1 = host_vertex_range(s)
        if not s:
            continue
        assert v0 == cursor, "gap or overlap between host ranges"
        assert v1 >= v0
        # within one host the entries tile its range
        inner = v0
        for (a, b) in s:
            assert a == inner and b > a
            inner = b
        assert inner == v1
        cursor = v1
    if csr.n_vertices:
        assert cursor == csr.n_vertices, "hosts do not cover the graph"


@prop()
def test_split_plan_edge_balance_with_weights(draw: Draw):
    """Weighted splitting keeps every host within the greedy-cut bound:
    total/k + max entry weight (entries are atomic, so no contiguous
    split can beat the largest single entry)."""
    csr = draw.csr(max_edges=2048)
    plan = draw.plan(csr)
    if not plan:
        return
    k = draw.process_count()
    weights = [int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in plan]
    slices = split_plan(plan, k, weights=weights)
    total = sum(weights)
    bound = total / k + max(weights, default=0) + 1e-9
    for s in slices:
        assert _entry_edges(csr, s) <= bound


@prop()
def test_split_plan_unweighted_inherits_plan_balance(draw: Draw):
    """Default (equal-weight) splitting of an EDGE-BALANCED plan stays
    within the same tolerance: per-host edges <= total/k + the heaviest
    plan entry (the plan's own granularity)."""
    csr = draw.csr(max_edges=2048)
    if csr.n_vertices == 0 or csr.n_edges == 0:
        return
    plan = vertex_range_partition(csr, draw.int(1, 9))
    k = draw.process_count()
    slices = split_plan(plan, k)
    per_entry = [int(csr.offsets[v1] - csr.offsets[v0]) for v0, v1 in plan]
    # equal-weight cuts put ceil/floor(len/k) ENTRIES per host; each entry
    # carries at most max(per_entry) edges beyond the even share
    max_entries = -(-len(plan) // k)
    bound = max_entries * max(per_entry)
    for s in slices:
        assert _entry_edges(csr, s) <= bound


@prop()
def test_split_plan_more_hosts_than_entries(draw: Draw):
    """k > len(plan): every entry still lands on exactly one host and the
    overflow hosts receive empty slices (they stream nothing) — never an
    error, never a duplicated range."""
    csr = draw.csr(max_edges=256)
    plan = draw.plan(csr, max_parts=3)
    k = len(plan) + draw.int(1, 5)
    slices = split_plan(plan, k)
    assert [e for s in slices for e in s] == plan
    assert sum(1 for s in slices if s) <= max(1, len(plan))
