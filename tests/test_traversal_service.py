"""Fault injection + concurrent-accounting lockdown for the traversal
service.

Storage faults (``FaultyStorage``: transient EIO, short reads, latency)
injected mid-frontier must either retry transparently
(``retried_reads`` asserted) or surface as a clean per-request error —
gate tokens returned, sibling in-flight traversals byte-identical to a
fault-free run, conservation invariants intact.

Also pins the engine's ``QueryStats.reset()`` atomicity under
concurrent batches (the regression found by this PR's audit: ``reset``
used to mutate fields outside the fold lock, so a snapshot taken while
a batch folded could tear ``sum(close_reasons) == batches``).
"""

import errno
import threading

import numpy as np
import pytest

from repro.core import paragrapher
from repro.core.policy import choose_admission
from repro.graph import rmat
from repro.query import NeighborQueryEngine, TraversalService
from tests.conftest import FaultyStorage

BLOCK = 512


def _open(path, **kw):
    kw.setdefault("pgfuse_retry_backoff_s", 0.0)
    g = paragrapher.open_graph(path, use_pgfuse=True,
                               pgfuse_block_size=BLOCK,
                               pgfuse_readahead=0,
                               pgfuse_eviction="clock", **kw)
    engine = NeighborQueryEngine(g, decode="host")
    return TraversalService(engine), engine, g


@pytest.fixture
def graph_file(tmp_path):
    csr = rmat(9, 7, seed=42)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp


def _clean_result(graph_file, *seed_batches, k=3):
    """Reference answers from a fault-free service on the same file."""
    svc, engine, g = _open(graph_file)
    try:
        return [svc.khop(s, k) for s in seed_batches]
    finally:
        svc.close(), engine.close(), g.close()


def _same(a, b):
    assert a.vertices.tolist() == b.vertices.tolist()
    assert a.depths.tolist() == b.depths.tolist()
    assert (a.hops, a.edges_scanned, a.truncated) \
        == (b.hops, b.edges_scanned, b.truncated)


def test_transient_eio_mid_frontier_retries_transparently(graph_file):
    """EIO on the FIRST storage call and again mid-traversal: with
    retries enabled the request never notices — the answer is
    byte-identical to a fault-free run and ``retried_reads`` counts
    exactly the two trips back to storage."""
    # count the fault-free underlying calls to place a fault mid-way
    svc, engine, g = _open(graph_file)
    probe = FaultyStorage().install_graph(g)
    [ref] = [svc.khop([3, 71], 3)]
    n_calls = probe.n_calls
    svc.close(), engine.close(), g.close()
    assert n_calls >= 3, "traversal must take several storage reads"

    svc, engine, g = _open(graph_file, pgfuse_retries=2)
    fs = FaultyStorage()
    fs.fail_at[1] = OSError(errno.EIO, "flaky OST")
    # the fault-free run took n_calls reads; +1 because the first retry
    # adds one extra underlying call before the midpoint
    fs.fail_at[n_calls // 2 + 1] = OSError(errno.EIO, "flaky OST")
    fs.install_graph(g)
    try:
        res = svc.khop([3, 71], 3)
        _same(res, ref)
        assert g.pgfuse_stats().retried_reads == 2
        st = svc.stats
        assert st.completed == 1 and st.failed == 0 and st.conserved
    finally:
        svc.close(), engine.close(), g.close()


def test_exhausted_retry_fails_cleanly_and_short_read_heals(graph_file):
    """With no retry budget an EIO surfaces as a clean per-request
    error (gate tokens come back, the failure is accounted).  A SHORT
    read on the next request's span prefetch is healed structurally —
    the truncated block is dropped, never installed, and re-read — so
    the request still gets the fault-free answer."""
    [ref] = _clean_result(graph_file, [5, 200])
    svc, engine, g = _open(graph_file)  # pgfuse_retries=0
    fs = FaultyStorage()
    fs.fail_at[1] = OSError(errno.EIO, "flaky OST")
    fs.install_graph(g)
    try:
        with pytest.raises(OSError):
            svc.khop([5, 200], 3)
        assert svc.gate.inflight == 0 and svc.gate.edges_inflight == 0
        # a truncated span-prefetch read must never hand short bytes to
        # the decoder: the block reverts to NOT_LOADED and reloads
        fs.truncate_at[fs.n_calls + 1] = 7
        _same(svc.khop([5, 200], 3), ref)
        assert any(returned == 7 for _, _, _, returned in fs.calls), \
            "the short read never fired"
        assert svc.gate.inflight == 0 and svc.gate.edges_inflight == 0
        st = svc.stats
        assert st.failed == 1 and st.completed == 1 and st.conserved
    finally:
        svc.close(), engine.close(), g.close()


def test_failed_request_leaves_sibling_inflight_intact(graph_file):
    """Request A is admitted and in flight when request B dies on a
    storage fault: B's failure releases only B's tokens, and A — run
    over the very cache the fault touched — still answers
    byte-identically to the fault-free reference."""
    ref_a, ref_b = _clean_result(graph_file, [9, 130], [77, 300])
    plan = choose_admission(0.5, edge_budget=1 << 16,
                            service_edges_per_s=5e6, servers=2)
    svc, engine, g = _open(graph_file)
    svc.gate.plan = plan
    fs = FaultyStorage()
    fs.install_graph(g)
    try:
        from repro.query import TraversalRequest
        req_a = TraversalRequest("khop", [9, 130], k=3,
                                 max_edges=1 << 16)
        req_b = TraversalRequest("khop", [77, 300], k=3,
                                 max_edges=1 << 16)
        assert svc.admit(req_a) and svc.admit(req_b)
        assert svc.gate.inflight == 2
        fs.fail_at[fs.n_calls + 1] = OSError(errno.EIO, "flaky OST")
        with pytest.raises(OSError):
            svc.perform(req_b)           # fails cleanly, releases B only
        assert svc.gate.inflight == 1
        assert svc.stats.failed == 1 and svc.stats.inflight == 1
        res_a = svc.perform(req_a)       # the sibling is untouched
        svc.complete(req_a, 0.0)
        _same(res_a, ref_a)
        _same(svc.khop([77, 300], 3), ref_b)   # B's retry succeeds
        st = svc.stats
        assert st.conserved and st.inflight == 0
        assert svc.gate.inflight == 0 and svc.gate.edges_inflight == 0
    finally:
        svc.close(), engine.close(), g.close()


def test_concurrent_submits_survive_fault_burst(graph_file):
    """Six concurrent ``submit()`` traversals through a burst of
    transient EIOs with one retry each: every request either completes
    with the fault-free answer or fails with a clean OSError; the
    counters conserve and the gate fully drains."""
    batches = [[i * 17 % 500, i * 53 % 500] for i in range(6)]
    refs = _clean_result(graph_file, *batches)
    svc, engine, g = _open(graph_file, pgfuse_retries=1)
    fs = FaultyStorage()
    fs.install_graph(g)
    try:
        from repro.query import TraversalRequest
        for i in range(1, 5):            # 4 consecutive flaky calls
            fs.fail_at[i] = OSError(errno.EIO, "flaky OST")
        futures = [svc.submit(TraversalRequest("khop", b, k=3))
                   for b in batches]
        ok, bad = 0, 0
        for fut, ref in zip(futures, refs):
            try:
                _same(fut.result(timeout=30), ref)
                ok += 1
            except OSError:
                bad += 1
        st = svc.stats
        assert ok + bad == 6 == st.admitted
        assert st.completed == ok and st.failed == bad
        assert st.conserved and st.inflight == 0
        assert svc.gate.inflight == 0 and svc.gate.edges_inflight == 0
        assert g.pgfuse_stats().retried_reads >= 1
        for b, ref in zip(batches, refs):    # full recovery
            _same(svc.khop(b, 3), ref)
    finally:
        svc.close(), engine.close(), g.close()


def test_latency_injection_only_slows_never_corrupts(graph_file):
    """A per-request storage latency floor mid-frontier changes
    timings, never answers: results stay byte-identical and nothing is
    retried or failed."""
    [ref] = _clean_result(graph_file, [0, 1, 2])
    svc, engine, g = _open(graph_file)
    FaultyStorage(latency_s=1e-4).install_graph(g)
    try:
        _same(svc.khop([0, 1, 2], 3), ref)
        assert g.pgfuse_stats().retried_reads == 0
        st = svc.stats
        assert st.completed == 1 and st.failed == 0 and st.conserved
    finally:
        svc.close(), engine.close(), g.close()


# -- QueryStats.reset() / close_reasons under concurrency (regression) ----

def test_querystats_reset_atomic_under_concurrent_batches(graph_file):
    """Hammer ``neighbors_batch`` from worker threads while the main
    thread snapshots via ``reset()`` and ``as_dict()``: every snapshot
    must satisfy ``sum(close_reasons) == batches`` (the invariant used
    to tear when ``reset`` mutated outside the fold lock), and no batch
    may be lost or double-counted across the epoch cuts."""
    g = paragrapher.open_graph(graph_file, use_pgfuse=True,
                               pgfuse_block_size=BLOCK,
                               pgfuse_readahead=0,
                               pgfuse_eviction="clock")
    engine = NeighborQueryEngine(g, decode="host")
    n_threads, per_thread = 4, 60
    start = threading.Event()
    errors: list = []

    def worker(tid):
        rng = np.random.default_rng(tid)
        start.wait()
        for _ in range(per_thread):
            v = rng.integers(0, engine.n_vertices, 8)
            engine.neighbors_batch(v.tolist())

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    try:
        for t in threads:
            t.start()
        start.set()
        snapshots = []
        while any(t.is_alive() for t in threads):
            live = engine.stats.as_dict()
            if sum(live["close_reasons"].values()) != live["batches"]:
                errors.append(("as_dict tear", live))
            snapshots.append(engine.stats.reset())
        for t in threads:
            t.join()
        snapshots.append(engine.stats.reset())
        assert not errors, errors[0]
        total_batches = 0
        for snap in snapshots:
            # the invariant holds on EVERY epoch cut, not just quiescent
            assert sum(snap.close_reasons.values()) == snap.batches, \
                (snap.batches, snap.close_reasons)
            assert snap.latencies.n <= snap.batches
            total_batches += snap.batches
        total_batches += engine.stats.batches
        assert total_batches == n_threads * per_thread
    finally:
        engine.close(), g.close()


def test_traversalstats_reset_carries_inflight(graph_file):
    """``TraversalStats.reset()`` with requests still in flight: the
    snapshot absorbs only finished history, the live object keeps the
    outstanding requests, and conservation holds on BOTH sides — before
    and after those requests complete."""
    from repro.query import TraversalRequest

    svc, engine, g = _open(graph_file)
    try:
        svc.khop([1, 2], 1)                       # finished history
        req = TraversalRequest("khop", [3], k=1)
        assert svc.admit(req)                     # in flight across the cut
        snap = svc.stats.reset()
        assert snap.submitted == 1 and snap.completed == 1
        assert snap.inflight == 0 and snap.conserved
        live = svc.stats
        assert live.inflight == 1 and live.admitted == 1 \
            and live.submitted == 1 and live.conserved
        svc.perform(req)
        svc.complete(req, 0.001)
        assert live.completed == 1 and live.inflight == 0
        assert live.conserved
        assert svc.gate.inflight == 0
    finally:
        svc.close(), engine.close(), g.close()
