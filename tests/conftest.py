"""Shared fixtures: deterministic storage-fault injection for PG-Fuse.

``FaultyStorage`` wraps a :class:`repro.core.pgfuse.CachedFile`'s
``_read_underlying_range`` — the single funnel every underlying storage
request passes through — so tests can inject the failure modes a Lustre /
SSD-pool deployment actually produces:

  * **transient errors** (``EIO`` from a flaky OST, surfacing exactly
    once and succeeding on retry),
  * **short reads** (the filesystem returning fewer bytes than asked),
  * **latency** (a per-request floor, for readahead-effectiveness tests).

Faults are keyed by the 1-based index of the underlying call *after*
installation, which makes every test scenario deterministic: the k-th
storage request fails, no matter how threads interleave, because the call
counter is taken under a lock.
"""

from __future__ import annotations

import threading
import time

import pytest


class FaultyStorage:
    """Programmable fault injector over ``CachedFile._read_underlying_range``.

    Configure, then :meth:`install` onto one or more CachedFiles::

        fs = FaultyStorage(latency_s=1e-3)
        fs.fail_at[2] = OSError(errno.EIO, "flaky OST")   # 2nd call fails
        fs.truncate_at[3] = 10                            # 3rd returns 10 B
        fs.install(cached_file)

    ``fail_at`` / ``truncate_at`` entries are popped when they fire, so
    every injected fault is transient: the next attempt at the same block
    goes through unharmed.  ``calls`` records ``(index, b0, n_blocks,
    returned_bytes)`` for assertions about what storage actually saw.
    """

    def __init__(self, latency_s: float = 0.0):
        self.latency_s = latency_s
        self.fail_at: dict[int, BaseException] = {}
        self.truncate_at: dict[int, int] = {}
        self.calls: list[tuple[int, int, int, int]] = []
        self._n = 0
        self._lock = threading.Lock()

    @property
    def n_calls(self) -> int:
        with self._lock:
            return self._n

    def install(self, cached_file) -> "FaultyStorage":
        orig = cached_file._read_underlying_range

        def wrapped(b0: int, n_blocks: int) -> bytes:
            with self._lock:
                self._n += 1
                idx = self._n
                exc = self.fail_at.pop(idx, None)
                cut = self.truncate_at.pop(idx, None)
            if self.latency_s:
                time.sleep(self.latency_s)
            if exc is not None:
                with self._lock:
                    self.calls.append((idx, b0, n_blocks, -1))
                raise exc
            data = orig(b0, n_blocks)
            if cut is not None:
                data = data[:cut]
            with self._lock:
                self.calls.append((idx, b0, n_blocks, len(data)))
            return data

        cached_file._read_underlying_range = wrapped
        return self

    def install_graph(self, graph) -> "FaultyStorage":
        """Install onto an open ``GraphHandle``'s PG-Fuse cache."""
        if graph._fs is None:
            raise ValueError("graph was opened without use_pgfuse=True")
        return self.install(graph._fs.mount(graph.path))


@pytest.fixture
def faulty_storage():
    """A fresh :class:`FaultyStorage` controller per test."""
    return FaultyStorage()
