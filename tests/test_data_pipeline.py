"""Token shards (CompBin-packed), prefetch, neighbor sampler."""

import numpy as np
import pytest

from repro.data import PrefetchIterator, TokenShardReader, write_token_shard
from repro.graph import NeighborSampler, erdos_renyi, rmat
from repro.graph.partition import edge_balanced_partition
from tests._prop import prop


def test_token_shard_roundtrip(tmp_path):
    vocab = 151_936
    rng = np.random.default_rng(0)
    toks = rng.integers(0, vocab, 10_000)
    path = str(tmp_path / "t.ctok")
    write_token_shard(path, toks, vocab)
    r = TokenShardReader(path)
    assert r.b == 3  # 151936 < 2^24 -> 3 bytes/token (25% saving vs int32)
    np.testing.assert_array_equal(r.read_tokens(0, 10_000), toks.astype(np.int32))
    np.testing.assert_array_equal(r.read_tokens(137, 500), toks[137:637])


def test_token_batches_and_pgfuse(tmp_path):
    vocab = 49_152
    rng = np.random.default_rng(1)
    toks = rng.integers(0, vocab, 50_000)
    path = str(tmp_path / "t.ctok")
    write_token_shard(path, toks, vocab)
    r = TokenShardReader(path, use_pgfuse=True, pgfuse_block_size=1 << 14)
    batches = list(r.batches(4, 16, n_steps=3, seed=0))
    assert all(b.shape == (4, 17) for b in batches)
    assert r.pgfuse_stats().underlying_reads > 0
    # packed mode: on-device decode path equals host decode
    packed = next(r.batches(4, 16, n_steps=1, seed=0, packed=True))
    from repro.kernels.compbin_decode import compbin_decode
    import jax.numpy as jnp
    dec = compbin_decode(jnp.asarray(packed.reshape(-1)), r.b, interpret=True)
    np.testing.assert_array_equal(np.asarray(dec).reshape(4, 17),
                                  batches[0])
    r.close()


def test_prefetch_iterator_order_and_errors():
    out = list(PrefetchIterator(range(10), depth=3))
    assert out == list(range(10))

    def boom():
        yield 1
        raise RuntimeError("producer died")

    it = PrefetchIterator(boom(), depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="producer died"):
        list(it)


@prop(5)
def test_sampler_respects_adjacency_and_fanout(draw):
    csr = erdos_renyi(draw.int(20, 200), draw.int(50, 1000),
                      seed=draw.int(0, 99))
    fanouts = (draw.int(1, 5), draw.int(1, 5))
    s = NeighborSampler(csr, fanouts, seed=0)
    seeds = draw.ints(0, csr.n_vertices - 1, 8)
    block = s.sample(seeds)
    assert len(block.layer_nodes) == 3
    assert len(block.layer_nodes[1]) == 8 * fanouts[0]
    # every valid sampled node is a true neighbor of its parent
    for l, f in enumerate(fanouts):
        parents = block.layer_nodes[l]
        children = block.layer_nodes[l + 1]
        valid = block.layer_valid[l + 1]
        for i, par in enumerate(parents):
            if par < 0:
                continue
            nbrs = set(csr.neighbors_of(int(par)).tolist())
            for c, ok in zip(children[i * f:(i + 1) * f],
                             valid[i * f:(i + 1) * f]):
                if ok:
                    assert int(c) in nbrs


def test_sampler_through_paragrapher(tmp_path):
    from repro.core import paragrapher as pg
    csr = rmat(8, 4, seed=3)
    path = str(tmp_path / "g.cbin")
    pg.save_graph(path, csr, format="compbin")
    with pg.open_graph(path, use_pgfuse=True, pgfuse_block_size=4096) as g:
        s = NeighborSampler(g, (3, 3), seed=0)
        block = s.sample(np.arange(16))
        assert block.num_nodes() == 16 + 48 + 144
        assert g.pgfuse_stats().underlying_reads > 0


def test_edge_partition_padding():
    csr = erdos_renyi(50, 333, seed=0)  # dedupe may drop duplicates
    src, dst = edge_balanced_partition(csr, 8)
    shard_len = -(-csr.n_edges // 8)
    assert src.shape == dst.shape == (8, shard_len)
    valid = src >= 0
    assert valid.sum() == csr.n_edges
    # padding aligned between src/dst
    np.testing.assert_array_equal(valid, dst >= 0)


def test_rmat_skew():
    """RMAT degree distribution must be heavier-tailed than ER."""
    r = rmat(10, 8, seed=0)
    e = erdos_renyi(1 << 10, r.n_edges, seed=0)
    assert r.degrees().max() > 3 * e.degrees().max() / 2
