"""Optimizer + gradient compression semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_map
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, ef_compress_psum, ef_state_init,
                         global_norm)


def test_adamw_converges_quadratic_bf16_params():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                      total_steps=200)
    params = {"w": jnp.ones(8, jnp.bfloat16) * 3}
    st = adamw_init(params, cfg)
    target = jnp.arange(8, dtype=jnp.float32) * 0.1

    @jax.jit
    def step(params, st):
        g = jax.grad(lambda p: jnp.sum(
            (p["w"].astype(jnp.float32) - target) ** 2))(params)
        return adamw_update(params, g, st, cfg)

    for _ in range(200):
        params, st, met = step(params, st)
    err = float(jnp.max(jnp.abs(params["w"].astype(jnp.float32) - target)))
    assert err < 0.05
    # master copies keep f32 precision beyond bf16 resolution
    assert st["master"]["w"].dtype == jnp.float32


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    st = adamw_init(params, cfg)
    g = {"w": jnp.ones(4) * 1e6}
    _, _, met = adamw_update(params, g, st, cfg)
    assert float(met["grad_norm"]) > 1e5  # reported pre-clip


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 0.01          # end of warmup
    assert abs(lrs[-1] - 0.1) < 0.01          # min lr
    assert all(a >= b - 1e-6 for a, b in zip(lrs[2:], lrs[3:]))  # decay


def test_global_norm():
    t = {"a": jnp.ones(4) * 3, "b": jnp.ones(9) * 4}
    np.testing.assert_allclose(float(global_norm(t)),
                               np.sqrt(4 * 9 + 9 * 16), rtol=1e-6)


def test_ef_compression_error_feedback_recovers_mean():
    """Repeated compressed transmissions of a constant gradient must
    average to the true value (the EF guarantee)."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    x = jnp.asarray(np.random.default_rng(0).standard_normal(512).astype(np.float32))

    f = jax.jit(shard_map(
        lambda g, e: ef_compress_psum(g, e, "data", axis_size=1),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))
    acc = jnp.zeros_like(x)
    e = ef_state_init(x)
    n = 64
    for _ in range(n):
        m, e = f(x, e)
        acc = acc + m
    lvl = float(jnp.max(jnp.abs(x))) / 127
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(x),
                               atol=1.2 * lvl)


def test_ef_compression_quantized_container_is_int8():
    """The on-wire array must be int8 (visible in jaxpr)."""
    from repro.optim.compression import _quantize
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    def fn(g):
        q, s = _quantize(g, 7, "data")
        return jax.lax.psum(q, "data"), s

    sm = shard_map(fn, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()))
    jaxpr = jax.make_jaxpr(sm)(jnp.ones(16))
    assert "i8" in str(jaxpr)
