"""Differential proof for the sharded scatter-gather serving path.

The :class:`repro.query.ShardedQueryService` contract is byte-identity:
N vertex-range shards, each a simulated process with its own PG-Fuse
mount, must answer every query batch, ragged frontier and traversal
EXACTLY as one engine over the whole file — and both must equal the
in-memory CSR reference.  This suite is that proof, over arbitrary
graphs (cycles, self-loops, isolated vertices, byte-width-fence sizes),
shard counts 1–4, replication factors 1–2, and both decode arms, with
the scatter-gather structure itself pinned (at most one engine batch
per shard per service batch) and router/stat conservation asserted
after every property run.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.core import paragrapher, policy
from repro.graph import rmat
from repro.graph.partition import shard_ranges
from repro.obs import Tracer, verify_span_tree, window_close_counts
from repro.query import (NeighborQueryEngine, ShardedQueryService,
                         TraversalService, close_reason_counts)
from tests._prop import Draw, prop
from tests.test_traversal_differential import _assert_matches, ref_traverse

#: the per-replica mount config the property suites use: small blocks so
#: multi-block adjacency is common, random-access policy like serving
OPEN_KW = dict(pgfuse_block_size=512, pgfuse_readahead=0,
               pgfuse_eviction="clock")


def _sharded(path, draw, decode="host", **kw):
    n_shards = draw.choice([1, 2, 3, 4]) if draw else 2
    replication = (draw.choice([1, 1, 2]) if draw else 1)
    okw = dict(OPEN_KW)
    if draw:
        okw["pgfuse_block_size"] = draw.choice([512, 1 << 12])
        if draw.bool():
            # the hot-set arm: every shard replica carries the HBM tier
            # of decoded runs, and answers must STAY byte-identical
            kw.setdefault("hotset_bytes", draw.choice([1 << 12, 1 << 16]))
    # every fuzzed service run is fully traced (sample_every=1) so
    # _check_conservation can reconcile span events against the stats
    # counters they shadow; max_traces is high enough that retention
    # never truncates the count-based checks
    kw.setdefault("tracer", Tracer(max_traces=100_000))
    return ShardedQueryService(path, n_shards=n_shards,
                               replication=replication, decode=decode,
                               open_kwargs=okw, **kw)


def _check_conservation(svc):
    """Router/stat reconciliation after a run — per-shard sums equal
    service totals, and nothing was routed off the books."""
    assert svc.conserved
    merged = svc.stats
    per_shard = svc.per_shard_stats()
    for field in ("requests", "unique_vertices", "batches",
                  "blocks_touched", "coalesced_reads"):
        assert sum(getattr(s, field) for s in per_shard) == \
            getattr(merged, field), field
    rd = svc.router.as_dict()
    assert sum(rd["routed_by_shard"].values()) == rd["requests"]
    # scatter-gather shape: every service batch ran at most one engine
    # batch per shard (and at least one somewhere, if it had vertices)
    if rd["batches"]:
        assert rd["batches"] <= merged.batches \
            <= rd["batches"] * svc.n_shards
        assert sum(rd["shard_batches"].values()) == merged.batches
    # hot-set arm: fleet totals are the per-shard sums, and the fold
    # preserves both conservation invariants
    hs = svc.hotset_stats()
    if hs is not None:
        assert hs.conserved
        per = [s for s in svc.per_shard_hotset_stats() if s is not None]
        for field in ("lookups", "hits", "fills", "admitted",
                      "resident_bytes"):
            assert sum(getattr(s, field) for s in per) == \
                getattr(hs, field), field
    # span/stats conservation (services built by _sharded carry a full-
    # sampling tracer): every retained trace is structurally valid and
    # the per-reason window_close event totals equal the merged
    # close_reasons counters — the service's replica engines are the
    # only traced batches, so the books balance exactly
    tracer = svc._tracer
    if tracer.enabled:
        traces = tracer.drain()
        assert tracer.dropped_traces == 0
        for root in traces:
            assert verify_span_tree(root) == [], root.name
        counted = close_reason_counts(merged.as_dict()["close_reasons"])
        assert window_close_counts(traces) == \
            {k: v for k, v in counted.items() if v}


@prop(8)
def test_sharded_queries_match_single_engine_and_csr(draw: Draw):
    """Arbitrary graphs x shard counts 1-4 x replication: batched
    neighbors and ragged frontiers from the sharded service are
    byte-identical to ONE engine over the whole file and to the CSR."""
    csr = draw.csr(max_edges=2048)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        svc = _sharded(gp, draw)
        g = paragrapher.open_graph(gp, use_pgfuse=True, **OPEN_KW)
        eng = NeighborQueryEngine(g, decode="host")
        try:
            for _ in range(4):
                batch = draw.vertex_batch(csr.n_vertices)
                got = svc.neighbors_batch(batch)
                want = eng.neighbors_batch(batch)
                assert len(got) == len(want) == len(batch)
                for v, a, b in zip(batch, got, want):
                    assert np.array_equal(a, b), int(v)
                    assert np.array_equal(a, csr.neighbors_of(int(v)))
                # ragged form: same flat buffer, same offsets, and for a
                # sorted frontier the pinned ascending-id order
                frontier = np.unique(batch)
                go, gi = svc.neighbors_batch_ragged(frontier)
                wo, wi = eng.neighbors_batch_ragged(frontier)
                assert np.array_equal(go, wo) and np.array_equal(gi, wi)
                assert go.dtype == np.int64 and gi.dtype == np.int64
            _check_conservation(svc)
        finally:
            eng.close(), g.close(), svc.close()


@prop(8)
def test_sharded_traversals_match_reference(draw: Draw):
    """All three traversal modes over the sharded frontier backend vs
    the pure CSR reference: khop/bfs with tight edge/vertex budgets
    (overshoot stop orders landing ON shard boundaries included) and
    shortest paths with deterministic parents."""
    csr = draw.csr(max_edges=1500)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        svc = _sharded(gp, draw)
        trav = TraversalService(svc)
        try:
            for _ in range(3):
                seeds = draw.vertex_batch(csr.n_vertices, max_size=24)
                if seeds.size == 0:
                    continue
                k = draw.int(0, 4)
                max_edges = draw.choice(
                    [1 << 20, draw.int(0, max(1, csr.n_edges))])
                max_vertices = (None if draw.bool() else
                                draw.int(1, max(1, csr.n_vertices)))
                res = trav.khop(seeds, k, max_edges=max_edges,
                                max_vertices=max_vertices)
                ref = ref_traverse(csr, "khop", seeds, k=k,
                                   max_edges=max_edges,
                                   max_vertices=max_vertices)
                _assert_matches(res, ref, ("khop", k, max_edges,
                                           svc.n_shards))
                res = trav.bfs_visit(seeds, max_edges=max_edges,
                                     max_vertices=max_vertices)
                ref = ref_traverse(csr, "bfs", seeds, max_edges=max_edges,
                                   max_vertices=max_vertices)
                _assert_matches(res, ref, ("bfs", max_edges, max_vertices,
                                           svc.n_shards))
                src = draw.int(0, csr.n_vertices - 1)
                dst = draw.int(0, csr.n_vertices - 1)
                res = trav.shortest_path(src, dst, max_edges=max_edges)
                ref = ref_traverse(csr, "path", [src], target=dst,
                                   max_edges=max_edges)
                _assert_matches(res, ref, ("path", src, dst,
                                           svc.n_shards))
            # each hop was ONE service batch scattering to <= n_shards
            # engine batches
            assert svc.router.batches == trav.stats.frontier_batches
            _check_conservation(svc)
        finally:
            trav.close(), svc.close()


@prop(4)
def test_sharded_device_decode_arm_matches_reference(draw: Draw):
    """The Pallas device-decode arm per shard replica answers identically
    to the host arm and the reference; every per-shard batch with edges
    really ran the kernel."""
    csr = draw.csr(max_edges=1500)
    if csr.n_vertices == 0:
        return
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        svc_d = ShardedQueryService(gp, n_shards=draw.choice([2, 3]),
                                    decode="device", open_kwargs=OPEN_KW)
        trav = TraversalService(svc_d)
        try:
            for _ in range(3):
                seeds = draw.vertex_batch(csr.n_vertices, max_size=16)
                if seeds.size == 0:
                    continue
                k = draw.int(0, 3)
                ref = ref_traverse(csr, "khop", seeds, k=k)
                _assert_matches(trav.khop(seeds, k), ref, "device")
            st = svc_d.stats
            assert st.device_batches == st.batches
            _check_conservation(svc_d)
        finally:
            trav.close(), svc_d.close()


def test_routing_table_and_validation(tmp_path):
    """Range routing: shard_of agrees with the published ranges, empty
    shards are never selected, out-of-range ids raise the engine's
    ValueError, closed services refuse requests."""
    csr = rmat(8, 5, seed=2)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    svc = ShardedQueryService(gp, n_shards=4, open_kwargs=OPEN_KW)
    try:
        assert [r for r in svc.ranges if r[0] < r[1]], svc.ranges
        assert svc.ranges[0][0] == 0
        assert svc.ranges[-1][1] == csr.n_vertices
        for s, (v0, v1) in enumerate(svc.ranges):
            for v in {v0, (v0 + v1) // 2, v1 - 1} if v0 < v1 else ():
                assert svc.shard_of(v) == s, (s, v)
        assert svc.neighbors_batch([]) == []
        with pytest.raises(ValueError, match="vertex ids"):
            svc.neighbors_batch([csr.n_vertices])
        with pytest.raises(ValueError, match="vertex ids"):
            svc.neighbors_batch([-1])
        assert np.array_equal(svc.neighbors_of(3), csr.neighbors_of(3))
    finally:
        svc.close()
    with pytest.raises(ValueError, match="closed"):
        svc.neighbors_batch([0])
    svc.close()  # idempotent


def test_more_shards_than_coverage(tmp_path):
    """More shards than the plan can feed: trailing shards get zero-width
    ranges, are never routed to, and answers stay correct."""
    csr = rmat(4, 3, seed=5)   # tiny graph
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    with ShardedQueryService(gp, n_shards=4, n_parts=2,
                             open_kwargs=OPEN_KW) as svc:
        assert len(svc.ranges) == 4
        assert any(v0 == v1 for v0, v1 in svc.ranges)
        batch = np.arange(csr.n_vertices, dtype=np.int64)
        for v, nbrs in zip(batch, svc.neighbors_batch(batch)):
            assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
        empty = {s for s, (v0, v1) in enumerate(svc.ranges) if v0 == v1}
        assert not (set(svc.router.routed_by_shard) & empty)
        _check_conservation(svc)


def test_replication_round_robin_spreads_and_stays_identical(tmp_path):
    """replication=2 with rr routing: consecutive per-shard batches
    alternate replicas (hub traffic splits across mounts), answers stay
    byte-identical, and the merged stats still reconcile."""
    csr = rmat(8, 5, seed=7)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    with ShardedQueryService(gp, n_shards=2, replication=2,
                             open_kwargs=OPEN_KW) as svc:
        assert svc.routing == "rr"
        hub = svc.ranges[0][0]      # every batch hits shard 0 only
        for _ in range(6):
            got = svc.neighbors_batch([hub])
            assert np.array_equal(got[0], csr.neighbors_of(int(hub)))
        row = svc.replicas[0]
        counts = [rep.engine.stats.batches for rep in row]
        assert counts == [3, 3], counts         # perfect alternation
        assert svc.replicas[1][0].engine.stats.batches == 0
        _check_conservation(svc)


@prop(8)
def test_shard_ranges_tile_plan_coverage(draw: Draw):
    """shard_ranges: monotone non-overlapping ranges exactly tiling the
    plan's coverage, shares skew included; zero-width ranges pin to the
    previous cut so searchsorted routing never selects them."""
    csr = draw.csr(max_edges=1024)
    plan = draw.plan(csr)
    n_shards = draw.process_count()
    shares = draw.shares(n_shards) if draw.bool() else None
    ranges = shard_ranges(plan, n_shards, shares=shares)
    assert len(ranges) == n_shards
    if not plan:
        assert all(r == (0, 0) for r in ranges)
        return
    prev = plan[0][0]
    for v0, v1 in ranges:
        assert v0 <= v1
        assert v0 == prev           # contiguous tiling, no gaps
        prev = v1
    assert prev == plan[-1][1]
    # routing consistency: bounds-ends searchsorted lands every covered
    # vertex in the shard whose range holds it
    bounds = np.asarray([v1 for _, v1 in ranges], dtype=np.int64)
    for s, (v0, v1) in enumerate(ranges):
        for v in {v0, v1 - 1} if v0 < v1 else ():
            assert int(np.searchsorted(bounds, v, side="right")) == s


def test_choose_shard_plan_policy():
    """Shard-count sizing: cache pressure and offered load each force
    shards up (capped), hub-heavy traffic turns on replication + rr."""
    GiB = 1 << 30
    p = policy.choose_shard_plan(1 * GiB, cache_budget_bytes=2 * GiB)
    assert (p.n_shards, p.replication, p.routing) == (1, 1, "direct")
    p = policy.choose_shard_plan(8 * GiB, cache_budget_bytes=2 * GiB)
    assert p.n_shards == 4 and "cache budgets" in p.reason
    p = policy.choose_shard_plan(1 * GiB, cache_budget_bytes=2 * GiB,
                                 offered_edges_per_s=20e6,
                                 shard_edges_per_s=5e6)
    assert p.n_shards == 4
    p = policy.choose_shard_plan(64 * GiB, cache_budget_bytes=1 * GiB,
                                 max_shards=16)
    assert p.n_shards == 16        # capped
    p = policy.choose_shard_plan(1 * GiB, cache_budget_bytes=2 * GiB,
                                 hot_fraction=0.7)
    assert p.replication == 2 and p.routing == "rr"
    with pytest.raises(ValueError):
        policy.choose_shard_plan(-1, cache_budget_bytes=1)
    with pytest.raises(ValueError):
        policy.choose_shard_plan(1, cache_budget_bytes=1,
                                 offered_edges_per_s=1e6)  # rate pair


def test_service_from_shard_plan(tmp_path):
    """A ShardPlan from the policy wires straight into the service
    constructor (explicit kwargs still win)."""
    csr = rmat(7, 4, seed=4)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    size = os.path.getsize(gp)
    plan = policy.choose_shard_plan(size, cache_budget_bytes=-(-size // 2),
                                    hot_fraction=0.8)
    assert plan.n_shards >= 2 and plan.replication == 2
    with ShardedQueryService(gp, plan=plan, open_kwargs=OPEN_KW) as svc:
        assert svc.n_shards == plan.n_shards
        assert svc.replication == 2 and svc.routing == "rr"
        v = csr.n_vertices // 2
        assert np.array_equal(svc.neighbors_of(v), csr.neighbors_of(v))
    with ShardedQueryService(gp, plan=plan, replication=1,
                             open_kwargs=OPEN_KW) as svc:
        assert svc.replication == 1    # explicit kwarg overrides plan
