"""Integration: end-to-end drivers in subprocesses + an 8-device mini
version of the production dry-run machinery."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess e2e drivers; excluded from tier-1

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, timeout=600, env=None):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, timeout=timeout, env=env or ENV,
                          cwd=REPO)


def test_train_with_failure_injection_and_restart(tmp_path):
    """Train 30 steps with a failure injected at step 17: the trainer must
    restore from the step-10 checkpoint and finish."""
    r = _run(["-m", "repro.launch.train", "--arch", "gcn-cora", "--reduced",
              "--steps", "30", "--ckpt-every", "10",
              "--inject-failure-at", "17",
              "--workdir", str(tmp_path),
              "--ckpt-dir", str(tmp_path / "ckpt")])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "injected host failure" in r.stderr
    assert "restoring latest checkpoint" in r.stderr


def test_train_lm_through_packed_tokens(tmp_path):
    r = _run(["-m", "repro.launch.train", "--arch", "qwen2-1.5b", "--reduced",
              "--steps", "12", "--workdir", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done:" in r.stderr


def test_train_with_grad_compression(tmp_path):
    r = _run(["-m", "repro.launch.train", "--arch", "din", "--reduced",
              "--steps", "10", "--batch", "16", "--compress-grads",
              "--workdir", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]


def test_train_sampled_through_query_engine(tmp_path):
    """--sampled: minibatch GCN drawn through the random-access query
    engine + column-family stores, in a fresh interpreter."""
    r = _run(["-m", "repro.launch.train", "--arch", "gcn-cora", "--reduced",
              "--steps", "20", "--sampled", "--workdir", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "sampled mode" in r.stderr
    assert "done:" in r.stderr


def test_serve_gnn_requests(tmp_path):
    """GNN serving: query -> gather features -> GCN forward, with
    latency + query-engine stats reported."""
    r = _run(["-m", "repro.launch.serve", "--arch", "gcn-cora", "--reduced",
              "--batch", "8", "--requests", "6",
              "--workdir", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "query dedup" in r.stderr


def test_serve_lm_decode(tmp_path):
    r = _run(["-m", "repro.launch.serve", "--arch", "smollm-360m", "--reduced",
              "--batch", "2", "--prompt-len", "16", "--tokens", "8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stderr


def test_mini_dryrun_8_devices(tmp_path):
    """The dry-run machinery on an 8-device (4x2) host mesh: lower+compile
    a reduced LM train cell and a GNN cell, assert roofline terms emitted.
    (The full 512-device x 40-cell sweep runs via launch/dryrun.py --all;
    its results are committed in results/dryrun.json.)"""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax
from repro.launch.steps import build_cell
from repro.launch.hlo_analysis import parse_collectives, roofline
mesh = jax.make_mesh((4, 2), ("data", "model"))
cell = build_cell("gcn-cora", "full_graph_sm", mesh)
with mesh:
    jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings, donate_argnums=cell.donate)
    compiled = jf.lower(*cell.args).compile()
cost = compiled.cost_analysis()
coll = parse_collectives(compiled.as_text())
rl = roofline(cost, coll, 8, cell.model_flops)
print(json.dumps({"flops": rl.flops_per_device, "dom": rl.dominant,
                  "wire": coll.wire_bytes}))
"""
    r = _run(["-c", script], timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
    assert out["dom"] in ("compute", "memory", "collective")


@pytest.mark.parametrize("fmt", ["compbin", "webgraph"])
def test_example_quickstart_formats(tmp_path, fmt):
    """quickstart example runs for both formats."""
    r = _run(["examples/quickstart.py", "--format", fmt,
              "--workdir", str(tmp_path)], timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "speedup" in r.stdout or "loaded" in r.stdout
