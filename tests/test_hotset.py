"""Hot-set tier unit + integration suite (cache tier 3).

Everything here is deterministic — admission, eviction order and
prefetch predictions are pure functions of the call sequence — so the
churn scenarios replay exactly: the degree-pinned hub must survive an
arbitrary amount of warm-middle churn, the cold tail must never enter,
the clock sweep must honor second chances, and the engine-integrated
tier must answer byte-identically to the plain packed-byte path
(including while storage faults hit the fill path).  The adversarial
byte-identity proof lives in the differential fuzzers
(tests/test_serving_differential.py, tests/test_sharded_differential.py,
tests/test_traversal_differential.py — each runs a hot-set arm); this
file pins the tier's MECHANISMS.
"""

import errno

import numpy as np
import pytest

from repro.core import paragrapher, policy
from repro.graph import rmat
from repro.query import (BYTES_PER_EDGE, HotSetCache, HotSetStats,
                         NeighborQueryEngine, ShardedQueryService,
                         merge_hotset_stats)
from tests.conftest import FaultyStorage


def _run(v: int, degree: int) -> np.ndarray:
    """A recognizable synthetic decoded run for vertex ``v``."""
    return (np.arange(degree, dtype=np.int64) + 7 * v) % (1 << 20)


def _cache(budget_edges: int, *, min_degree=4, pin_degree=64,
           place="host", **kw) -> HotSetCache:
    return HotSetCache(budget_bytes=budget_edges * BYTES_PER_EDGE,
                       min_degree=min_degree, pin_degree=pin_degree,
                       place=place, **kw)


# -- policy ----------------------------------------------------------------

def test_choose_hotset_admission_thresholds():
    """Thresholds scale from the mean degree; placement follows the
    int32 lane constraint; bad inputs raise."""
    p = policy.choose_hotset_admission(1000, 16000, 1 << 20)
    assert p.min_degree == 32 and p.pin_degree == 256
    assert p.place == "device" and p.device
    assert "mean degree 16.0" in p.reason
    # sparse graph: the floor keeps degree-1 tail out even at mean ~1
    p = policy.choose_hotset_admission(1000, 900, 1 << 20)
    assert p.min_degree == 2
    # beyond int32 lanes the tier degrades to host placement
    p = policy.choose_hotset_admission((1 << 31) + 1, 1 << 33, 1 << 20)
    assert p.place == "host" and not p.device
    with pytest.raises(ValueError, match="budget_bytes"):
        policy.choose_hotset_admission(10, 10, 0)
    with pytest.raises(ValueError, match="pin_fraction"):
        policy.choose_hotset_admission(10, 10, 1, pin_fraction=1.5)
    with pytest.raises(ValueError, match=">= 0"):
        policy.choose_hotset_admission(-1, 10, 1)


def test_cache_constructor_validation():
    with pytest.raises(ValueError, match="plan= or budget_bytes="):
        HotSetCache()
    with pytest.raises(ValueError, match="budget_bytes"):
        HotSetCache(budget_bytes=0)
    with pytest.raises(ValueError, match="place"):
        HotSetCache(budget_bytes=1, place="tpu")
    with pytest.raises(ValueError, match="pin_fraction"):
        HotSetCache(budget_bytes=1, pin_fraction=-0.1)
    # explicit kwargs override plan fields
    plan = policy.choose_hotset_admission(100, 1600, 1 << 20)
    c = HotSetCache(plan=plan, min_degree=1, place="host")
    assert c.plan.min_degree == 1 and c.plan.place == "host"
    assert c.plan.budget_bytes == 1 << 20


# -- admission / eviction churn (deterministic virtual clock) --------------

def test_degree_pinned_hub_survives_churn_and_cold_tail_bypasses():
    """The churn scenario from the admission design: one pinned hub, a
    stream of warm-middle entries far over budget, and a cold tail.
    After arbitrary churn the hub is still resident (the sweep never
    takes pinned entries), the middle saw real evictions, and no
    cold-tail vertex ever became resident."""
    cache = _cache(budget_edges=1000, min_degree=4, pin_degree=64)
    assert cache.fill(0, _run(0, 100))          # the hub: pinned
    assert cache.is_pinned(0)
    # cold tail: degree < min_degree bypasses, never admitted
    for v in range(1000, 1040):
        assert not cache.fill(v, _run(v, 3))
    # warm middle: 90 entries x 20 edges = 1800 edges >> remaining budget
    for v in range(1, 91):
        cache.fill(v, _run(v, 20))
    st = cache.stats
    assert st.evicted > 0, "churn must exceed the budget"
    assert cache.is_pinned(0), "pinned hub evicted by churn"
    resident = set(cache.resident_vertices.tolist())
    assert 0 in resident
    assert not (resident & set(range(1000, 1040))), "cold tail leaked in"
    assert st.bypassed == 40
    assert st.conserved
    assert st.resident_bytes <= cache.plan.budget_bytes
    # the hub's bytes stayed charged the whole time
    assert st.resident_bytes >= 100 * BYTES_PER_EDGE
    # lookups answer the hub byte-identically after all that churn
    got = cache.lookup(np.array([0], dtype=np.int64))
    assert np.array_equal(got[0], _run(0, 100))
    assert got[0].dtype == np.int64


def test_clock_sweep_gives_second_chances():
    """PG-Fuse's ``eviction="clock"`` semantics lifted to decoded runs:
    a fresh fill carries a set reference bit (one churn round of grace),
    so the FIRST over-budget sweep clears the round and takes the
    entry at the hand — and after that, only a re-touched entry's bit
    is set again, so the next sweep evicts an un-touched survivor, not
    the re-referenced one."""
    cache = _cache(budget_edges=48, min_degree=4, pin_degree=1 << 62)
    for v in (1, 2, 3):
        assert cache.fill(v, _run(v, 16))
    # sweep 1: clears every fresh bit, then evicts at the hand (1)
    assert cache.fill(4, _run(4, 16))
    assert set(cache.resident_vertices.tolist()) == {2, 3, 4}
    # re-touch 2 only; 3's bit stays clear from sweep 1
    cache.lookup(np.array([2], dtype=np.int64))
    # sweep 2: the un-touched 3 is the victim, the re-touched 2 survives
    assert cache.fill(5, _run(5, 16))
    resident = set(cache.resident_vertices.tolist())
    assert resident == {2, 4, 5}, resident
    assert cache.stats.evicted == 2
    assert np.array_equal(cache.lookup(np.array([2]))[2], _run(2, 16))


def test_oversized_and_unmakeable_room_rejected():
    """A run larger than the whole budget is rejected outright; an
    admissible run is rejected when everything resident is pinned."""
    cache = _cache(budget_edges=100, min_degree=2, pin_degree=8,
                   pin_fraction=1.0)
    assert not cache.fill(1, _run(1, 101))           # > budget
    assert cache.stats.rejected == 1
    assert cache.fill(2, _run(2, 90))                # pinned (deg >= 8)
    assert not cache.fill(3, _run(3, 20))            # no unpinned victim
    assert cache.stats.rejected == 2
    assert cache.stats.conserved


def test_pin_fraction_caps_pinned_bytes():
    """Beyond ``pin_fraction`` of the budget a hub is still admitted —
    just unpinned (evictable), so pins can never starve the warm
    middle."""
    cache = _cache(budget_edges=100, min_degree=2, pin_degree=10,
                   pin_fraction=0.5)
    assert cache.fill(1, _run(1, 40))     # pinned: 40 <= 50 edges worth
    assert cache.fill(2, _run(2, 40))     # would breach the cap: unpinned
    assert cache.is_pinned(1) and not cache.is_pinned(2)
    assert cache.stats.pinned == 1


def test_clear_drops_entries_keeps_flow_history():
    cache = _cache(budget_edges=100)
    cache.fill(1, _run(1, 10))
    cache.lookup(np.array([1]))
    cache.clear()
    assert cache.resident_bytes == 0
    assert cache.resident_vertices.size == 0
    st = cache.stats
    assert st.hits == 1 and st.fills == 1          # history survives
    assert st.resident_entries == 0 and st.pinned == 0


# -- stats -----------------------------------------------------------------

def test_stats_merge_associative_and_conserved():
    a = HotSetStats(lookups=10, hits=7, misses=3, fills=5, admitted=3,
                    bypassed=1, rejected=1, evicted=2, pinned=1,
                    prefetch_fills=1, hit_edges=70, resident_bytes=800,
                    resident_entries=1)
    b = HotSetStats(lookups=4, hits=1, misses=3, fills=2, admitted=1,
                    bypassed=1, evicted=1, hit_edges=9,
                    resident_bytes=80, resident_entries=1)
    c = HotSetStats(lookups=1, misses=1)
    ab_c = a.merge(b).merge(c)
    a_bc = a.merge(b.merge(c))
    assert ab_c.as_dict() == a_bc.as_dict()
    assert ab_c.lookups == 15 and ab_c.hits == 8
    assert ab_c.resident_bytes == 880
    assert ab_c.conserved
    folded = merge_hotset_stats([a, b, c])
    assert folded.as_dict() == ab_c.as_dict()
    assert merge_hotset_stats([]).lookups == 0
    d = a.as_dict()
    assert d["hit_rate"] == 0.7
    assert "_lock" not in d


def test_stats_reset_keeps_resident_gauges():
    st = HotSetStats(lookups=5, hits=2, misses=3, resident_bytes=640,
                     resident_entries=2, pinned=1)
    snap = st.reset()
    assert snap.lookups == 5                       # pre-reset snapshot
    assert st.lookups == 0 and st.hits == 0
    assert st.resident_bytes == 640 and st.resident_entries == 2
    assert st.pinned == 1                          # gauges survive


# -- trace-driven prefetch -------------------------------------------------

def test_prefetch_predicts_hot_and_never_refetches_bypassed():
    """A vertex seen ``prefetch_min_hits`` times becomes a candidate
    exactly once; a candidate whose run turned out cold-tail (bypassed
    fill) is never handed out again — but an ADMITTED candidate that is
    later evicted becomes predictable again."""
    cache = _cache(budget_edges=100, min_degree=4,
                   prefetch_min_hits=2, prefetch_batch=4)
    ids = np.array([5, 9], dtype=np.int64)
    cache.observe(ids)
    assert cache.prefetch_candidates().size == 0    # 1 hit < min_hits
    cache.observe(ids)
    cand = cache.prefetch_candidates()
    assert set(cand.tolist()) == {5, 9}
    assert cache.prefetch_candidates().size == 0    # marked attempted
    # 5 turns out cold tail -> bypassed; more observations, still silent
    assert not cache.fill(5, _run(5, 2), prefetch=True)
    cache.observe(ids), cache.observe(ids)
    assert cache.prefetch_candidates().size == 0
    # 9 is admitted; evict it by filling over budget -> predictable again
    assert cache.fill(9, _run(9, 10), prefetch=True)
    assert cache.stats.prefetch_fills == 1
    cache.fill(50, _run(50, 95))
    assert 9 not in set(cache.resident_vertices.tolist())
    cache.observe(ids)
    assert 9 in set(cache.prefetch_candidates().tolist())


def test_prefetch_frequency_window_decays():
    """Observations older than HISTORY_WINDOW distinct folds decay: a
    vertex hot long ago is not predicted forever."""
    from repro.query.hotset import HISTORY_WINDOW
    cache = _cache(budget_edges=100, prefetch_min_hits=2, prefetch_batch=4)
    cache.observe(np.array([7, 7], dtype=np.int64))
    # flood the window with distinct ids until 7's observations age out
    filler = np.arange(10_000, 10_000 + HISTORY_WINDOW, dtype=np.int64)
    cache.observe(filler)
    assert 7 not in set(cache.prefetch_candidates().tolist())


# -- engine integration ----------------------------------------------------

@pytest.fixture()
def graph_path(tmp_path):
    csr = rmat(9, 8, seed=3)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp, csr


def _open(gp):
    return paragrapher.open_graph(gp, use_pgfuse=True,
                                  pgfuse_block_size=512,
                                  pgfuse_readahead=0,
                                  pgfuse_eviction="clock")


def test_engine_hotset_byte_identity_hits_and_placement(graph_path):
    """Engine-level integration on a hub-heavy replay: the hot-set
    engine answers byte-identically to the plain engine, actually HITS
    on the second pass over the hubs, serves device-placed int32 runs
    re-widened to int64, and prefetch fills land outside the request
    accounting."""
    gp, csr = graph_path
    degrees = np.diff(csr.offsets)
    hubs = np.argsort(degrees)[::-1][:16].astype(np.int64)
    with _open(gp) as gh, _open(gp) as gc:
        plain = NeighborQueryEngine(gh, decode="host")
        hot = NeighborQueryEngine(
            gc, decode="host",
            hotset=HotSetCache(budget_bytes=1 << 18, min_degree=2,
                               pin_degree=int(degrees.max()),
                               place="device", prefetch_min_hits=2,
                               prefetch_batch=4))
        rng = np.random.default_rng(0)
        for _ in range(6):
            cold = rng.integers(0, csr.n_vertices, 48)
            ids = np.where(rng.random(48) < 0.5,
                           hubs[rng.integers(0, len(hubs), 48)], cold)
            a = plain.neighbors_batch(ids)
            b = hot.neighbors_batch(ids)
            for v, x, y in zip(ids, a, b):
                assert x.dtype == y.dtype == np.int64
                assert np.array_equal(x, y), int(v)
                assert np.array_equal(x, csr.neighbors_of(int(v)))
        hs = hot.hotset.stats
        assert hs.hits > 0 and hs.conserved
        assert hs.resident_bytes <= hot.hotset.plan.budget_bytes
        # both engines returned identical request accounting
        assert plain.stats.requests == hot.stats.requests
        # a resident hub really lives on the device as int32
        v = int(hot.hotset.resident_vertices[0])
        entry = hot.hotset._entries[v]
        assert np.asarray(entry.store).dtype == np.int32


def test_engine_builds_tier_from_int_plan_and_cache(graph_path):
    """The ``hotset=`` kwarg accepts a byte budget (policy-sized), a
    HotSetPlan, or a prebuilt HotSetCache."""
    gp, csr = graph_path
    plan = policy.choose_hotset_admission(csr.n_vertices, csr.n_edges,
                                          1 << 16, prefetch_min_hits=2)
    for hs in (1 << 16, plan, HotSetCache(plan=plan)):
        with _open(gp) as g:
            e = NeighborQueryEngine(g, decode="host", hotset=hs)
            assert e.hotset is not None
            assert e.hotset.plan.budget_bytes == 1 << 16
            got = e.neighbors_batch([0, 1, 2, 1])
            for v, nbrs in zip([0, 1, 2, 1], got):
                assert np.array_equal(nbrs, csr.neighbors_of(v))
    with _open(gp) as g:
        assert NeighborQueryEngine(g).hotset is None     # default: off


def test_hotset_fills_under_storage_faults(graph_path):
    """Deterministic transient EIOs while the tier is FILLING (and
    prefetching): the retry policy absorbs them, answers stay correct,
    admitted entries hold the true decoded bytes, and the accounting
    stays conserved."""
    gp, csr = graph_path
    g = paragrapher.open_graph(gp, use_pgfuse=True, pgfuse_block_size=512,
                               pgfuse_readahead=0, pgfuse_retries=3,
                               pgfuse_retry_backoff_s=0.0)
    try:
        inj = FaultyStorage()
        for k in (1, 3, 6, 9):
            inj.fail_at[k] = OSError(errno.EIO, "flaky OST")
        inj.install_graph(g)
        engine = NeighborQueryEngine(
            g, decode="host",
            hotset=HotSetCache(budget_bytes=1 << 16, min_degree=1,
                               place="host", prefetch_min_hits=2,
                               prefetch_batch=4))
        ids = np.arange(24, dtype=np.int64)
        for _ in range(3):                 # repeat -> hits + prefetch
            for v, nbrs in zip(ids, engine.neighbors_batch(ids)):
                assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
        hs = engine.hotset.stats
        assert hs.conserved and hs.hits > 0
        assert g.pgfuse_stats().retried_reads >= 1
        # every resident run equals the reference bytes
        for v in engine.hotset.resident_vertices.tolist():
            got = engine.hotset.lookup(np.array([v]))[v]
            assert np.array_equal(got, csr.neighbors_of(int(v)))
    finally:
        g.close()


def test_sharded_per_shard_hotsets(graph_path):
    """``hotset_bytes=`` gives every shard replica its own tier;
    per-shard stats fold into fleet totals and answers stay identical
    to the CSR."""
    gp, csr = graph_path
    with ShardedQueryService(gp, n_shards=2, hotset_bytes=1 << 16,
                             open_kwargs=dict(pgfuse_block_size=512,
                                              pgfuse_readahead=0)) as svc:
        ids = np.arange(0, csr.n_vertices, 7, dtype=np.int64)
        for _ in range(2):
            for v, nbrs in zip(ids, svc.neighbors_batch(ids)):
                assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
        hs = svc.hotset_stats()
        assert hs is not None and hs.conserved
        per = [s for s in svc.per_shard_hotset_stats() if s is not None]
        assert len(per) == 2
        assert sum(s.lookups for s in per) == hs.lookups
    # without the flag the fleet has no tier to report
    with ShardedQueryService(gp, n_shards=2,
                             open_kwargs=dict(pgfuse_block_size=512,
                                              pgfuse_readahead=0)) as svc:
        assert svc.hotset_stats() is None
        assert all(s is None for s in svc.per_shard_hotset_stats())
