"""Transformer family: decode==forward, backend equivalences, MoE, params."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # heavy model sweeps; excluded from tier-1

from repro.configs import get_arch
from repro.models.transformer import (TransformerConfig, decode_step, forward,
                                      init_params, loss_fn, moe_ffn, prefill)

CFG = TransformerConfig(n_layers=2, d_model=64, n_heads=6, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=256, dtype=jnp.float32,
                        attn_impl="chunked", attn_chunk=32, qkv_bias=True,
                        rope_pct=0.5)

MOE_CFG = TransformerConfig(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=0,
    vocab=256, dtype=jnp.float32, moe=True, n_experts=6, n_experts_padded=8,
    top_k=2, moe_d_ff=32, n_shared_experts=2, shared_d_ff=64,
    shared_expert_gate=True, capacity_factor=8.0)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.key(1), (2, 65), 0, 256)


@pytest.mark.parametrize("cfg", [CFG, MOE_CFG], ids=["dense", "moe"])
def test_train_step_finite(cfg, toks):
    p = init_params(cfg, jax.random.key(0))
    loss, grads = jax.value_and_grad(loss_fn)(p, toks[:, :-1], toks[:, 1:], cfg)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g))) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("cfg", [CFG, MOE_CFG], ids=["dense", "moe"])
def test_prefill_decode_matches_forward(cfg, toks):
    p = init_params(cfg, jax.random.key(0))
    full, _, _ = forward(p, toks, cfg)
    last, cache = prefill(p, toks[:, :-1], cfg, max_len=80)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    step, cache = decode_step(p, toks[:, -1:], cache, cfg)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    assert int(cache["len"]) == toks.shape[1]


def test_attention_backends_agree(toks):
    p = init_params(CFG, jax.random.key(0))
    outs = []
    for impl, unroll in [("dense", False), ("chunked", False), ("chunked", True)]:
        cfg = dataclasses.replace(CFG, attn_impl=impl, attn_unroll=unroll)
        f, _, _ = forward(p, toks, cfg)
        outs.append(np.asarray(f))
    np.testing.assert_allclose(outs[1], outs[0], rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(outs[2], outs[0], rtol=3e-4, atol=3e-4)


def test_unrolled_layers_match_scan(toks):
    p = init_params(CFG, jax.random.key(0))
    f0, _, _ = forward(p, toks, CFG)
    cfg_u = dataclasses.replace(CFG, unroll_layers=True, attn_unroll=True)
    f1, _, _ = forward(p, toks, cfg_u)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f0),
                               rtol=3e-4, atol=3e-4)


def test_kv_expand_equivalent(toks):
    p = init_params(CFG, jax.random.key(0))
    f0, _, _ = forward(p, toks, CFG)
    f1, _, _ = forward(p, toks, dataclasses.replace(CFG, attn_kv_expand=True))
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f0),
                               rtol=3e-4, atol=3e-4)


def test_moe_no_drop_exact_routing():
    """With no_drop, every token's top-k contribution must be present:
    compare against a dense loop over experts."""
    cfg = MOE_CFG
    p = init_params(cfg, jax.random.key(0))
    lp = jax.tree.map(lambda a: a[0], p["layers"])
    x = jax.random.normal(jax.random.key(2), (10, cfg.d_model))
    out, _ = moe_ffn(x, lp, cfg, no_drop=True)

    logits = x @ lp["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ lp["we_gate"][e]) * (x @ lp["we_up"][e])
        y_e = h @ lp["we_down"][e]
        w = jnp.where(idx == e, gates, 0).sum(-1)
        ref = ref + w[:, None] * y_e
    shared = jax.nn.silu(x @ lp["ws_gate"]) * (x @ lp["ws_up"]) @ lp["ws_down"]
    shared = shared * jax.nn.sigmoid(x @ lp["shared_gate"])
    ref = ref + shared
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_load_balance_loss_positive():
    cfg = MOE_CFG
    p = init_params(cfg, jax.random.key(0))
    lp = jax.tree.map(lambda a: a[0], p["layers"])
    x = jax.random.normal(jax.random.key(3), (64, cfg.d_model))
    _, lb = moe_ffn(x, lp, cfg)
    assert float(lb) > 0


@pytest.mark.parametrize("arch_id,expected_m", [
    ("smollm-360m", 360), ("qwen2-1.5b", 1540), ("stablelm-1.6b", 1640),
    ("qwen2-moe-a2.7b", 14300), ("dbrx-132b", 132_000),
])
def test_param_counts_match_public_figures(arch_id, expected_m):
    cfg = get_arch(arch_id).make_config()
    n = cfg.n_params() / 1e6
    assert abs(n - expected_m) / expected_m < 0.12, f"{arch_id}: {n:.0f}M"


def test_active_params_moe():
    cfg = get_arch("qwen2-moe-a2.7b").make_config()
    active = cfg.n_active_params() / 1e9
    assert 2.0 < active < 3.5  # "A2.7B"
