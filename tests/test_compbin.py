"""CompBin (paper §IV): eq. (1) decode, roundtrips, sizes, random access."""

import io

import numpy as np
import pytest

from repro.core import compbin
from repro.core.csr import CSR, csr_from_edges
from tests._prop import prop


def test_bytes_per_vertex_boundaries():
    # b = ceil(log2|V| / 8)
    assert compbin.bytes_per_vertex(2) == 1
    assert compbin.bytes_per_vertex(256) == 1
    assert compbin.bytes_per_vertex(257) == 2
    assert compbin.bytes_per_vertex(2**16) == 2
    assert compbin.bytes_per_vertex(2**16 + 1) == 3
    assert compbin.bytes_per_vertex(2**24) == 3
    # paper: for 2^24 <= |V| < 2^32 CompBin == binary CSR (4 bytes)
    assert compbin.bytes_per_vertex(2**24 + 1) == 4
    assert compbin.bytes_per_vertex(2**32 - 1) == 4
    assert compbin.bytes_per_vertex(2**32 + 1) == 5


def test_eq1_manual():
    # decode of [0x01, 0x02, 0x03] with b=3 is 0x030201 (eq. 1, little-endian)
    packed = np.array([0x01, 0x02, 0x03], dtype=np.uint8)
    out = compbin.decode_ids(packed, 3)
    assert out[0] == 0x01 + (0x02 << 8) + (0x03 << 16)


@prop()
def test_encode_decode_roundtrip(draw):
    b = draw.int(1, 8)
    n = draw.int(0, 2000)
    hi = min(2 ** (8 * b) - 1, 2**63 - 1)
    ids = draw.rng.integers(0, hi + 1 if hi < 2**63 else hi, n,
                            dtype=np.uint64)
    packed = compbin.encode_ids(ids, b)
    assert packed.shape == (n * b,)
    out = compbin.decode_ids(packed, b)
    np.testing.assert_array_equal(out.astype(np.uint64), ids)


@prop(10)
def test_file_roundtrip_and_random_access(draw):
    nv = draw.int(2, 5000)
    ne = draw.int(0, 20000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne), draw.ints(0, nv - 1, ne), nv)
    blob = compbin.roundtrip_bytes(csr)
    assert len(blob) == compbin.compbin_nbytes(nv, csr.n_edges)
    f = compbin.CompBinFile(io.BytesIO(blob))
    assert (f.n_vertices, f.n_edges) == (nv, csr.n_edges)
    got = f.read_full()
    assert got == csr
    # O(1) random access to any adjacency list (the paper's key property)
    for v in draw.ints(0, nv - 1, 5):
        np.testing.assert_array_equal(
            f.neighbors_of(int(v)).astype(np.int64),
            csr.neighbors_of(int(v)).astype(np.int64))
    # partition read
    v0 = draw.int(0, nv - 1)
    v1 = draw.int(v0, nv)
    offs, nbrs = f.read_partition(v0, v1)
    assert offs[0] == 0 and offs[-1] == len(nbrs)
    exp = csr.neighbors[csr.offsets[v0]:csr.offsets[v1]]
    np.testing.assert_array_equal(nbrs.astype(np.int64), exp.astype(np.int64))


def test_size_formula_matches_table1_layout():
    # CompBin size = header + 8(|V|+1) + b|E| — Table I's accounting
    nv, ne = 1000, 5000
    csr = csr_from_edges(np.random.default_rng(0).integers(0, nv, ne),
                         np.random.default_rng(1).integers(0, nv, ne), nv)
    blob = compbin.roundtrip_bytes(csr)
    b = compbin.bytes_per_vertex(nv)
    assert len(blob) == compbin.HEADER_SIZE + 8 * (nv + 1) + b * csr.n_edges


def test_id_overflow_rejected():
    with pytest.raises(ValueError):
        compbin.encode_ids(np.array([256], np.uint64), 1)


def test_bad_magic_rejected():
    with pytest.raises(ValueError):
        compbin.read_header(io.BytesIO(b"NOPE" + b"\x00" * 20))
