"""CompBin (paper §IV): eq. (1) decode, roundtrips, sizes, random access."""

import io

import numpy as np
import pytest

from repro.core import compbin
from repro.core.csr import CSR, csr_from_edges
from tests._prop import prop


def test_bytes_per_vertex_boundaries():
    # b = ceil(log2|V| / 8)
    assert compbin.bytes_per_vertex(2) == 1
    assert compbin.bytes_per_vertex(256) == 1
    assert compbin.bytes_per_vertex(257) == 2
    assert compbin.bytes_per_vertex(2**16) == 2
    assert compbin.bytes_per_vertex(2**16 + 1) == 3
    assert compbin.bytes_per_vertex(2**24) == 3
    # paper: for 2^24 <= |V| < 2^32 CompBin == binary CSR (4 bytes)
    assert compbin.bytes_per_vertex(2**24 + 1) == 4
    assert compbin.bytes_per_vertex(2**32 - 1) == 4
    assert compbin.bytes_per_vertex(2**32 + 1) == 5


def test_eq1_manual():
    # decode of [0x01, 0x02, 0x03] with b=3 is 0x030201 (eq. 1, little-endian)
    packed = np.array([0x01, 0x02, 0x03], dtype=np.uint8)
    out = compbin.decode_ids(packed, 3)
    assert out[0] == 0x01 + (0x02 << 8) + (0x03 << 16)


@prop()
def test_encode_decode_roundtrip(draw):
    b = draw.int(1, 8)
    n = draw.int(0, 2000)
    hi = min(2 ** (8 * b) - 1, 2**63 - 1)
    ids = draw.rng.integers(0, hi + 1 if hi < 2**63 else hi, n,
                            dtype=np.uint64)
    packed = compbin.encode_ids(ids, b)
    assert packed.shape == (n * b,)
    out = compbin.decode_ids(packed, b)
    np.testing.assert_array_equal(out.astype(np.uint64), ids)


@prop(10)
def test_file_roundtrip_and_random_access(draw):
    nv = draw.int(2, 5000)
    ne = draw.int(0, 20000)
    csr = csr_from_edges(draw.ints(0, nv - 1, ne), draw.ints(0, nv - 1, ne), nv)
    blob = compbin.roundtrip_bytes(csr)
    assert len(blob) == compbin.compbin_nbytes(nv, csr.n_edges)
    f = compbin.CompBinFile(io.BytesIO(blob))
    assert (f.n_vertices, f.n_edges) == (nv, csr.n_edges)
    got = f.read_full()
    assert got == csr
    # O(1) random access to any adjacency list (the paper's key property)
    for v in draw.ints(0, nv - 1, 5):
        np.testing.assert_array_equal(
            f.neighbors_of(int(v)).astype(np.int64),
            csr.neighbors_of(int(v)).astype(np.int64))
    # partition read
    v0 = draw.int(0, nv - 1)
    v1 = draw.int(v0, nv)
    offs, nbrs = f.read_partition(v0, v1)
    assert offs[0] == 0 and offs[-1] == len(nbrs)
    exp = csr.neighbors[csr.offsets[v0]:csr.offsets[v1]]
    np.testing.assert_array_equal(nbrs.astype(np.int64), exp.astype(np.int64))


def test_size_formula_matches_table1_layout():
    # CompBin size = header + 8(|V|+1) + b|E| — Table I's accounting
    nv, ne = 1000, 5000
    csr = csr_from_edges(np.random.default_rng(0).integers(0, nv, ne),
                         np.random.default_rng(1).integers(0, nv, ne), nv)
    blob = compbin.roundtrip_bytes(csr)
    b = compbin.bytes_per_vertex(nv)
    assert len(blob) == compbin.HEADER_SIZE + 8 * (nv + 1) + b * csr.n_edges


def test_id_overflow_rejected():
    with pytest.raises(ValueError):
        compbin.encode_ids(np.array([256], np.uint64), 1)


def test_bad_magic_rejected():
    with pytest.raises(ValueError):
        compbin.read_header(io.BytesIO(b"NOPE" + b"\x00" * 20))


def test_bytes_per_vertex_every_byte_fence():
    """Regression for the float-log2 fencepost: b is exact at EVERY
    2**(8k) +- 1 boundary.  The max id is |V| - 1, so |V| = 2**(8k) + 1
    is the first size whose max id needs k+1 bytes — the old
    ``ceil(log2(|V|)/8)`` got 2**56 + 1 wrong (log2 rounds to exactly
    56.0 -> b=7) and write_compbin then crashed in encode_ids."""
    for k in range(1, 8):
        fence = 1 << (8 * k)
        assert compbin.bytes_per_vertex(fence - 1) == k
        assert compbin.bytes_per_vertex(fence) == k      # max id fence-1
        assert compbin.bytes_per_vertex(fence + 1) == k + 1
    assert compbin.bytes_per_vertex(2**56 + 1) == 8   # the broken case
    assert compbin.bytes_per_vertex(2**64) == 8       # capped
    # the header's promise must hold: the max id always encodes
    for k in range(1, 8):
        nv = (1 << (8 * k)) + 1
        b = compbin.bytes_per_vertex(nv)
        packed = compbin.encode_ids(np.array([nv - 1], np.uint64), b)
        assert int(compbin.decode_ids(packed, b)[0]) == nv - 1
    with pytest.raises(ValueError):
        compbin.bytes_per_vertex(-1)


@prop()
def test_encode_ids_byte_exact_vs_pure_python(draw):
    """Regression for the platform-endian ``view(np.uint8)``: the wire
    format is little-endian BY DEFINITION (eq. (1) shifts the low byte
    first), so the vectorized encoder must match a pure-Python
    ``int.to_bytes(b, "little")`` packer byte for byte."""
    b = draw.int(1, 8)
    n = draw.int(0, 200)
    hi = min(2 ** (8 * b) - 1, 2**63 - 1)
    ids = draw.rng.integers(0, hi + 1 if hi < 2**63 else hi, n,
                            dtype=np.uint64)
    got = compbin.encode_ids(ids, b).tobytes()
    want = b"".join(int(i).to_bytes(b, "little") for i in ids)
    assert got == want


def _corrupt_graph_blobs():
    from repro.core import codec
    csr = csr_from_edges(np.array([0, 1, 2, 2]), np.array([1, 2, 0, 3]), 5)
    return {
        "compbin": (compbin.roundtrip_bytes(csr), compbin.read_header,
                    compbin.CompBinFile, compbin.HEADER_SIZE),
        "logcsr": (codec.logcsr_roundtrip_bytes(csr),
                   codec.read_logcsr_header, codec.LogCSRFile,
                   codec.LOGCSR_HEADER_SIZE),
    }


@pytest.mark.parametrize("fmt", ["compbin", "logcsr"])
def test_corrupt_header_fuzz_byte_flips(fmt):
    """Flip every bit of every header byte: the reader must either
    reject the file with a clean ValueError/IOError at open time or
    parse a still-consistent header — never leak a ZeroDivisionError
    (b=0), an index error, or a garbage decode from impossible sizes."""
    blob, read_header, open_file, header_size = _corrupt_graph_blobs()[fmt]
    for pos in range(header_size):
        for bit in range(8):
            bad = bytearray(blob)
            bad[pos] ^= 1 << bit
            bad = bytes(bad)
            try:
                f = open_file(io.BytesIO(bad))
            except (ValueError, IOError):
                continue   # clean rejection is the contract
            try:
                # accepted: the header must be self-consistent enough
                # that full decode works or fails cleanly
                f.read_full()
            except (ValueError, IOError):
                pass
            finally:
                f.close()


@pytest.mark.parametrize("fmt", ["compbin", "logcsr"])
def test_header_validation_specific_fields(fmt):
    """The specific corruptions the satellites name: b=0, b>8, and a
    total_size promising more bytes than the file holds."""
    import struct as _struct

    from repro.core import codec
    blob, read_header, open_file, header_size = _corrupt_graph_blobs()[fmt]
    b_off = 6  # both layouts: magic(4) + version u16, then b as u8
    for bad_b in (0, 9, 255):
        bad = bytearray(blob)
        bad[b_off] = bad_b
        with pytest.raises((IOError, ValueError), match="b="):
            read_header(io.BytesIO(bytes(bad)))
    # truncation: drop the last payload byte -> total_size cross-check
    with pytest.raises((IOError, ValueError), match="truncat"):
        open_file(io.BytesIO(blob[:-1]))
    # inflate n_edges so the header promises more than the file holds
    ne_off = {"compbin": 16, "logcsr": 20}[fmt]
    bad = bytearray(blob)
    ne = int.from_bytes(bad[ne_off:ne_off + 8], "little")
    bad[ne_off:ne_off + 8] = (ne + 10**6).to_bytes(8, "little")
    with pytest.raises((IOError, ValueError)):
        read_header(io.BytesIO(bytes(bad)))


@pytest.mark.parametrize("fmt", ["compbin", "logcsr"])
def test_concurrent_readers_no_seek_interleave(fmt, tmp_path):
    """Regression for the shared seek/read race: concurrent
    neighbors_of/read_edge_range through ONE reader must never hand one
    thread the bytes of another thread's seek.  Before the positional-
    read fix this failed within a handful of iterations."""
    import threading

    from repro.core import codec
    rng = np.random.default_rng(7)
    nv, ne = 500, 6000
    csr = csr_from_edges(rng.integers(0, nv, ne), rng.integers(0, nv, ne),
                         nv)
    path = str(tmp_path / f"g.{fmt}")
    write = {"compbin": compbin.write_compbin,
             "logcsr": codec.write_logcsr}[fmt]
    open_file = {"compbin": compbin.CompBinFile,
                 "logcsr": codec.LogCSRFile}[fmt]
    write(path, csr)
    f = open_file(path)
    errors = []
    barrier = threading.Barrier(4)

    def worker(seed):
        r = np.random.default_rng(seed)
        barrier.wait()
        try:
            for _ in range(200):
                v = int(r.integers(0, nv))
                got = f.neighbors_of(v)
                want = csr.neighbors[csr.offsets[v]:csr.offsets[v + 1]]
                if not np.array_equal(got.astype(np.int64),
                                      want.astype(np.int64)):
                    errors.append((v, got, want))
                    return
        except BaseException as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    f.close()
    assert not errors, f"interleaved reads corrupted answers: {errors[:1]}"
