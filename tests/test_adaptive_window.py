"""The adaptive micro-batch window, in isolation and inside the engine.

The state machine (repro.query.window.AdaptiveWindow) runs on an
injectable clock with synthetic arrival schedules, so every close
decision — early on plateau, instant on full, late on timeout — is
pinned deterministically.  The engine-level tests then assert the
QueryStats invariant: every executed batch records exactly one close
reason and sum(close_reasons.values()) == batches.
"""

import numpy as np
import pytest

from repro.core import paragrapher
from repro.graph import rmat
from repro.query import CLOSE_REASONS, AdaptiveWindow, NeighborQueryEngine

RANDOM_KW = dict(use_pgfuse=True, pgfuse_block_size=1 << 12,
                 pgfuse_readahead=0, pgfuse_eviction="clock")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# the state machine in isolation
# ---------------------------------------------------------------------------

def test_overlapping_arrivals_keep_window_open_until_timeout():
    """Arrivals that keep raising the dedup ratio never close early; the
    window runs its full span and times out."""
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=1000, clock=clk)
    hot = np.arange(10)
    for k in range(6):           # the same hot set over and over
        clk.t += 0.1
        assert w.arrival(hot) is None, k
    assert w.dedup_ratio == 6.0
    assert not w.timed_out() and 0 < w.remaining() < 1.0
    clk.t = w._t_open + 1.0
    assert w.timed_out() and w.remaining() == 0.0


def test_disjoint_arrivals_close_on_plateau():
    """Arrivals sharing nothing stop improving the ratio: after
    ``patience`` consecutive stale arrivals the window says plateau."""
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=1000, patience=2, clock=clk)
    assert w.arrival(np.arange(0, 10)) is None      # opens the window
    assert w.arrival(np.arange(10, 20)) is None     # stale #1
    assert w.arrival(np.arange(20, 30)) == "plateau"  # stale #2: close
    assert w.is_open and w.pending_ids == 30


def test_recovering_overlap_resets_patience():
    """One overlapping arrival in between clears the stale counter —
    plateau needs CONSECUTIVE non-improving arrivals."""
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=1000, patience=2, clock=clk)
    assert w.arrival(np.arange(0, 10)) is None
    assert w.arrival(np.arange(10, 20)) is None     # stale #1
    assert w.arrival(np.arange(0, 10)) is None      # overlap: ratio jumps
    assert w.arrival(np.arange(20, 30)) is None     # stale #1 again
    assert w.arrival(np.arange(30, 40)) == "plateau"


def test_half_overlapping_arrivals_stay_open():
    """Arrivals that each half-duplicate the pending set must keep the
    window open indefinitely (waiting still saves half of every
    arrival's fetches) — the plateau signal is the MARGINAL overlap per
    arrival, not the delta of the converging cumulative ratio."""
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=10 ** 6, patience=2,
                       clock=clk)
    hot = np.arange(8)
    for k in range(30):
        ids = np.concatenate([hot, np.arange(1000 + 8 * k, 1008 + 8 * k)])
        assert w.arrival(ids) is None, k   # overlap 0.5 every time


def test_full_fires_immediately_and_wins_over_plateau():
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=32, clock=clk)
    assert w.arrival(np.arange(16)) is None
    assert w.arrival(np.arange(100, 116)) == "full"   # 32 pending ids


def test_fixed_window_never_plateaus():
    """adaptive=False degrades to PR 4's fixed window: only full/timeout."""
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=1000, adaptive=False,
                       clock=clk)
    for k in range(20):
        assert w.arrival(np.arange(k * 10, k * 10 + 10)) is None, k


def test_reset_forgets_everything():
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=1000, patience=1, clock=clk)
    w.arrival(np.arange(10))
    w.arrival(np.arange(10, 20))
    w.reset()
    assert not w.is_open and w.pending_ids == 0 and w.dedup_ratio == 0.0
    assert w.remaining() == 1.0   # a closed window has its full span left
    assert w.arrival(np.arange(10)) is None  # fresh history, no stale carry


def test_empty_arrivals_never_divide_by_zero():
    clk = FakeClock()
    w = AdaptiveWindow(window_s=1.0, max_batch=8, clock=clk)
    assert w.arrival(np.zeros(0, np.int64)) is None
    assert w.dedup_ratio == 0.0
    assert w.arrival(np.zeros(0, np.int64)) is None


def test_window_validates_params():
    with pytest.raises(ValueError, match="window_s"):
        AdaptiveWindow(window_s=-1.0, max_batch=8)
    with pytest.raises(ValueError, match="patience"):
        AdaptiveWindow(window_s=1.0, max_batch=8, patience=0)


# ---------------------------------------------------------------------------
# inside the engine: close reasons + the QueryStats invariant
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph_on_disk(tmp_path_factory):
    d = tmp_path_factory.mktemp("aw")
    csr = rmat(9, 6, seed=3)
    gp = str(d / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp, csr


def _assert_invariant(stats) -> None:
    assert set(stats.close_reasons) <= set(CLOSE_REASONS)
    assert sum(stats.close_reasons.values()) == stats.batches


def test_engine_closes_early_on_disjoint_traffic(graph_on_disk):
    """Disjoint concurrent requests plateau the window: the engine
    executes them WITHOUT waiting out a 30 s span (the test would time
    out otherwise), and records the plateau."""
    gp, csr = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        with NeighborQueryEngine(g, window_s=30.0, window_patience=2) \
                as engine:
            futs = [engine.submit(np.arange(i * 16, i * 16 + 16))
                    for i in range(4)]
            for f in futs:
                f.result(timeout=10)   # resolved long before 30 s
            st = engine.stats
            assert st.close_reasons.get("plateau", 0) >= 1
            _assert_invariant(st)


def test_engine_records_full_and_direct_and_flush(graph_on_disk):
    gp, csr = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        engine = NeighborQueryEngine(g, window_s=30.0, max_batch=32)
        engine.neighbors_batch([1, 2, 3])           # sync: "direct"
        fut = engine.submit(np.arange(40))          # >= max_batch: "full"
        fut.result(timeout=10)
        slow = engine.submit([5])                   # rides a manual flush
        engine.flush()
        slow.result(timeout=10)
        st = engine.stats
        assert st.close_reasons.get("direct") == 1
        assert st.close_reasons.get("full") == 1
        assert st.close_reasons.get("flush") == 1
        _assert_invariant(st)
        engine.close()


def test_engine_records_timeout(graph_on_disk):
    gp, csr = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        with NeighborQueryEngine(g, window_s=0.01) as engine:
            fut = engine.submit([1, 2])  # alone: nothing closes it early
            fut.result(timeout=10)
            st = engine.stats
            assert st.close_reasons.get("timeout") == 1
            _assert_invariant(st)


def test_invariant_survives_reset_and_mixed_traffic(graph_on_disk):
    gp, csr = graph_on_disk
    rng = np.random.default_rng(0)
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        with NeighborQueryEngine(g, window_s=0.005) as engine:
            for _ in range(3):
                engine.neighbors_batch(rng.integers(0, csr.n_vertices, 8))
            futs = [engine.submit(rng.integers(0, csr.n_vertices, 16))
                    for _ in range(8)]
            for f in futs:
                f.result(timeout=10)
            _assert_invariant(engine.stats)
            snap = engine.stats.reset()
            _assert_invariant(snap)              # snapshot keeps the ledger
            assert engine.stats.close_reasons == {} \
                and engine.stats.batches == 0    # zeroed together
            engine.neighbors_batch([0])
            _assert_invariant(engine.stats)
