"""Property-based format round-trips (CSR <-> CompBin <-> WebGraph) and
host/device decoder equivalence across the byte-width fences of
``bytes_per_vertex`` (2^8 / 2^16 / 2^24) — the places where a decoder that
"works on my graph" quietly corrupts IDs."""

import io

import numpy as np
import pytest

from repro.core import compbin, webgraph
from repro.core.csr import CSR
from tests._prop import Draw, prop


@prop(20)
def test_csr_compbin_roundtrip(draw: Draw):
    csr = draw.csr()
    blob = compbin.roundtrip_bytes(csr)
    out = compbin.read_compbin(io.BytesIO(blob))
    assert out == csr
    # header geometry must agree with the actual blob
    assert len(blob) == compbin.compbin_nbytes(csr.n_vertices, csr.n_edges)


@prop(15)
def test_csr_webgraph_roundtrip(draw: Draw):
    csr = draw.csr(max_edges=1024)
    blob = io.BytesIO()
    webgraph.write_webgraph(blob, csr)
    out = webgraph.read_webgraph(io.BytesIO(blob.getvalue()))
    assert out == csr


@prop(10)
def test_compbin_webgraph_compbin_chain(draw: Draw):
    """CSR -> CompBin -> CSR -> WebGraph -> CSR -> CompBin: no format in
    the chain may perturb the graph."""
    csr = draw.csr(max_edges=512)
    cb = compbin.read_compbin(io.BytesIO(compbin.roundtrip_bytes(csr)))
    wg_blob = io.BytesIO()
    webgraph.write_webgraph(wg_blob, cb)
    wg = webgraph.read_webgraph(io.BytesIO(wg_blob.getvalue()))
    cb2 = compbin.read_compbin(io.BytesIO(compbin.roundtrip_bytes(wg)))
    assert cb2 == csr


def test_bytes_per_vertex_fences():
    """b jumps exactly at 2^8, 2^16, 2^24 (paper §IV: b = ceil(log2|V|/8))."""
    assert compbin.bytes_per_vertex(0) == 1
    assert compbin.bytes_per_vertex(1) == 1
    for p, b_below in ((8, 1), (16, 2), (24, 3), (32, 4)):
        n = 1 << p
        assert compbin.bytes_per_vertex(n) == b_below
        assert compbin.bytes_per_vertex(n + 1) == b_below + 1


@prop(15)
def test_encode_decode_ids_all_widths(draw: Draw):
    """encode_ids/decode_ids inverse for every b in [1,8], IDs hugging the
    width fences (0, 1, 2^(8b)-1, random)."""
    b = draw.int(1, 8)
    hi = (1 << (8 * b)) - 1
    n = draw.int(0, 2048)
    ids = draw.rng.integers(0, hi, n, dtype=np.uint64) if hi < 2**63 else \
        draw.rng.integers(0, 2**63 - 1, n, dtype=np.uint64)
    if n >= 3:
        ids[0], ids[1], ids[2] = 0, hi, max(0, hi - 1)
    packed = compbin.encode_ids(ids, b)
    assert packed.size == n * b
    out = compbin.decode_ids(packed, b)
    np.testing.assert_array_equal(out.astype(np.uint64), ids)


@prop(12)
def test_device_kernel_matches_decode_ids(draw: Draw):
    """Pallas compbin_decode == host decode_ids for b in [1,8].

    b in [1,4] runs the kernel directly; b in [5,8] packs IDs < 2^31 (the
    int32-lane ceiling, enforced by the dry-run) whose high bytes are
    zero, decoded via the kernel's wide-format path."""
    from repro.kernels.compbin_decode import compbin_decode

    b = draw.int(1, 8)
    n = draw.int(1, 5000)
    hi = min(1 << (8 * b), 1 << 31)
    ids = draw.rng.integers(0, hi, n, dtype=np.uint64)
    packed = compbin.encode_ids(ids, b)
    host = compbin.decode_ids(packed, b)
    dev = np.asarray(compbin_decode(packed, b, interpret=True))
    np.testing.assert_array_equal(dev.astype(np.uint64), host.astype(np.uint64))
    np.testing.assert_array_equal(dev.astype(np.uint64), ids)


@prop(8)
def test_compbin_partition_reads_match_full(draw: Draw):
    """Random partitions of a CompBin file agree with the full read."""
    csr = draw.csr(max_edges=2048)
    f = io.BytesIO(compbin.roundtrip_bytes(csr))
    rdr = compbin.CompBinFile(f)
    full = rdr.read_full()
    assert full == csr
    n = csr.n_vertices
    for _ in range(5):
        v0 = draw.int(0, n)
        v1 = draw.int(v0, n)
        offs, nbrs = rdr.read_partition(v0, v1)
        e0, e1 = int(csr.offsets[v0]), int(csr.offsets[v1])
        np.testing.assert_array_equal(
            offs, csr.offsets[v0:v1 + 1] - csr.offsets[v0])
        np.testing.assert_array_equal(nbrs.astype(np.int64),
                                      csr.neighbors[e0:e1].astype(np.int64))
