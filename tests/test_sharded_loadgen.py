"""Deterministic overload comparison: sharded vs single-process serving.

The scale-out claim the sharded service makes — N shards carry N times
the offered load at the same SLO — becomes a CI-gateable number on the
loadgen's virtual clock: the SAME zipf client fleet is replayed against
a 1-shard and a 2-shard deployment (the 2-shard gate re-sized for the
doubled aggregate service rate, exactly as
``launch.serve.make_traversal_server(shards=2)`` sizes it), and the
2-shard arm must shed strictly less while BOTH arms keep admitted-p99
within the SLO.  Same seed => bit-identical reports, sharded arm
included.
"""

import numpy as np

from repro.core import paragrapher
from repro.core.policy import choose_admission
from repro.graph import rmat
from repro.query import (LoadGenerator, ShardedQueryService,
                         TraversalRequest, TraversalService)

SLO_S = 0.02
EDGE_BUDGET = 8192
RATE = 5.0e6          # one shard's service_edges_per_s
SERVERS = 1           # executors per shard

OPEN_KW = dict(pgfuse_block_size=1 << 12, pgfuse_readahead=0,
               pgfuse_eviction="clock")


def _graph(tmp_path):
    csr = rmat(9, 6, seed=3)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp


def _make_request(rng: np.random.Generator, client_id: int):
    n = 512
    seeds = np.minimum(rng.zipf(1.8, size=3) - 1, n - 1)
    return TraversalRequest("khop", seeds, k=2, max_edges=EDGE_BUDGET)


def _run(graph_file, *, shards, n_clients, seed=7, horizon_s=0.2):
    """One virtual-clock overload run against an n-shard deployment.

    The admission plan and the loadgen's executor count both scale by
    the shard count — the apples-to-apples deployment comparison: same
    clients, same traffic, N times the serving capacity.
    """
    svc = ShardedQueryService(graph_file, n_shards=shards,
                              open_kwargs=OPEN_KW)
    plan = choose_admission(SLO_S, edge_budget=EDGE_BUDGET,
                            service_edges_per_s=RATE * shards,
                            servers=SERVERS * shards)
    trav = TraversalService(svc, admission=plan)
    try:
        gen = LoadGenerator(trav, _make_request, n_clients=n_clients,
                            horizon_s=horizon_s, think_s=0.0,
                            backoff_s=0.01, servers=SERVERS * shards,
                            seed=seed)
        report = gen.run()
        return report, trav.stats.as_dict(), svc.router.as_dict()
    finally:
        trav.close(), svc.close()


def test_two_shards_shed_less_at_equal_offered_load(tmp_path):
    """48 clients against 1 vs 2 shards: the 2-shard gate admits twice
    the in-flight work, so the shed rate drops strictly — while BOTH
    arms keep admitted-p99 within the SLO (the gate never buys
    throughput with latency)."""
    gp = _graph(tmp_path)
    one, st1, _ = _run(gp, shards=1, n_clients=48, horizon_s=0.1)
    two, st2, rd2 = _run(gp, shards=2, n_clients=48, horizon_s=0.1)
    assert one.shed > 0                       # genuinely overloaded
    assert two.shed_rate < one.shed_rate
    assert two.completed > one.completed      # capacity, not accounting
    assert one.p99_s <= SLO_S and two.p99_s <= SLO_S
    # conservation on both services' own counters after the drain
    for st in (st1, st2):
        assert st["submitted"] == st["admitted"] + st["shed"]
        assert st["admitted"] == st["completed"] + st["failed"]
        assert st["inflight"] == 0
    # the 2-shard run really scattered: both shards answered traffic
    assert set(rd2["routed_by_shard"]) == {0, 1}
    assert all(v > 0 for v in rd2["routed_by_shard"].values())


def test_sharded_overload_run_is_bit_reproducible(tmp_path):
    """Same seed, same graph, same shard count => identical report,
    latencies included, and identical service + router counters — the
    scatter-gather layer adds no nondeterminism to the virtual day."""
    gp = _graph(tmp_path)
    a, sa, ra = _run(gp, shards=2, n_clients=8, seed=11, horizon_s=0.05)
    b, sb, rb = _run(gp, shards=2, n_clients=8, seed=11, horizon_s=0.05)
    assert a.as_dict() == b.as_dict()
    assert a.latencies_s == b.latencies_s
    assert sa == sb and ra == rb
    c, _, _ = _run(gp, shards=2, n_clients=8, seed=12, horizon_s=0.05)
    assert c.latencies_s != a.latencies_s


def test_loadgen_servers_override_validates():
    import pytest

    from repro.query import NeighborQueryEngine  # noqa: F401  (API)
    with pytest.raises(ValueError, match="servers"):
        LoadGenerator(object(), lambda rng, c: None, n_clients=1,
                      horizon_s=1.0, servers=0)
