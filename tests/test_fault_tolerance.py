"""Fault tolerance: failure-injected recovery, straggler detection."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault_tolerance import ResilientTrainer, StragglerMonitor


def _make_step():
    @jax.jit
    def step(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return {"w": w, "n": state["n"] + 1}, {"loss": jnp.mean((w - batch) ** 2)}
    return step


def test_resilient_trainer_recovers_from_injected_failure(tmp_path):
    state = {"w": jnp.zeros(4), "n": jnp.int32(0)}
    batches = itertools.repeat(jnp.ones(4))
    tr = ResilientTrainer(_make_step(), state, ckpt_dir=str(tmp_path),
                          ckpt_every=5, max_retries=2)
    seen = []
    final = tr.run(batches, n_steps=20, inject_failure_at=12,
                   on_metrics=lambda s, m: seen.append(s))
    # the run completed all 20 *effective* steps despite the failure
    assert int(final["n"]) == 20
    assert max(seen) == 20
    # steps 11..12 were re-run after restoring the step-10 checkpoint
    assert seen.count(11) == 2


def test_resilient_trainer_restart_from_latest(tmp_path):
    state = {"w": jnp.zeros(4), "n": jnp.int32(0)}
    step = _make_step()
    tr1 = ResilientTrainer(step, state, ckpt_dir=str(tmp_path), ckpt_every=5)
    tr1.run(itertools.repeat(jnp.ones(4)), n_steps=10)
    # simulate a NEW JOB (relaunch): trainer picks up at step 10
    tr2 = ResilientTrainer(step, state, ckpt_dir=str(tmp_path), ckpt_every=5)
    assert tr2.start_step == 10
    final = tr2.run(itertools.repeat(jnp.ones(4)), n_steps=15)
    assert int(final["n"]) == 15


def test_straggler_monitor_flags_slow_host():
    sm = StragglerMonitor(8, window=10, k=2.0, min_samples=3)
    rng = np.random.default_rng(0)
    for _ in range(10):
        t = rng.normal(1.0, 0.03, 8)
        t[5] = 2.8
        sm.record_step(t)
    assert sm.stragglers() == [5]
    assert sm.should_evict(5)
    assert not sm.should_evict(0)


def test_straggler_monitor_needs_evidence():
    sm = StragglerMonitor(4, min_samples=5)
    sm.record_step([1.0, 1.0, 1.0, 9.0])
    assert sm.stragglers() == []  # one sample is not evidence


def test_straggler_monitor_recovery():
    sm = StragglerMonitor(4, window=5, k=2.0, min_samples=3)
    for _ in range(5):
        sm.record_step([1.0, 1.0, 1.0, 5.0])
    assert sm.stragglers() == [3]
    for _ in range(5):  # host 3 recovers; window slides
        sm.record_step([1.0, 1.0, 1.0, 1.0])
    assert sm.stragglers() == []
