"""Span-tracing lockdown for the unified telemetry layer.

Three properties, each load-bearing for the observability contract:

* **event/stat conservation** — faults injected with
  ``tests/conftest.py``'s ``FaultyStorage`` must appear as span events
  whose counts equal the stats counters they shadow: ``"retry"`` vs
  ``PGFuseStats.retried_reads``, ``"reroute"`` vs
  ``RouterStats.reroutes``, ``"shed"`` vs ``TraversalStats.shed``, and
  ``"window_close"`` reason totals vs ``QueryStats.close_reasons``;
* **determinism** — two same-seed runs over the same request sequence
  under an injected virtual clock produce bit-identical span trees
  (``Span.as_dict()`` equality, ids and timestamps included);
* **attribution** — a sharded traversal under the SimStorage charged
  clock attributes >= 95% of each request's virtual time to the named
  tiers (storage + decode carry ALL charged time, so routing/gather
  machinery shows as exactly the zero self-time it costs in virtual
  seconds).
"""

import errno

import numpy as np
import pytest

from benchmarks.storage_sim import PROFILES, SimStorage
from repro.core import paragrapher
from repro.core.policy import AdmissionPlan
from repro.graph import rmat
from repro.obs import (NAMED_TIERS, Tracer, attribution, event_counts,
                       render_report, verify_span_tree,
                       window_close_counts)
from repro.query import (NeighborQueryEngine, ShardedQueryService,
                         TraversalRequest, TraversalService, TraversalShed,
                         close_reason_counts)
from tests.conftest import FaultyStorage

BLOCK = 512
OPEN_KW = dict(pgfuse_block_size=BLOCK, pgfuse_readahead=0,
               pgfuse_eviction="clock", pgfuse_retry_backoff_s=0.0)


@pytest.fixture
def graph_file(tmp_path):
    csr = rmat(9, 7, seed=42)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp


def _service(gp, tracer, **kw):
    g = paragrapher.open_graph(gp, use_pgfuse=True, **dict(OPEN_KW, **kw))
    engine = NeighborQueryEngine(g, decode="host", tracer=tracer)
    return TraversalService(engine), engine, g


class _Tick:
    """Deterministic injectable clock: advances a fixed step per read,
    so span timestamps depend only on the call sequence."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 1e-6
        return self.t


# -- structure ------------------------------------------------------------

def test_request_trace_structure_and_tiers(graph_file):
    """One traversal request yields ONE root span (tier "request")
    whose subtree passes structural validation and touches the gather,
    storage and decode tiers — the span tree IS the request's path
    through the stack."""
    tracer = Tracer()
    svc, engine, g = _service(graph_file, tracer)
    try:
        res = svc.khop([3, 71], 3)
        assert res.vertices.size > 0
    finally:
        svc.close(), engine.close(), g.close()
    traces = tracer.drain()
    assert len(traces) == 1 and tracer.dropped_traces == 0
    root = traces[0]
    assert root.tier == "request" and root.attrs["kind"] == "khop"
    assert root.attrs["hops"] == res.hops
    assert verify_span_tree(root) == []
    tiers = {s.tier for s in root.iter_spans()}
    assert {"request", "gather", "storage", "decode"} <= tiers
    # a second drain is empty: exposition consumed the retained traces
    assert tracer.drain() == []


def test_null_tracer_default_records_nothing(graph_file):
    """The default (no tracer) serving path runs on NULL_TRACER: same
    answers, no retained traces, no per-request allocations to drain."""
    tracer = Tracer()
    svc, engine, g = _service(graph_file, tracer)
    try:
        ref = svc.khop([3, 71], 3)
    finally:
        svc.close(), engine.close(), g.close()
    svc, engine, g = _service(graph_file, None)
    try:
        res = svc.khop([3, 71], 3)
        assert res.vertices.tolist() == ref.vertices.tolist()
        assert engine._tracer.drain() == []
        assert engine._tracer.traces == ()
    finally:
        svc.close(), engine.close(), g.close()


def test_sampling_keeps_every_nth_root_and_bounds_retention(graph_file):
    """``sample_every=3`` records roots 0, 3, 6, ... and suppresses the
    whole subtree in between; ``max_traces`` bounds retention with
    ``dropped_traces`` counting the overflow."""
    tracer = Tracer(sample_every=3, max_traces=2)
    svc, engine, g = _service(graph_file, tracer)
    try:
        for i in range(9):
            svc.khop([i, i + 40], 2)
    finally:
        svc.close(), engine.close(), g.close()
    assert len(tracer.traces) == 2 and tracer.dropped_traces == 1
    # orphan non-root-tier spans (a storage read with no request
    # context) are suppressed, never recorded as one-span traces
    with tracer.span("pgfuse.read", tier="storage"):
        pass
    assert len(tracer.traces) == 2


# -- event/stat conservation ----------------------------------------------

def test_retry_events_equal_retried_reads(graph_file):
    """Two transient EIOs healed by per-mount retries: the trace shows
    exactly two ``"retry"`` events on storage spans, equal to
    ``PGFuseStats.retried_reads``."""
    tracer = Tracer()
    svc, engine, g = _service(graph_file, tracer, pgfuse_retries=2)
    fs = FaultyStorage()
    fs.fail_at[1] = OSError(errno.EIO, "flaky OST")
    fs.fail_at[4] = OSError(errno.EIO, "flaky OST")
    fs.install_graph(g)
    try:
        svc.khop([3, 71], 3)
        assert g.pgfuse_stats().retried_reads == 2
        traces = tracer.drain()
        assert event_counts(traces, "retry") == 2
        retry_spans = [s for root in traces for s in root.iter_spans()
                       if any(e.name == "retry" for e in s.events)]
        assert retry_spans and all(s.tier == "storage"
                                   for s in retry_spans)
        for s in retry_spans:
            for e in s.events:
                if e.name == "retry":
                    assert e.attrs["errno"] == errno.EIO
    finally:
        svc.close(), engine.close(), g.close()


def test_reroute_events_equal_router_reroutes(graph_file):
    """replication=2 with an EIO burst on one replica's mount: every
    failover the router performs appears as a ``"reroute"`` event on
    the route span, count equal to ``RouterStats.reroutes``."""
    tracer = Tracer()
    with ShardedQueryService(graph_file, n_shards=2, replication=2,
                             open_kwargs=OPEN_KW, tracer=tracer) as svc:
        (a0, a1), _ = svc.ranges
        fs = FaultyStorage().install_graph(svc.replicas[0][0].graph)
        for i in range(fs.n_calls + 1, fs.n_calls + 401):
            fs.fail_at[i] = OSError(errno.EIO, "dead OST")
        v = np.arange(a0, a1, dtype=np.int64)[:64]
        svc.neighbors_batch(v)
        svc.neighbors_batch(v)
        rd = svc.router.as_dict()
        assert rd["reroutes"] >= 1 and rd["failed_batches"] == 0
        traces = tracer.drain()
        assert event_counts(traces, "reroute") == rd["reroutes"]
        assert event_counts(traces, "shard_failed") == 0
        for root in traces:
            assert root.tier == "route"
            assert verify_span_tree(root) == []


def test_shed_events_equal_traversal_shed(graph_file):
    """Admission sheds are trace-visible: each shed is a zero-width
    request root carrying one ``"shed"`` event, and the event total
    equals ``TraversalStats.shed`` — on both the sync and async
    paths."""
    tracer = Tracer()
    svc, engine, g = _service(graph_file, tracer)
    svc.gate.plan = AdmissionPlan(max_inflight=1, max_edges_inflight=1 << 30,
                                  servers=1, slo_s=0.5,
                                  reason="test: one-request gate")
    try:
        blocker = TraversalRequest("khop", [1], k=1, max_edges=64)
        assert svc.admit(blocker)           # occupy the whole gate
        with pytest.raises(TraversalShed):
            svc.khop([3, 71], 2)            # sync shed
        with pytest.raises(TraversalShed):
            svc.submit(TraversalRequest("khop", [5], k=1))  # async shed
        st = svc.stats
        assert st.shed == 2
        traces = tracer.drain()
        shed_roots = [r for r in traces if r.event_count("shed")]
        assert event_counts(shed_roots, "shed") == st.shed
        for r in shed_roots:
            assert r.tier == "request" and not r.children
        svc.perform(blocker)
        svc.complete(blocker, 0.0)
        assert svc.stats.conserved
    finally:
        svc.close(), engine.close(), g.close()


def test_window_close_events_reconcile_with_close_reasons(graph_file):
    """With every batch traced, per-reason ``window_close`` event totals
    equal ``QueryStats.close_reasons`` on the full
    ``repro.query.window.CLOSE_REASONS`` axis."""
    tracer = Tracer()
    g = paragrapher.open_graph(graph_file, use_pgfuse=True, **OPEN_KW)
    engine = NeighborQueryEngine(g, decode="host", tracer=tracer)
    try:
        rng = np.random.default_rng(0)
        for _ in range(7):
            engine.neighbors_batch(rng.integers(0, engine.n_vertices, 16))
        st = engine.stats.as_dict()
        counted = close_reason_counts(st["close_reasons"])
        assert sum(counted.values()) == st["batches"] == 7
        traced = window_close_counts(tracer.drain())
        assert {k: v for k, v in counted.items() if v} == traced
    finally:
        engine.close(), g.close()


# -- determinism ----------------------------------------------------------

def _traced_run(gp) -> list:
    tracer = Tracer(clock=_Tick(), seed=0)
    svc, engine, g = _service(gp, tracer)
    try:
        svc.khop([3, 71], 3)
        svc.bfs_visit([5], max_vertices=64)
        svc.shortest_path(3, 200)
        return [r.as_dict() for r in tracer.drain()]
    finally:
        svc.close(), engine.close(), g.close()


def test_same_seed_span_trees_bit_identical(graph_file):
    """Two same-seed runs of the same request sequence under the
    injected tick clock: span ids, timestamps, attrs, events and tree
    shape are ALL identical — the serialized trees compare equal."""
    first, second = _traced_run(graph_file), _traced_run(graph_file)
    assert len(first) == 3
    assert first == second


# -- attribution ----------------------------------------------------------

def test_sharded_traversal_attribution_coverage(graph_file):
    """The acceptance bar: a sharded traversal under the SimStorage
    charged clock attributes >= 95% of each request's virtual time to
    named tiers.  The virtual clock advances ONLY inside charged
    storage reads and charged decode, both of which happen inside
    storage/decode spans — so named-tier coverage is structural, not a
    tuning accident."""
    storage = SimStorage(PROFILES["lustre_ssd"])
    vdecode = [0.0]

    def clock() -> float:
        return storage.charged_s + vdecode[0]

    tracer = Tracer(clock=clock, seed=0)
    svc = ShardedQueryService(
        graph_file, n_shards=2, decode="host", clock=clock, tracer=tracer,
        open_kwargs=dict(OPEN_KW, pgfuse_pread_fn=storage.pread))
    for row in svc.replicas:                    # bench decode-cost model
        for rep in row:
            orig = rep.engine._decode_host
            b = rep.graph.bytes_per_id

            def charged(packed, _orig=orig, _b=b):
                vdecode[0] += (sum(p.size for p in packed) // _b) / 5.0e7
                return _orig(packed)

            rep.engine._decode_host = charged
    trav = TraversalService(svc, tracer=tracer)
    try:
        trav.khop([3, 71], 3)
        trav.bfs_visit([5], max_vertices=256)
        traces = tracer.drain()
        assert len(traces) == 2
        for root in traces:
            assert verify_span_tree(root) == []
            att = attribution(root)
            assert att["total_s"] > 0
            assert att["coverage"] >= 0.95, att
            # storage + decode carry the charged time; the other named
            # tiers exist in the tree but cost ~nothing virtual
            assert att["tiers"]["storage"] + att["tiers"]["decode"] > 0
        report = render_report(traces)
        assert "coverage" in report
        for tier in NAMED_TIERS:
            assert tier in report
    finally:
        trav.close(), svc.close()
