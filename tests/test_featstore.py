"""FeatStore: wire format, PG-Fuse path, fault injection, and the
stream_features stage end to end (features + straggler re-splitting
through the multi-host simulator).

Tier-1 (fast) on purpose: like the multi-host suite this is the only
coverage the feature-streaming path gets without a real cluster."""

import io
import os
import time

import numpy as np
import pytest

from repro.core import featstore, paragrapher, pgfuse
from repro.data.multihost import (aggregate_stats, all_shards,
                                  resplit_shares, simulate_hosts)
from repro.graph import (featstore_for_graph, rmat, synthesize_node_features,
                         write_node_features)
from tests._prop import Draw, prop
from tests.conftest import FaultyStorage

OPEN_KW = dict(use_pgfuse=True, pgfuse_block_size=1 << 14,
               pgfuse_readahead=2)


@pytest.fixture(scope="module")
def graph_and_features(tmp_path_factory):
    d = tmp_path_factory.mktemp("fs")
    csr = rmat(9, 6, seed=3)
    gp = str(d / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    fp = featstore_for_graph(gp, str(d / "g.fst"), 16, seed=0,
                             data_align=1 << 14)
    x = synthesize_node_features(csr.n_vertices, 16, seed=0)
    return gp, fp, csr, x


# ---------------------------------------------------------------------------
# the format: roundtrip, alignment, validation
# ---------------------------------------------------------------------------

@prop()
def test_featstore_roundtrip(draw: Draw):
    n = draw.int(0, 300)
    d = draw.int(1, 40)
    dtype = draw.choice([np.float32, np.float16, np.uint8])
    x = (draw.floats((n, d), scale=3.0).astype(dtype)
         if dtype != np.uint8 else draw.ints(0, 255, (n, d)).astype(np.uint8))
    blob = featstore.roundtrip_bytes(x, data_align=draw.choice([1, 64, 4096]))
    with featstore.FeatStoreFile(io.BytesIO(blob)) as f:
        assert (f.n_rows, f.d) == (n, d)
        assert f.dtype == np.dtype(dtype)
        assert np.array_equal(f.read_full(), x)
        if n:
            v0 = draw.int(0, n - 1)
            v1 = draw.int(v0, n)
            assert np.array_equal(f.read_rows(v0, v1), x[v0:v1])


def test_featstore_data_align_and_validation(tmp_path):
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    p = tmp_path / "a.fst"
    n = write_node_features(p, x, data_align=4096)
    hdr = featstore.read_header(open(p, "rb"))
    assert hdr.data_start == 4096 and n == 4096 + 3 * 16
    assert hdr.row_stride == 16 and hdr.row_bytes == 16
    assert hdr.total_size == os.path.getsize(p)
    with pytest.raises(ValueError, match="2-D"):
        featstore.write_featstore(io.BytesIO(), np.zeros(3))
    with pytest.raises(ValueError, match="unsupported feature dtype"):
        featstore.write_featstore(io.BytesIO(), np.zeros((2, 2), np.int64))
    with pytest.raises(ValueError, match="bad magic"):
        featstore.FeatStoreFile(io.BytesIO(b"NOPE" + b"\0" * 28))
    with featstore.FeatStoreFile(str(p)) as f:
        with pytest.raises(ValueError, match="bad row range"):
            f.read_rows(0, 4)


def test_featstore_pgfuse_reads_match_plain(graph_and_features):
    _, fp, _, x = graph_and_features
    with featstore.open_featstore(fp, use_pgfuse=True,
                                  pgfuse_block_size=1 << 12,
                                  pgfuse_readahead=2) as h:
        assert (h.n_rows, h.d) == x.shape
        assert np.array_equal(h.read_rows(0, h.n_rows), x)
        assert np.array_equal(h.read_rows(7, 23), x[7:23])
        st = h.pgfuse_stats()
        assert st is not None and st.cache_hits + st.cache_misses > 0


def test_featstore_mounts_into_shared_fs(graph_and_features):
    gp, fp, _, x = graph_and_features
    with paragrapher.open_graph(gp, **OPEN_KW) as g:
        with featstore.open_featstore(fp, fs=g.fs) as h:
            assert np.array_equal(h.read_rows(3, 9), x[3:9])
            # the store's traffic is attributed to ITS file, not the
            # graph's: per-file counters stay separable
            assert h.pgfuse_stats().bytes_served > 0
            assert g.pgfuse_file_stats().bytes_served \
                < g.pgfuse_stats().bytes_served


# ---------------------------------------------------------------------------
# fault injection: feature reads fail loudly, like CompBin reads
# ---------------------------------------------------------------------------

def test_featstore_short_read_raises_and_retry_succeeds(graph_and_features):
    """A short underlying read of feature rows raises IOError instead of
    returning truncated (zero-padded) features; the claim reverts so the
    retry reloads cleanly — the same contract CachedFile gives CompBin."""
    _, fp, _, x = graph_and_features
    h = featstore.open_featstore(fp, use_pgfuse=True,
                                 pgfuse_block_size=1 << 12)
    try:
        faults = FaultyStorage()
        faults.install(h.cached_file)
        faults.truncate_at[1] = 10  # first post-install storage call
        with pytest.raises(IOError, match="short read"):
            h.read_rows(0, h.n_rows)
        assert np.array_equal(h.read_rows(0, h.n_rows), x)  # transient
        assert faults.n_calls >= 2
    finally:
        h.close()


def test_featstore_transient_eio_surfaces(graph_and_features):
    import errno

    _, fp, _, x = graph_and_features
    h = featstore.open_featstore(fp, use_pgfuse=True,
                                 pgfuse_block_size=1 << 12)
    try:
        faults = FaultyStorage()
        faults.install(h.cached_file)
        faults.fail_at[1] = OSError(errno.EIO, "flaky OST")
        with pytest.raises(OSError, match="flaky OST"):
            h.read_rows(0, h.n_rows)
        assert np.array_equal(h.read_rows(0, h.n_rows), x)
    finally:
        h.close()


# ---------------------------------------------------------------------------
# the stream_features stage end to end
# ---------------------------------------------------------------------------

def test_streamed_features_are_byte_exact(graph_and_features):
    gp, fp, csr, x = graph_and_features
    results = simulate_hosts(gp, 2, open_kwargs=OPEN_KW, n_parts=8,
                             feature_path=fp)
    shards = all_shards(results)
    assert all(s.x is not None for s in shards)
    got = np.concatenate([np.asarray(s.x) for s in shards])
    assert np.array_equal(got, x)
    agg = aggregate_stats(results)
    assert agg.feature_rows == csr.n_vertices
    assert agg.feature_bytes == x.nbytes == agg.feature_bytes_h2d
    assert agg.feature_cache_hits + agg.feature_cache_misses > 0
    assert agg.feature_read_s >= 0.0
    d = agg.as_dict()
    assert d["feature_hit_rate"] == agg.feature_hit_rate
    for r in results:  # per-host stats carry real per-stage traffic
        if r.stats.partitions:
            assert r.stats.feature_rows == r.host_range[1] - r.host_range[0]


def test_feature_topology_stats_stay_separable(graph_and_features):
    """Mounting the store on the graph's fs must not leak feature
    traffic into the topology storage counters (the per-file delta)."""
    gp, fp, csr, x = graph_and_features
    plain = simulate_hosts(gp, 1, open_kwargs=OPEN_KW, n_parts=8)[0]
    featd = simulate_hosts(gp, 1, open_kwargs=OPEN_KW, n_parts=8,
                           feature_path=fp)[0]
    assert featd.stats.cache_hits + featd.stats.cache_misses \
        == plain.stats.cache_hits + plain.stats.cache_misses
    assert featd.stats.bytes_h2d == plain.stats.bytes_h2d
    assert plain.stats.feature_rows == 0 and plain.stats.feature_bytes == 0


def test_feature_store_row_count_must_match_graph(tmp_path,
                                                  graph_and_features):
    gp, _, csr, _ = graph_and_features
    bad = tmp_path / "bad.fst"
    write_node_features(bad, np.zeros((csr.n_vertices + 5, 4), np.float32))
    with pytest.raises(ValueError, match="rows for a graph"):
        simulate_hosts(gp, 1, open_kwargs=OPEN_KW, feature_path=str(bad))


def test_short_feature_read_fails_the_stream(graph_and_features, tmp_path):
    """A truncated feature store (rows promised by the header missing on
    disk) surfaces as an error from the stream, not as a silent
    zero-padded shard."""
    gp, fp, csr, x = graph_and_features
    blob = open(fp, "rb").read()
    trunc = tmp_path / "trunc.fst"
    trunc.write_bytes(blob[:-x.nbytes // 2])  # drop the tail rows
    with pytest.raises(IOError, match="short read of feature rows"):
        simulate_hosts(gp, 1, open_kwargs=OPEN_KW, n_parts=8,
                       feature_path=str(trunc))


# ---------------------------------------------------------------------------
# straggler-aware re-splitting end to end
# ---------------------------------------------------------------------------

def test_slow_host_gets_smaller_slice_after_resplit(graph_and_features):
    """Acceptance: a simulated slow host (injected per-request storage
    latency) is measurably de-weighted by resplit_from_stats — its next
    epoch streams fewer edges than its first, and fewer than its peer."""
    gp, fp, csr, x = graph_and_features

    def open_kwargs(latency_by_host):
        def kwargs_for(i):
            kw = dict(use_pgfuse=True, pgfuse_block_size=1 << 12)
            lat = latency_by_host.get(i, 0.0)
            if lat:
                def slow_pread(fd, n, off, _lat=lat):
                    time.sleep(_lat)
                    return os.pread(fd, n, off)
                kw["pgfuse_pread_fn"] = slow_pread
            return kw
        return kwargs_for

    # warm-up epoch compiles the decode kernels so epoch-1 wall times
    # measure storage, not jit
    simulate_hosts(gp, 2, open_kwargs=open_kwargs({}), n_parts=8,
                   feature_path=fp)
    epoch1 = simulate_hosts(gp, 2, open_kwargs=open_kwargs({0: 0.08}),
                            n_parts=8, feature_path=fp)
    shares = resplit_shares(epoch1, floor=0.1)
    assert shares[0] < shares[1], shares  # the straggler is de-weighted
    epoch2 = simulate_hosts(gp, 2, open_kwargs=open_kwargs({0: 0.08}),
                            n_parts=8, feature_path=fp, shares=shares)
    assert epoch2[0].stats.edges < epoch1[0].stats.edges
    assert epoch2[0].stats.edges < epoch2[1].stats.edges
    # the re-split is still a correct cover: training sees every vertex
    got = np.concatenate([np.asarray(s.x) for s in all_shards(epoch2)])
    assert np.array_equal(got, x)


def test_streamed_batch_uses_store_features(graph_and_features):
    """launch.data_gnn.streamed_graph_batch: zero synthetic x when the
    shards carry feature rows."""
    from repro.launch.data_gnn import streamed_graph_batch
    from repro.models.gnn import gcn

    gp, fp, csr, x = graph_and_features
    results = simulate_hosts(gp, 2, open_kwargs=OPEN_KW, n_parts=8,
                             feature_path=fp)
    cfg = gcn.GCNConfig(n_layers=2, d_hidden=16, d_in=16, n_classes=7)
    batch = streamed_graph_batch("gcn-cora", cfg, all_shards(results),
                                 np.random.default_rng(0),
                                 n_vertices=results[0].n_vertices)
    assert np.array_equal(np.asarray(batch["x"]), x)
    # a model expecting a different width must fail loudly
    cfg8 = gcn.GCNConfig(n_layers=2, d_hidden=16, d_in=8, n_classes=7)
    with pytest.raises(ValueError, match="d_in"):
        streamed_graph_batch("gcn-cora", cfg8, all_shards(results),
                             np.random.default_rng(0))
    # mixed featured/feature-less shards are an error, not garbage rows
    plain = simulate_hosts(gp, 2, open_kwargs=OPEN_KW, n_parts=8)
    mixed = sorted(all_shards(results), key=lambda s: s.v0)
    hostless = sorted(all_shards(plain), key=lambda s: s.v0)
    mixed[-1] = hostless[-1]
    with pytest.raises(ValueError, match="no feature rows"):
        streamed_graph_batch("gcn-cora", cfg, mixed,
                             np.random.default_rng(0))
