"""Random-access query engine (repro.query) + the PG-Fuse access-pattern
split it rides on: property-tested equivalence with in-memory CSR
adjacency, async micro-batching, span-fetch, clock-vs-LRU eviction,
per-file budgets under pressure, and the serving path end to end.

Tier-1 (fast) on purpose: like the multi-host suite this is the only
coverage the random-access regime gets without a real cluster."""

import os

import numpy as np
import pytest

from repro.core import featstore, paragrapher, pgfuse, policy
from repro.graph import (NeighborSampler, featstore_for_graph, rmat,
                         synthesize_node_features)
from repro.query import NeighborQueryEngine, gather_rows
from tests._prop import Draw, prop

RANDOM_KW = dict(use_pgfuse=True, pgfuse_block_size=1 << 12,
                 pgfuse_readahead=0, pgfuse_eviction="clock")


@pytest.fixture(scope="module")
def graph_on_disk(tmp_path_factory):
    d = tmp_path_factory.mktemp("qe")
    csr = rmat(9, 6, seed=3)
    gp = str(d / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    fp = featstore_for_graph(gp, str(d / "g.fst"), 8, seed=0,
                             data_align=1 << 12)
    x = synthesize_node_features(csr.n_vertices, 8, seed=0)
    return gp, fp, csr, x


# ---------------------------------------------------------------------------
# correctness: engine answers == in-memory CSR adjacency
# ---------------------------------------------------------------------------

@prop(10)
def test_engine_matches_csr_adjacency(draw: Draw):
    """For arbitrary graphs and arbitrary (duplicate-heavy) batches, the
    engine's coalesced random-access answers equal the in-memory CSR."""
    import tempfile

    csr = draw.csr(max_edges=2048)
    with tempfile.TemporaryDirectory() as d:
        gp = os.path.join(d, "g.cbin")
        paragrapher.save_graph(gp, csr, format="compbin")
        use_pgfuse = draw.bool()
        kw = dict(use_pgfuse=use_pgfuse)
        if use_pgfuse:
            kw.update(pgfuse_block_size=draw.choice([64, 512, 1 << 12]),
                      pgfuse_eviction=draw.choice(["lru", "clock"]),
                      pgfuse_readahead=draw.choice([0, 2]))
        with paragrapher.open_graph(gp, **kw) as g:
            engine = NeighborQueryEngine(
                g, merge_gap=draw.choice([0, 64, 1 << 14]))
            for _ in range(3):
                batch = draw.vertex_batch(csr.n_vertices)
                got = engine.neighbors_batch(batch)
                assert len(got) == len(batch)
                for v, nbrs in zip(batch, got):
                    assert np.array_equal(nbrs, csr.neighbors_of(int(v))), \
                        (int(v), csr.n_vertices)


def test_engine_validates_inputs(graph_on_disk, tmp_path):
    gp, _, csr, _ = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        engine = NeighborQueryEngine(g)
        assert engine.neighbors_batch([]) == []
        with pytest.raises(ValueError, match="vertex ids"):
            engine.neighbors_batch([csr.n_vertices])
        with pytest.raises(ValueError, match="vertex ids"):
            engine.neighbors_batch([-1])
    # WebGraph has no fixed-width direct addressing: refuse, loudly
    wp = str(tmp_path / "g.wg")
    paragrapher.save_graph(wp, csr, format="webgraph")
    with paragrapher.open_graph(wp) as g:
        with pytest.raises(ValueError, match="direct-addressing"):
            NeighborQueryEngine(g)


def test_engine_stats_dedup_and_blocks(graph_on_disk):
    gp, _, csr, _ = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        engine = NeighborQueryEngine(g)
        ids = np.array([7, 7, 7, 9, 9, 100], dtype=np.int64)
        engine.neighbors_batch(ids)
        st = engine.stats
        assert st.requests == 6 and st.unique_vertices == 3
        assert st.dedup_ratio == 2.0
        assert st.batches == 1 and st.blocks_touched > 0
        assert st.coalesced_reads > 0 and st.bytes_gathered > 0
        assert st.latencies.n == 1
        assert st.p99_s >= st.p50_s >= 0.0
        d = st.as_dict()
        assert d["dedup_ratio"] == 2.0 and d["n_latencies"] == 1
        snap = st.reset()
        assert snap.requests == 6 and st.requests == 0


def test_engine_virtual_clock_latency(graph_on_disk):
    """An injected clock makes latency percentiles a deterministic
    property of the request pattern (what the bench gates)."""
    gp, _, csr, _ = graph_on_disk
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        engine = NeighborQueryEngine(g, clock=clock)
        engine.neighbors_batch([1, 2, 3])
        # one tick at entry, one at exit -> latency exactly 1.0 (the
        # histogram clamps constant distributions to the observed value)
        assert engine.stats.latencies.n == 1
        assert engine.stats.latency_quantile(0.5) == 1.0
        assert engine.stats.latencies.min_s == 1.0
        assert engine.stats.latencies.max_s == 1.0


# ---------------------------------------------------------------------------
# async micro-batching
# ---------------------------------------------------------------------------

def test_async_submit_coalesces_and_answers(graph_on_disk):
    gp, _, csr, _ = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        with NeighborQueryEngine(g, window_s=0.05) as engine:
            rng = np.random.default_rng(0)
            reqs = [rng.integers(0, csr.n_vertices, 16) for _ in range(12)]
            futs = [engine.submit(ids) for ids in reqs]
            for ids, fut in zip(reqs, futs):
                got = fut.result(timeout=10)
                assert fut.done and fut.latency_s >= 0.0
                for v, nbrs in zip(ids, got):
                    assert np.array_equal(nbrs, csr.neighbors_of(int(v)))
            st = engine.stats
            assert st.requests == 12 * 16
            # the window coalesced concurrent requests into FEWER batches,
            # and cross-request duplicates were fetched once
            assert st.batches < 12
            assert st.dedup_ratio > 1.0
        with pytest.raises(ValueError, match="closed"):
            engine.submit([0])


def test_async_flush_and_error_propagation(graph_on_disk):
    gp, _, csr, _ = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        engine = NeighborQueryEngine(g, window_s=30.0)  # never fires alone
        ok = engine.submit([1, 2])
        bad = engine.submit([csr.n_vertices + 5])  # poisoned batch
        engine.flush()
        with pytest.raises(ValueError, match="vertex ids"):
            bad.result(timeout=5)
        # the poisoned micro-batch fails every rider; a fresh one succeeds
        with pytest.raises(ValueError):
            ok.result(timeout=5)
        again = engine.submit([1, 2])
        engine.flush()
        got = again.result(timeout=5)
        assert np.array_equal(got[0], csr.neighbors_of(1))
        engine.close()


# ---------------------------------------------------------------------------
# the PG-Fuse random-access machinery underneath
# ---------------------------------------------------------------------------

def test_span_fetch_one_request_per_cold_run(tmp_path):
    """prefetch_range fetches a multi-block cold span with ONE underlying
    request (vs one per block), byte-identically."""
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, 16 * 1024, dtype=np.uint8).tobytes()
    p = tmp_path / "blob.bin"
    p.write_bytes(data)
    bs = 1024
    with pgfuse.PGFuseFS(block_size=bs) as fs:
        cf = fs.mount(str(p))
        assert cf.prefetch_range(0, 8 * bs) == 8
        assert cf.stats.underlying_reads == 1       # ONE enlarged request
        assert cf.stats.span_fetch_blocks == 8
        assert cf.pread(0, 8 * bs) == data[:8 * bs]
        assert cf.stats.underlying_reads == 1       # all served from cache
        # idempotent over resident blocks; extends only the cold tail
        assert cf.prefetch_range(6 * bs, 4 * bs) == 2
        assert cf.stats.underlying_reads == 2
        # clipped at EOF / empty spans are no-ops
        assert cf.prefetch_range(len(data) + 5, 10) == 0
        assert cf.prefetch_range(0, 0) == 0


def test_clock_plus_budget_beats_lru_on_looped_scan(tmp_path):
    """Satellite acceptance (deterministic): a hot file re-read every
    round survives a looped scan of a big file ONLY under the
    random-access stack (clock eviction + a per-file cap on the
    scanner); pure global LRU lets the scan churn the hot set out.
    Hit-rate comparison on the identical single-threaded trace."""
    bs = 1024
    rng = np.random.default_rng(1)
    hot_b, scan_b = 4, 32
    hot = tmp_path / "hot.bin"
    hot.write_bytes(rng.integers(0, 256, hot_b * bs, dtype=np.uint8).tobytes())
    scan = tmp_path / "scan.bin"
    scan.write_bytes(rng.integers(0, 256, scan_b * bs,
                                  dtype=np.uint8).tobytes())

    def replay(eviction, scan_budget):
        fs = pgfuse.PGFuseFS(block_size=bs, max_resident_bytes=8 * bs,
                             eviction=eviction)
        with fs:
            cf_hot = fs.mount(str(hot))
            cf_scan = fs.mount(str(scan), max_resident_bytes=scan_budget)
            for _ in range(6):  # rounds: touch hot set, then loop the scan
                for b in range(hot_b):
                    cf_hot.pread(b * bs, 100)
                for b in range(scan_b):
                    cf_scan.pread(b * bs, 100)
                    if scan_budget is not None:
                        assert cf_scan.resident_bytes <= scan_budget
            st = fs.stats()
            return st.cache_hits / (st.cache_hits + st.cache_misses)

    lru = replay("lru", None)
    configured = replay("clock", 4 * bs)
    assert configured > lru, (configured, lru)
    # the hot file's 4 blocks hit on 5 of 6 rounds under the configured
    # stack: at least those 20 acquisitions are hits
    assert configured >= 20 / (6 * (hot_b + scan_b)), configured


def test_per_file_budget_respected_under_pressure(graph_on_disk):
    """Acceptance: a feature store capped via its handle keeps its cache
    share under the cap through sustained random-gather churn, and the
    graph's hot blocks stay resident on the shared mount."""
    gp, fp, csr, x = graph_on_disk
    cap = 4 * (1 << 12)
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        h = featstore.open_featstore(fp, fs=g.fs, pgfuse_file_budget=cap,
                                     pgfuse_file_readahead=0)
        engine = NeighborQueryEngine(g)
        engine.neighbors_batch(np.arange(0, csr.n_vertices, 7))  # warm graph
        graph_resident = g.fs.mount(gp).resident_bytes
        assert graph_resident > 0
        rng = np.random.default_rng(0)
        for _ in range(30):  # feature churn >> cap
            gather_rows(h, rng.integers(0, csr.n_vertices, 64))
            assert h.cached_file.resident_bytes <= cap
        # the churn reclaimed from ITSELF; the graph's warm set survived
        assert g.fs.mount(gp).resident_bytes == graph_resident
        st = h.pgfuse_stats()
        assert st.evictions > 0  # the cap actually bit
        h.close()


def test_retroactive_file_budget(tmp_path):
    rng = np.random.default_rng(2)
    p = tmp_path / "f.bin"
    p.write_bytes(rng.integers(0, 256, 8 * 1024, dtype=np.uint8).tobytes())
    with pgfuse.PGFuseFS(block_size=1024) as fs:
        cf = fs.mount(str(p))
        cf.pread(0, 8 * 1024)
        assert cf.resident_bytes == 8 * 1024
        fs.set_file_budget(str(p), 2 * 1024)  # applies immediately
        assert cf.resident_bytes <= 2 * 1024
        assert cf.pread(0, 8 * 1024) == p.read_bytes()  # still correct


def test_bad_eviction_policy_rejected(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(b"x" * 100)
    with pytest.raises(ValueError, match="eviction"):
        pgfuse.PGFuseFS(eviction="mru")
    with pytest.raises(ValueError, match="eviction"):
        pgfuse.CachedFile(str(p), eviction="fifo")


# ---------------------------------------------------------------------------
# the sampler drawn through the engine + feature gathers
# ---------------------------------------------------------------------------

def test_sampler_through_engine_bit_identical(graph_on_disk):
    gp, _, csr, _ = graph_on_disk
    with paragrapher.open_graph(gp, **RANDOM_KW) as g:
        engine = NeighborQueryEngine(g)
        s_csr = NeighborSampler(csr, (4, 3), seed=5)
        s_eng = NeighborSampler(engine, (4, 3), seed=5)
        seeds = np.random.default_rng(1).integers(0, csr.n_vertices, 32)
        a, b = s_csr.sample(seeds), s_eng.sample(seeds)
        assert a.fanouts == b.fanouts
        for la, lb, va, vb in zip(a.layer_nodes, b.layer_nodes,
                                  a.layer_valid, b.layer_valid):
            assert np.array_equal(la, lb) and np.array_equal(va, vb)
        assert engine.stats.batches == len(a.fanouts)  # one fetch per layer


@prop(10)
def test_gather_rows_matches_matrix(draw: Draw):
    import io

    n = draw.int(1, 300)
    d = draw.int(1, 16)
    x = draw.floats((n, d))
    blob = featstore.roundtrip_bytes(x, data_align=draw.choice([1, 64, 4096]))

    class Store:  # duck-typed FeatureStoreHandle over an in-memory file
        def __init__(self):
            self._f = featstore.FeatStoreFile(io.BytesIO(blob))
            self.header = self._f.header
            self.n_rows, self.d, self.dtype = n, d, self._f.dtype

        def read_rows(self, v0, v1):
            return self._f.read_rows(v0, v1)

    ids = draw.vertex_batch(n, max_size=64)
    if draw.bool() and len(ids):
        ids[draw.int(0, len(ids) - 1)] = -1  # sampler padding
    got = gather_rows(Store(), ids)
    assert got.shape == (len(ids), d)
    for i, v in enumerate(ids):
        want = x[v] if v >= 0 else np.zeros(d, x.dtype)
        assert np.array_equal(got[i], want)


# ---------------------------------------------------------------------------
# end-to-end acceptance: serving byte-identical to the in-memory path,
# sampled minibatch training learns
# ---------------------------------------------------------------------------

def test_serving_answers_match_in_memory_csr(tmp_path):
    """The served logits for a request batch equal the in-memory-CSR
    reference computed with the same seeds/params — the storage path
    (engine + PG-Fuse + feature store) changes WHERE bytes come from,
    never WHAT the model sees."""
    import jax

    from repro.configs import get_arch
    from repro.launch.data_gnn import block_to_edges, ensure_gnn_assets
    from repro.launch.serve import make_gnn_server
    from repro.launch.steps import _GNN_MODULES

    cfg = get_arch("gcn-cora").make_reduced()
    d_in = cfg.d_in
    workdir = str(tmp_path)
    answer, engine, close = make_gnn_server("gcn-cora", cfg, workdir,
                                            fanouts=(3, 2), seed=7)
    try:
        gp, _, _ = ensure_gnn_assets(workdir, d_in, cfg.n_classes)
        csr = paragrapher.open_graph(gp).read_full()
        x = synthesize_node_features(csr.n_vertices, d_in, seed=0)
        ref_sampler = NeighborSampler(csr, (3, 2), seed=7)
        mod = _GNN_MODULES["gcn-cora"]
        params = mod.init_params(cfg, jax.random.key(0))
        fwd = jax.jit(lambda p, b: mod.forward(p, b, cfg))
        rng = np.random.default_rng(3)
        for _ in range(3):
            seeds = rng.integers(0, csr.n_vertices, 16)
            got = answer(seeds)
            # reference: same sampler RNG stream over the in-memory CSR
            block = ref_sampler.sample(seeds)
            src, dst, n = block_to_edges(block)
            nodes = np.concatenate(block.layer_nodes)
            valid = np.concatenate(block.layer_valid)
            xr = np.zeros((n, d_in), np.float32)
            xr[valid] = x[nodes[valid]]
            import jax.numpy as jnp
            ref = np.asarray(fwd(params, {
                "x": jnp.asarray(xr),
                "edge_src": jnp.asarray(src.astype(np.int32)),
                "edge_dst": jnp.asarray(dst.astype(np.int32)),
            })[:len(seeds)])
            assert np.array_equal(got, ref)
        assert engine.stats.dedup_ratio > 1.0  # acceptance: batching pays
    finally:
        close()


def test_sampled_training_loss_decreases(tmp_path):
    """Acceptance: --sampled minibatch GCN trains through the query
    engine + column-family stores and the loss goes down."""
    from repro.configs import get_arch
    from repro.launch.train import _gnn_sampled_batches
    from repro.models.gnn import gcn
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    import jax

    cfg = get_arch("gcn-cora").make_reduced()
    batches = _gnn_sampled_batches("gcn-cora", cfg, str(tmp_path), True,
                                   batch_seeds=64)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=80)
    params = gcn.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        l, g = jax.value_and_grad(
            lambda p: gcn.loss_fn(p, batch, cfg))(params)
        params, opt, _ = adamw_update(params, g, opt, opt_cfg)
        return params, opt, l

    losses = []
    for _, batch in zip(range(80), batches):
        params, opt, l = step(params, opt, batch)
        losses.append(float(l))
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first, (first, last)
