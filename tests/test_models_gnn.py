"""GNN layers + models: hand-checked aggregation, invariances."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import layers as L
from tests._prop import prop


def test_scatter_sum_hand_example():
    msgs = jnp.asarray([[1.0], [2.0], [4.0], [8.0]])
    dst = jnp.asarray([0, 1, 0, -1])  # -1 = padding, dropped
    out = L.scatter_sum(msgs, dst, 3)
    np.testing.assert_allclose(np.asarray(out), [[5.0], [2.0], [0.0]])


def test_degree_and_mean():
    dst = jnp.asarray([0, 0, 2, -1])
    assert list(np.asarray(L.degree(dst, 3))) == [2, 0, 1]
    msgs = jnp.asarray([[2.0], [4.0], [5.0], [9.0]])
    np.testing.assert_allclose(np.asarray(L.scatter_mean(msgs, dst, 3)),
                               [[3.0], [0.0], [5.0]])


def test_scatter_max_min_std():
    msgs = jnp.asarray([[1.0], [5.0], [-2.0]])
    dst = jnp.asarray([0, 0, 0])
    assert float(L.scatter_max(msgs, dst, 1)[0, 0]) == 5.0
    assert float(L.scatter_min(msgs, dst, 1)[0, 0]) == -2.0
    std = float(L.scatter_std(msgs, dst, 1)[0, 0])
    np.testing.assert_allclose(std, np.std([1.0, 5.0, -2.0]), rtol=1e-3)


@prop(10)
def test_gather_padding(draw):
    n, e = draw.int(1, 50), draw.int(1, 100)
    x = jnp.asarray(draw.floats((n, 4)))
    idx = jnp.asarray(draw.ints(-1, n - 1, e))
    out = L.gather(x, idx)
    for i, j in enumerate(np.asarray(idx)):
        if j < 0:
            assert np.all(np.asarray(out[i]) == 0)
        else:
            np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(x[j]))


def test_gcn_two_node_hand_check():
    """1 directed edge 0->1, sym norm; hand-compute layer 1 output."""
    from repro.models.gnn import gcn
    cfg = gcn.GCNConfig(n_layers=1, d_hidden=1, d_in=2, n_classes=2, norm="sym")
    params = {"w0": jnp.asarray([[1.0, 0.0], [0.0, 1.0]]),
              "b0": jnp.zeros(2)}
    batch = {"x": jnp.asarray([[1.0, 2.0], [3.0, 4.0]]),
             "edge_src": jnp.asarray([0]), "edge_dst": jnp.asarray([1])}
    out = gcn.forward(params, batch, cfg)
    # node 0: deg 1 (self) -> self_w = 1 -> x0
    np.testing.assert_allclose(np.asarray(out[0]), [1.0, 2.0], rtol=1e-5)
    # node 1: deg 2 -> 1/sqrt(1*2)*x0 + x1/2
    exp = np.array([1.0, 2.0]) / np.sqrt(2) + np.array([3.0, 4.0]) / 2
    np.testing.assert_allclose(np.asarray(out[1]), exp, rtol=1e-5)


def test_dimenet_rotation_invariance():
    """DimeNet consumes only distances and angles -> predictions must be
    invariant under global rotation of positions."""
    from repro.models.gnn import dimenet
    cfg = dimenet.DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=4,
                                n_spherical=3, n_radial=3, d_in=4)
    p = dimenet.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    N, E, T = 12, 40, 60
    src, dst = rng.integers(0, N, E), rng.integers(0, N, E)
    batch = dict(
        x=jnp.asarray(rng.standard_normal((N, 4)).astype(np.float32)),
        pos=jnp.asarray(rng.standard_normal((N, 3)).astype(np.float32)),
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
        triplet_kj=jnp.asarray(rng.integers(0, E, T)),
        triplet_ji=jnp.asarray(rng.integers(0, E, T)),
        graph_id=jnp.asarray(np.zeros(N, np.int32)), n_graphs=1)
    out1 = dimenet.forward(p, batch, cfg)
    # rotate positions by a random orthogonal matrix
    A = np.linalg.qr(rng.standard_normal((3, 3)))[0].astype(np.float32)
    batch2 = dict(batch, pos=batch["pos"] @ jnp.asarray(A))
    out2 = dimenet.forward(p, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out1),
                               rtol=1e-3, atol=1e-4)


def test_meshgraphnet_residual_identity_at_zero():
    """With zero node/edge inputs and zero biases the decoder sees zeros."""
    from repro.models.gnn import meshgraphnet as mgn
    cfg = mgn.MeshGraphNetConfig(n_layers=2, d_hidden=8, d_node_in=4,
                                 d_edge_in=4, d_out=2)
    p = mgn.init_params(cfg, jax.random.key(0))
    batch = {"x": jnp.zeros((5, 4)), "edge_attr": jnp.zeros((6, 4)),
             "edge_src": jnp.asarray([0, 1, 2, 3, 4, 0]),
             "edge_dst": jnp.asarray([1, 2, 3, 4, 0, 2])}
    out = mgn.forward(p, batch, cfg)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_pna_scalers_change_output():
    from repro.models.gnn import pna
    rng = np.random.default_rng(0)
    cfg1 = pna.PNAConfig(n_layers=1, d_hidden=8, d_in=4, n_classes=2,
                         avg_log_degree=1.0)
    cfg2 = pna.PNAConfig(n_layers=1, d_hidden=8, d_in=4, n_classes=2,
                         avg_log_degree=4.0)
    p = pna.init_params(cfg1, jax.random.key(0))
    batch = {"x": jnp.asarray(rng.standard_normal((10, 4)).astype(np.float32)),
             "edge_src": jnp.asarray(rng.integers(0, 10, 30)),
             "edge_dst": jnp.asarray(rng.integers(0, 10, 30))}
    o1 = pna.forward(p, batch, cfg1)
    o2 = pna.forward(p, batch, cfg2)
    assert not np.allclose(np.asarray(o1), np.asarray(o2))
