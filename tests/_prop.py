"""Property-test harness.

Uses `hypothesis` when available; otherwise falls back to a seeded
random-case sweep with the same API surface we need (`given` + strategies
over ints/floats/arrays).  The fallback keeps the property-style structure
(each test is a predicate over randomly drawn inputs) and prints the
failing seed for reproduction.
"""

from __future__ import annotations

import functools
import os

import numpy as np

try:  # pragma: no cover - environment-dependent
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_CASES = int(os.environ.get("PROP_CASES", "25"))


class Draw:
    def __init__(self, rng: np.random.Generator):
        self.rng = rng

    def int(self, lo: int, hi: int) -> int:
        return int(self.rng.integers(lo, hi + 1))

    def float(self, lo: float, hi: float) -> float:
        return float(self.rng.uniform(lo, hi))

    def ints(self, lo: int, hi: int, size) -> np.ndarray:
        return self.rng.integers(lo, hi + 1, size)

    def floats(self, size, scale: float = 1.0) -> np.ndarray:
        return (self.rng.standard_normal(size) * scale).astype(np.float32)

    def bool(self) -> bool:
        return bool(self.rng.random() < 0.5)

    def choice(self, seq):
        return seq[int(self.rng.integers(0, len(seq)))]

    # -- graph strategies -------------------------------------------------
    #: |V| values straddling the 2^8 / 2^16 / 2^24 fences of CompBin's
    #: bytes_per_vertex, plus degenerate sizes (empty, single vertex).
    VERTEX_FENCES = (0, 1, 2, 3, 255, 256, 257, 65535, 65536, 65537,
                     (1 << 24) - 1, 1 << 24, (1 << 24) + 1)

    def n_vertices(self, fence_bias: float = 0.7, cap: int = 1 << 17) -> int:
        """Graph size, biased toward byte-width fences (capped: fence sizes
        above ``cap`` are exercised via offsets-only paths by callers)."""
        if self.rng.random() < fence_bias:
            return int(self.choice([v for v in self.VERTEX_FENCES if v <= cap]))
        return self.int(0, cap)

    def process_count(self, hi: int = 8) -> int:
        """Simulated host counts, biased toward the interesting small end
        (1 host = degenerate split, 2 = the common pair)."""
        if self.rng.random() < 0.5:
            return self.choice([1, 2])
        return self.int(1, hi)

    def align(self, hi: int = 512) -> int:
        """Block-grid vertex alignments for split_plan(align=): biased
        toward powers of two (the ``block_size // row_stride`` values a
        fixed-stride feature store actually produces)."""
        if self.rng.random() < 0.7:
            return int(2 ** self.int(0, 9))
        return self.int(1, hi)

    def shares(self, k: int) -> np.ndarray:
        """Per-host capacity shares: mostly mild skew, sometimes one
        host 10x the others (a straggler's inverse)."""
        s = self.rng.uniform(0.1, 1.0, k)
        if self.bool():
            s[self.int(0, k - 1)] *= 10.0
        return s / s.sum()

    def vertex_batch(self, n_vertices: int, max_size: int = 256) -> np.ndarray:
        """A query batch over [0, n_vertices): biased toward DUPLICATES
        (zipf-ish hot vertices repeated in one batch — the case the query
        engine's dedup exists for) and occasionally empty."""
        if n_vertices == 0 or self.rng.random() < 0.05:
            return np.zeros(0, dtype=np.int64)
        size = self.int(1, max_size)
        ids = self.ints(0, n_vertices - 1, size).astype(np.int64)
        if self.bool():  # fold a hot subset over itself
            k = self.int(1, max(1, size // 4))
            ids[self.ints(0, size - 1, k)] = ids[self.int(0, size - 1)]
        return ids

    def plan(self, csr, max_parts: int = 9) -> list:
        """An edge-balanced partition plan over ``csr`` (the same cut rule
        GraphHandle.partition_plan uses), possibly with more requested
        parts than the graph can support."""
        from repro.graph.partition import vertex_range_partition

        if csr.n_vertices == 0:
            return []
        return vertex_range_partition(csr, self.int(1, max_parts))

    def csr(self, n_vertices=None, max_edges: int = 4096,
            sort_neighbors: bool = True, dedupe: bool = True):
        """Random CSR with edge-case structure: empty graphs, isolated
        vertices (edges only touch a subset of rows), duplicate-free rows
        when ``dedupe`` (required by the WebGraph encoder)."""
        from repro.core.csr import CSR, csr_from_edges

        n = self.n_vertices() if n_vertices is None else n_vertices
        if n == 0:
            return CSR(offsets=np.zeros(1, np.int64),
                       neighbors=np.zeros(0, np.int32))
        n_edges = self.int(0, max_edges)
        # confine sources to a random sub-range so some vertices stay
        # isolated (degree 0 rows are the classic off-by-one trap)
        lo = self.int(0, max(0, n - 1))
        hi = self.int(lo, n - 1)
        src = self.ints(lo, hi, n_edges)
        dst = self.ints(0, n - 1, n_edges)
        return csr_from_edges(src, dst, n, sort_neighbors=sort_neighbors,
                              dedupe=dedupe)


def prop(n_cases: int = N_CASES):
    """Decorator: run ``test(draw)`` for ``n_cases`` seeded draws."""

    def deco(fn):
        def wrapper():
            for case in range(n_cases):
                rng = np.random.default_rng(1000 + case)
                try:
                    fn(Draw(rng))
                except Exception:
                    print(f"[prop] failing case seed={1000 + case} in {fn.__name__}")
                    raise
        # keep pytest discovery name but NOT the (draw) signature
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
