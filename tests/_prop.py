"""Property-test harness.

Uses `hypothesis` when available; otherwise falls back to a seeded
random-case sweep with the same API surface we need (`given` + strategies
over ints/floats/arrays).  The fallback keeps the property-style structure
(each test is a predicate over randomly drawn inputs) and prints the
failing seed for reproduction.
"""

from __future__ import annotations

import functools
import os

import numpy as np

try:  # pragma: no cover - environment-dependent
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_CASES = int(os.environ.get("PROP_CASES", "25"))


class Draw:
    def __init__(self, rng: np.random.Generator):
        self.rng = rng

    def int(self, lo: int, hi: int) -> int:
        return int(self.rng.integers(lo, hi + 1))

    def float(self, lo: float, hi: float) -> float:
        return float(self.rng.uniform(lo, hi))

    def ints(self, lo: int, hi: int, size) -> np.ndarray:
        return self.rng.integers(lo, hi + 1, size)

    def floats(self, size, scale: float = 1.0) -> np.ndarray:
        return (self.rng.standard_normal(size) * scale).astype(np.float32)

    def bool(self) -> bool:
        return bool(self.rng.random() < 0.5)

    def choice(self, seq):
        return seq[int(self.rng.integers(0, len(seq)))]


def prop(n_cases: int = N_CASES):
    """Decorator: run ``test(draw)`` for ``n_cases`` seeded draws."""

    def deco(fn):
        def wrapper():
            for case in range(n_cases):
                rng = np.random.default_rng(1000 + case)
                try:
                    fn(Draw(rng))
                except Exception:
                    print(f"[prop] failing case seed={1000 + case} in {fn.__name__}")
                    raise
        # keep pytest discovery name but NOT the (draw) signature
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
