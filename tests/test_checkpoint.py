"""Checkpointing: roundtrip, atomicity, async, GC, elastic re-shard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.distributed.elastic import reshard


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "opt": {"step": jnp.int32(7), "m": [jnp.zeros(2), jnp.ones(3)]}}


def test_roundtrip(tmp_path, tree):
    ck.save(str(tmp_path), 5, tree)
    step, got = ck.restore_latest(str(tmp_path), tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float32),
                                      np.asarray(b, dtype=np.float32))


def test_restore_latest_picks_max_and_ignores_tmp(tmp_path, tree):
    ck.save(str(tmp_path), 3, tree)
    ck.save(str(tmp_path), 11, jax.tree.map(lambda x: x + 1, tree))
    os.makedirs(tmp_path / "step_00000099.tmp")  # crashed save
    step, got = ck.restore_latest(str(tmp_path), tree)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(got["opt"]["step"]), 8)


def test_gc_keeps_last_k(tmp_path, tree):
    for s in range(6):
        ck.save(str(tmp_path), s, tree, keep_last=2)
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_async_checkpointer(tmp_path, tree):
    ac = ck.AsyncCheckpointer(str(tmp_path), keep_last=3)
    ac.save(1, tree)
    ac.save(2, tree)   # waits for #1 internally
    ac.wait()
    assert ck.latest_step(str(tmp_path)) == 2


def test_missing_leaf_raises(tmp_path, tree):
    ck.save(str(tmp_path), 1, {"params": tree["params"]})
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), 1, tree)


def test_elastic_reshard_roundtrip(tmp_path, tree):
    """Save on one layout, restore re-sharded onto a (1-device) mesh with
    explicit PartitionSpecs — the elastic-restart path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck.save(str(tmp_path), 2, tree)
    mesh = jax.make_mesh((1,), ("data",))
    specs = {"params": {"w": P("data", None), "b": P(None)},
             "opt": {"step": P(), "m": [P(None), P(None)]}}
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    step, got = ck.restore_latest(str(tmp_path), tree, shardings=shardings)
    assert step == 2
    assert got["params"]["w"].sharding.spec == P("data", None)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    # and move it again with reshard() (live re-mesh)
    moved = reshard(got, mesh, specs)
    np.testing.assert_array_equal(np.asarray(moved["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
