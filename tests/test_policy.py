"""Hybrid format policy (paper Fig. 4 crossover)."""

import numpy as np

from repro.core import policy


def test_choose_format_small_graph_prefers_compbin():
    # fast storage + slow webgraph decode -> CompBin wins
    m = policy.SystemModel(storage_bw=2e9, compbin_decode_rate=5e8,
                           webgraph_decode_rate=2e6)
    assert policy.choose_format(10_000, 100_000, webgraph_size=50_000,
                                model=m) == "compbin"


def test_choose_format_large_compressed_graph_prefers_webgraph():
    # slow storage + well-compressed webgraph (eu-2015 regime)
    m = policy.SystemModel(storage_bw=2e7, compbin_decode_rate=5e8,
                           webgraph_decode_rate=1e8)
    n_v, n_e = 2**31, 10**9
    from repro.core import compbin
    wg_size = int(0.05 * compbin.compbin_nbytes(n_v, n_e))
    assert policy.choose_format(n_v, n_e, webgraph_size=wg_size,
                                model=m) == "webgraph"


def test_crossover_grows_with_storage_bw():
    """Faster storage pushes the crossover UP (paper §V-D: thresholds are
    system dependent): with more read bandwidth, CompBin's fat reads cost
    less, so WebGraph needs a bigger size advantage to win."""
    slow = policy.SystemModel(storage_bw=1e8)
    fast = policy.SystemModel(storage_bw=1e10)
    n_e, n_v = 10**8, 10**7
    assert (policy.crossover_size_difference(fast, n_e, n_v)
            > policy.crossover_size_difference(slow, n_e, n_v))


def test_calibrate_measures_sane_rates():
    m = policy.calibrate(n_vertices=1 << 12, n_edges=1 << 14)
    assert m.compbin_decode_rate > m.webgraph_decode_rate  # the paper's premise
    assert m.storage_bw > 0
