"""Storage-fault injection over PG-Fuse (tests/conftest.py FaultyStorage):
transient EIO, short reads, and latency must surface deterministically —
never hang a reader, never hand truncated bytes downstream — and the
readahead path must keep running through injected latency."""

import errno
import os

import numpy as np
import pytest

from repro.core import paragrapher, pgfuse
from repro.data.graph_stream import assemble_csr, stream_partitions
from repro.graph import erdos_renyi


BLOCK = 1024


@pytest.fixture
def data_file(tmp_path):
    rng = np.random.default_rng(7)
    payload = rng.integers(0, 256, 4 * BLOCK, dtype=np.uint8).tobytes()
    p = str(tmp_path / "blob.bin")
    with open(p, "wb") as f:
        f.write(payload)
    return p, payload


@pytest.fixture
def graph_file(tmp_path):
    csr = erdos_renyi(1 << 9, 1 << 13, seed=11)
    p = str(tmp_path / "g.cbin")
    paragrapher.save_graph(p, csr, format="compbin")
    return p, csr


def test_transient_eio_surfaces_then_recovers(data_file, faulty_storage):
    path, payload = data_file
    cf = pgfuse.CachedFile(path, block_size=BLOCK)
    try:
        faulty_storage.fail_at[1] = OSError(errno.EIO, "flaky OST")
        faulty_storage.install(cf)
        with pytest.raises(OSError) as exc:
            cf.pread(0, len(payload))
        assert exc.value.errno == errno.EIO
        # transient: the claim reverted (-2 -> -1), so the retry reloads
        # the same blocks and succeeds with byte-exact data
        assert cf.pread(0, len(payload)) == payload
    finally:
        cf.close()


def test_short_read_of_requested_block_raises_not_hangs(data_file,
                                                        faulty_storage):
    path, payload = data_file
    cf = pgfuse.CachedFile(path, block_size=BLOCK)
    try:
        faulty_storage.truncate_at[1] = 100  # < one block
        faulty_storage.install(cf)
        # must raise (silent truncation would corrupt every future reader;
        # installing the stub would spin pread forever on a 0-byte take)
        with pytest.raises(IOError, match="short read"):
            cf.pread(0, len(payload))
        assert cf.pread(0, len(payload)) == payload  # fault was transient
    finally:
        cf.close()


def test_short_read_drops_readahead_blocks_only(data_file, faulty_storage):
    path, payload = data_file
    cf = pgfuse.CachedFile(path, block_size=BLOCK, readahead=3)
    try:
        # call 1 claims blocks 0..3 in ONE enlarged request but storage
        # returns just block 0: the requested block installs, the three
        # readahead claims revert silently (paper: readahead is advisory)
        faulty_storage.truncate_at[1] = BLOCK
        faulty_storage.install(cf)
        assert cf.pread(0, len(payload)) == payload
        assert faulty_storage.n_calls == 2  # blocks 1..3 refetched as a run
        assert cf.stats.readahead_blocks == 2  # call 2: b=1 + ahead {2,3}
    finally:
        cf.close()


def test_async_read_surfaces_storage_error(graph_file, faulty_storage):
    path, csr = graph_file
    with paragrapher.open_graph(path, use_pgfuse=True,
                                pgfuse_block_size=BLOCK) as g:
        plan = g.partition_plan(4)
        faulty_storage.fail_at[1] = OSError(errno.EIO, "flaky OST")
        faulty_storage.install_graph(g)
        got = []
        ar = g.read_async(plan, lambda buf: got.append(buf.error),
                          n_workers=1)
        with pytest.raises(OSError):
            ar.wait(timeout=30)  # surfaces the EIO, does NOT time out
        assert ar.done
        assert any(isinstance(e, OSError) for e in got)


def test_stream_surfaces_storage_error_not_hang(graph_file, faulty_storage):
    path, csr = graph_file
    with paragrapher.open_graph(path, use_pgfuse=True,
                                pgfuse_block_size=BLOCK) as g:
        stream = stream_partitions(g, None, n_parts=4, n_workers=1)
        faulty_storage.fail_at[1] = OSError(errno.EIO, "flaky OST")
        faulty_storage.install_graph(g)
        with pytest.raises(OSError):
            with stream:
                list(stream)


def test_stream_recovers_after_transient_error(graph_file, faulty_storage):
    path, csr = graph_file
    with paragrapher.open_graph(path, use_pgfuse=True,
                                pgfuse_block_size=BLOCK) as g:
        faulty_storage.fail_at[1] = OSError(errno.EIO, "flaky OST")
        faulty_storage.install_graph(g)
        with pytest.raises(OSError):
            with stream_partitions(g, None, n_parts=4, n_workers=1) as s:
                list(s)
        # the fault was transient and all block claims reverted: a fresh
        # stream over the SAME handle reassembles the graph byte-exactly
        with stream_partitions(g, None, n_parts=4) as stream:
            assert assemble_csr(list(stream)) == csr


def test_retry_policy_absorbs_transient_eio(data_file, faulty_storage):
    """With retries=N a transient EIO never reaches the consumer: the
    bounded-retry wrapper goes back to storage (deterministic backoff)
    and the SAME pread succeeds.  The retry sits above the underlying-
    read funnel, so the injected fault exercises the real policy."""
    path, payload = data_file
    cf = pgfuse.CachedFile(path, block_size=BLOCK, retries=2,
                           retry_backoff_s=1e-4)
    try:
        faulty_storage.fail_at[1] = OSError(errno.EIO, "flaky OST")
        faulty_storage.install(cf)
        assert cf.pread(0, len(payload)) == payload  # no exception escapes
        assert cf.stats.retried_reads == 1
        assert faulty_storage.n_calls >= 2  # the retry really hit storage
    finally:
        cf.close()


def test_retry_policy_is_bounded(data_file, faulty_storage):
    """More consecutive EIOs than retries= allows must surface — a dead
    OST is not a transient fault, and unbounded retry would hang the
    loader instead of failing it over."""
    path, payload = data_file
    cf = pgfuse.CachedFile(path, block_size=BLOCK, retries=1,
                           retry_backoff_s=1e-4)
    try:
        for i in (1, 2):  # first attempt AND its one retry both fail
            faulty_storage.fail_at[i] = OSError(errno.EIO, "dead OST")
        faulty_storage.install(cf)
        with pytest.raises(OSError) as exc:
            cf.pread(0, len(payload))
        assert exc.value.errno == errno.EIO
        assert cf.stats.retried_reads == 1  # exactly one retry was spent
        # claims reverted through the state machine: a later read works
        assert cf.pread(0, len(payload)) == payload
    finally:
        cf.close()


def test_retry_policy_through_graph_stream(graph_file, faulty_storage):
    """End to end: a streamed load over a retrying mount survives an
    injected transient EIO that would otherwise kill the stream."""
    path, csr = graph_file
    with paragrapher.open_graph(path, use_pgfuse=True,
                                pgfuse_block_size=BLOCK,
                                pgfuse_retries=2,
                                pgfuse_retry_backoff_s=1e-4) as g:
        faulty_storage.fail_at[1] = OSError(errno.EIO, "flaky OST")
        faulty_storage.install_graph(g)
        with stream_partitions(g, None, n_parts=4) as stream:
            assert assemble_csr(list(stream)) == csr
        assert g.pgfuse_stats().retried_reads == 1


def test_retry_does_not_mask_short_reads(data_file, faulty_storage):
    """Short reads are NOT retried by the policy (they surface through
    the strict short-read path): retrying would re-read a block the
    filesystem claims is shorter than the header says, hiding
    truncation behind latency."""
    path, payload = data_file
    cf = pgfuse.CachedFile(path, block_size=BLOCK, retries=3,
                           retry_backoff_s=1e-4)
    try:
        faulty_storage.truncate_at[1] = 100
        faulty_storage.install(cf)
        with pytest.raises(IOError, match="short read"):
            cf.pread(0, len(payload))
        assert cf.stats.retried_reads == 0
    finally:
        cf.close()


def test_readahead_runs_through_injected_latency(graph_file):
    """Under a per-request latency floor the readahead path must stay
    active (enlarged multi-block fetches) and cut underlying requests."""
    from tests.conftest import FaultyStorage

    path, csr = graph_file
    calls = {}
    for ra in (0, 4):
        with paragrapher.open_graph(path, use_pgfuse=True,
                                    pgfuse_block_size=BLOCK,
                                    pgfuse_readahead=ra) as g:
            fs = FaultyStorage(latency_s=5e-4)
            fs.install_graph(g)
            with stream_partitions(g, None, n_parts=4) as stream:
                assert assemble_csr(list(stream)) == csr
            calls[ra] = fs.n_calls
            if ra:
                assert stream.stats.readahead_blocks > 0
    assert calls[4] < calls[0], calls
