"""Associative stats aggregation — the accounting layer the sharded
service's fleet totals stand on.

``QueryStats.merge`` / ``TraversalStats.merge`` must be associative
(fold order across shards cannot change the totals), invariant-
preserving (``sum(close_reasons) == batches``; both traversal
conservation identities), and safe against the two concurrent
mutations a live service performs: per-batch folds and atomic
``reset()``.  The stress tests here race all three and demand that no
batch is ever lost or double-counted and that every merged snapshot
satisfies the invariants at every instant.

Latency retention is a bounded :class:`repro.obs.metrics
.LatencyHistogram` (the old raw lists grew without bound and merge
concatenated them untrimmed); its merge is EXACTLY associative —
integer bucket counts plus min/max, no float accumulation — so fold
results are pinned bit-for-bit here.
"""

import threading

import numpy as np
import pytest

from repro.obs.metrics import LatencyHistogram
from repro.query import QueryStats, TraversalStats, merge_query_stats


def _qstats(requests=0, unique=0, batches=0, reasons=(), lat=()):
    st = QueryStats()
    st.requests, st.unique_vertices, st.batches = requests, unique, batches
    for r in reasons:
        st.close_reasons[r] = st.close_reasons.get(r, 0) + 1
    for v in lat:
        st.latencies.add(v)
    return st


def test_query_stats_merge_sums_and_preserves_invariant():
    a = _qstats(10, 4, 2, ["direct", "full"], [0.1, 0.2])
    b = _qstats(6, 3, 3, ["direct", "timeout", "direct"], [0.3])
    m = a.merge(b)
    assert (m.requests, m.unique_vertices, m.batches) == (16, 7, 5)
    assert m.close_reasons == {"direct": 3, "full": 1, "timeout": 1}
    assert sum(m.close_reasons.values()) == m.batches
    assert m.latencies.n == 3
    assert m.latencies.min_s == 0.1 and m.latencies.max_s == 0.3
    # merge is a pure fold: operands untouched, result independent
    assert a.requests == 10 and b.requests == 6
    m.requests += 1
    assert a.requests == 10
    # identity: merging a zero element changes nothing
    assert a.merge(QueryStats()).as_dict() == a.as_dict()


def test_query_stats_merge_associative():
    a = _qstats(10, 4, 2, ["direct"] * 2, [0.1])
    b = _qstats(6, 3, 3, ["full"] * 3, [0.2, 0.4])
    c = _qstats(9, 9, 1, ["plateau"], [0.5])
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.as_dict() == right.as_dict()
    assert left.latencies == right.latencies
    # merge_query_stats is the same left fold
    assert merge_query_stats([a, b, c]).as_dict() == left.as_dict()
    assert merge_query_stats([]).requests == 0
    # self-merge must not deadlock (snapshot, then combine)
    d = a.merge(a)
    assert d.requests == 20 and d.batches == 4


def _tstats(submitted, admitted, shed, completed, failed, inflight,
            kinds=(), lat=()):
    st = TraversalStats()
    (st.submitted, st.admitted, st.shed, st.completed, st.failed,
     st.inflight) = (submitted, admitted, shed, completed, failed,
                     inflight)
    for k in kinds:
        st.requests_by_kind[k] = st.requests_by_kind.get(k, 0) + 1
    for v in lat:
        st.latencies.add(v)
    return st


def test_traversal_stats_merge_sums_and_conserves():
    a = _tstats(5, 4, 1, 3, 0, 1, ["khop", "bfs"], [0.1])
    b = _tstats(7, 5, 2, 4, 1, 0, ["khop"], [0.2, 0.3])
    assert a.conserved and b.conserved
    m = a.merge(b)
    assert (m.submitted, m.admitted, m.shed) == (12, 9, 3)
    assert (m.completed, m.failed, m.inflight) == (7, 1, 1)
    assert m.conserved
    assert m.requests_by_kind == {"khop": 2, "bfs": 1}
    assert m.latencies.n == 3
    left = a.merge(b).merge(a)
    right = a.merge(b.merge(a))
    assert left.as_dict() == right.as_dict()


def test_query_stats_concurrent_merge_vs_fold_vs_reset():
    """Engine-style folds + periodic reset() + periodic merge
    snapshots, all racing: every merged snapshot satisfies
    sum(close_reasons) == batches, and folded + reset-absorbed batches
    reconcile exactly at the end — nothing lost, nothing doubled."""
    st = QueryStats()
    N_FOLDS, N_THREADS = 400, 4
    absorbed = []          # reset() snapshots (the drained history)
    bad = []

    def fold():
        for _ in range(N_FOLDS):
            with st._lock:     # exactly how the engine folds a batch
                st.requests += 3
                st.batches += 1
                st.close_reasons["direct"] = \
                    st.close_reasons.get("direct", 0) + 1
                st.latencies.add(0.001)

    def resetter():
        for _ in range(50):
            absorbed.append(st.reset())

    def merger():
        for _ in range(100):
            m = st.merge(st)   # snapshot-based: safe, non-blocking
            if sum(m.close_reasons.values()) != m.batches:
                bad.append(m)

    threads = [threading.Thread(target=fold) for _ in range(N_THREADS)]
    threads += [threading.Thread(target=resetter),
                threading.Thread(target=merger)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not bad, "a merged snapshot tore the close_reasons invariant"
    total = merge_query_stats(absorbed + [st])
    assert total.batches == N_FOLDS * N_THREADS
    assert total.requests == 3 * N_FOLDS * N_THREADS
    assert total.close_reasons == {"direct": N_FOLDS * N_THREADS}
    assert sum(total.close_reasons.values()) == total.batches
    # latency samples reconcile too: reset/merge never drop or double
    assert total.latencies.n == N_FOLDS * N_THREADS


def test_traversal_stats_concurrent_merge_vs_reset():
    """Service-style request lifecycles + reset() + merge, racing: every
    merge sees a conserved snapshot and the final fold of all reset
    snapshots plus the live object loses no request."""
    st = TraversalStats()
    N_REQ = 300
    absorbed, bad = [], []

    def lifecycle():
        for i in range(N_REQ):
            with st._lock:
                st.submitted += 1
                st.admitted += 1
                st.inflight += 1
            with st._lock:
                st.inflight -= 1
                st.completed += 1
                st.latencies.add(0.001)

    def resetter():
        for _ in range(40):
            absorbed.append(st.reset())

    def merger():
        for _ in range(80):
            m = st.merge(st)
            if not m.conserved:
                bad.append(m.as_dict())

    threads = [threading.Thread(target=lifecycle) for _ in range(3)]
    threads += [threading.Thread(target=resetter),
                threading.Thread(target=merger)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not bad, f"merge saw a torn snapshot: {bad[:1]}"
    total = TraversalStats()
    for s in absorbed + [st]:
        total = total.merge(s)
    assert total.submitted == total.admitted == 3 * N_REQ
    assert total.completed == 3 * N_REQ
    assert total.inflight == 0 and total.shed == 0
    assert total.conserved
    assert total.latencies.n == 3 * N_REQ


def test_latency_histogram_merge_exactly_associative_and_bounded():
    """The histogram replaces the old untrimmed-list concatenation: its
    merge must be EXACTLY associative (bit-for-bit, not approximately —
    integer bucket counts and min/max only), its memory bounded by the
    bucket table regardless of sample count, and the merged quantiles a
    pure function of the merged state (fold order invisible)."""
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-6.0, sigma=2.0, size=9000)
    parts = [LatencyHistogram() for _ in range(3)]
    for i, v in enumerate(samples):
        parts[i % 3].add(float(v))
    a, b, c = parts
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left == right == c.merge(a).merge(b)
    assert left.n == samples.size
    # bounded: bucket count can never exceed the fixed table size
    from repro.obs.metrics import HIST_N_BUCKETS
    assert len(left.counts) <= HIST_N_BUCKETS + 2
    # quantiles of the fold match quantiles of one big histogram
    one = LatencyHistogram()
    for v in samples:
        one.add(float(v))
    assert left.quantile(0.5) == one.quantile(0.5)
    assert left.quantile(0.99) == one.quantile(0.99)


def test_latency_quantile_pins_old_list_behavior():
    """Regression pin for the list -> histogram swap: p50/p99 stay
    within one bucket width (2%) of the exact np.quantile values the
    bench gates were tuned on, and are EXACT for the constant
    virtual-clock distributions the unit tests pin."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-5.5, sigma=1.5, size=8000)
    st = _qstats(lat=[float(v) for v in samples])
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        est = st.latency_quantile(q)
        assert abs(est - exact) <= 0.021 * exact, (q, exact, est)
    # constant distribution: exact (clamped to observed min/max)
    st2 = _qstats(lat=[0.00308] * 37)
    assert st2.latency_quantile(0.5) == pytest.approx(0.00308, abs=0)
    assert st2.latency_quantile(0.99) == pytest.approx(0.00308, abs=0)
    # empty: 0.0, matching the old empty-list behavior
    assert QueryStats().latency_quantile(0.5) == 0.0
    assert TraversalStats().latency_quantile(0.99) == 0.0
    # the as_dict surface agrees with latency_quantile
    d = st2.as_dict()
    assert d["p50_s"] == st2.latency_quantile(0.5)
    assert d["n_latencies"] == 37
