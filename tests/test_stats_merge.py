"""Associative stats aggregation — the accounting layer the sharded
service's fleet totals stand on.

``QueryStats.merge`` / ``TraversalStats.merge`` must be associative
(fold order across shards cannot change the totals), invariant-
preserving (``sum(close_reasons) == batches``; both traversal
conservation identities), and safe against the two concurrent
mutations a live service performs: per-batch folds and atomic
``reset()``.  The stress tests here race all three and demand that no
batch is ever lost or double-counted and that every merged snapshot
satisfies the invariants at every instant.
"""

import threading

import numpy as np
import pytest

from repro.query import QueryStats, TraversalStats, merge_query_stats


def _qstats(requests=0, unique=0, batches=0, reasons=(), lat=()):
    st = QueryStats()
    st.requests, st.unique_vertices, st.batches = requests, unique, batches
    for r in reasons:
        st.close_reasons[r] = st.close_reasons.get(r, 0) + 1
    st.latencies_s = list(lat)
    return st


def test_query_stats_merge_sums_and_preserves_invariant():
    a = _qstats(10, 4, 2, ["direct", "full"], [0.1, 0.2])
    b = _qstats(6, 3, 3, ["direct", "timeout", "direct"], [0.3])
    m = a.merge(b)
    assert (m.requests, m.unique_vertices, m.batches) == (16, 7, 5)
    assert m.close_reasons == {"direct": 3, "full": 1, "timeout": 1}
    assert sum(m.close_reasons.values()) == m.batches
    assert m.latencies_s == [0.1, 0.2, 0.3]
    # merge is a pure fold: operands untouched, result independent
    assert a.requests == 10 and b.requests == 6
    m.requests += 1
    assert a.requests == 10
    # identity: merging a zero element changes nothing
    assert a.merge(QueryStats()).as_dict() == a.as_dict()


def test_query_stats_merge_associative():
    a = _qstats(10, 4, 2, ["direct"] * 2, [0.1])
    b = _qstats(6, 3, 3, ["full"] * 3, [0.2, 0.4])
    c = _qstats(9, 9, 1, ["plateau"], [0.5])
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.as_dict() == right.as_dict()
    assert left.latencies_s == right.latencies_s
    # merge_query_stats is the same left fold
    assert merge_query_stats([a, b, c]).as_dict() == left.as_dict()
    assert merge_query_stats([]).requests == 0
    # self-merge must not deadlock (snapshot, then combine)
    d = a.merge(a)
    assert d.requests == 20 and d.batches == 4


def _tstats(submitted, admitted, shed, completed, failed, inflight,
            kinds=(), lat=()):
    st = TraversalStats()
    (st.submitted, st.admitted, st.shed, st.completed, st.failed,
     st.inflight) = (submitted, admitted, shed, completed, failed,
                     inflight)
    for k in kinds:
        st.requests_by_kind[k] = st.requests_by_kind.get(k, 0) + 1
    st.latencies_s = list(lat)
    return st


def test_traversal_stats_merge_sums_and_conserves():
    a = _tstats(5, 4, 1, 3, 0, 1, ["khop", "bfs"], [0.1])
    b = _tstats(7, 5, 2, 4, 1, 0, ["khop"], [0.2, 0.3])
    assert a.conserved and b.conserved
    m = a.merge(b)
    assert (m.submitted, m.admitted, m.shed) == (12, 9, 3)
    assert (m.completed, m.failed, m.inflight) == (7, 1, 1)
    assert m.conserved
    assert m.requests_by_kind == {"khop": 2, "bfs": 1}
    assert m.latencies_s == [0.1, 0.2, 0.3]
    left = a.merge(b).merge(a)
    right = a.merge(b.merge(a))
    assert left.as_dict() == right.as_dict()


def test_query_stats_concurrent_merge_vs_fold_vs_reset():
    """Engine-style folds + periodic reset() + periodic merge
    snapshots, all racing: every merged snapshot satisfies
    sum(close_reasons) == batches, and folded + reset-absorbed batches
    reconcile exactly at the end — nothing lost, nothing doubled."""
    st = QueryStats()
    N_FOLDS, N_THREADS = 400, 4
    absorbed = []          # reset() snapshots (the drained history)
    bad = []

    def fold():
        for _ in range(N_FOLDS):
            with st._lock:     # exactly how the engine folds a batch
                st.requests += 3
                st.batches += 1
                st.close_reasons["direct"] = \
                    st.close_reasons.get("direct", 0) + 1
                st.latencies_s.append(0.001)

    def resetter():
        for _ in range(50):
            absorbed.append(st.reset())

    def merger():
        for _ in range(100):
            m = st.merge(st)   # snapshot-based: safe, non-blocking
            if sum(m.close_reasons.values()) != m.batches:
                bad.append(m)

    threads = [threading.Thread(target=fold) for _ in range(N_THREADS)]
    threads += [threading.Thread(target=resetter),
                threading.Thread(target=merger)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not bad, "a merged snapshot tore the close_reasons invariant"
    total = merge_query_stats(absorbed + [st])
    assert total.batches == N_FOLDS * N_THREADS
    assert total.requests == 3 * N_FOLDS * N_THREADS
    assert total.close_reasons == {"direct": N_FOLDS * N_THREADS}
    assert sum(total.close_reasons.values()) == total.batches


def test_traversal_stats_concurrent_merge_vs_reset():
    """Service-style request lifecycles + reset() + merge, racing: every
    merge sees a conserved snapshot and the final fold of all reset
    snapshots plus the live object loses no request."""
    st = TraversalStats()
    N_REQ = 300
    absorbed, bad = [], []

    def lifecycle():
        for i in range(N_REQ):
            with st._lock:
                st.submitted += 1
                st.admitted += 1
                st.inflight += 1
            with st._lock:
                st.inflight -= 1
                st.completed += 1
                st.latencies_s.append(0.001)

    def resetter():
        for _ in range(40):
            absorbed.append(st.reset())

    def merger():
        for _ in range(80):
            m = st.merge(st)
            if not m.conserved:
                bad.append(m.as_dict())

    threads = [threading.Thread(target=lifecycle) for _ in range(3)]
    threads += [threading.Thread(target=resetter),
                threading.Thread(target=merger)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not bad, f"merge saw a torn snapshot: {bad[:1]}"
    total = TraversalStats()
    for s in absorbed + [st]:
        total = total.merge(s)
    assert total.submitted == total.admitted == 3 * N_REQ
    assert total.completed == 3 * N_REQ
    assert total.inflight == 0 and total.shed == 0
    assert total.conserved


def test_merge_untrimmed_latencies_keep_associativity():
    """merge() concatenates latency samples UNTRIMMED: trimming to the
    rolling window inside merge would make (a+b)+c drop different
    samples than a+(b+c).  The window applies at fold time (engine) and
    quantile time, never inside the fold."""
    from repro.query.engine import LATENCY_WINDOW
    a = _qstats(lat=[0.1] * LATENCY_WINDOW)
    b = _qstats(lat=[0.2] * LATENCY_WINDOW)
    c = _qstats(lat=[0.3])
    left, right = a.merge(b).merge(c), a.merge(b.merge(c))
    assert len(left.latencies_s) == 2 * LATENCY_WINDOW + 1
    assert left.latencies_s == right.latencies_s
