"""Deterministic load/soak lockdown for traversal admission control.

The closed-loop generator (:mod:`repro.query.loadgen`) replays whole
serving scenarios on a virtual clock, so the admission gate's two
promises become exact CI-gateable assertions rather than wall-clock
luck:

* **overload surfaces as shedding** — shed rate rises with offered
  load, it never collapses latency;
* **admitted requests keep the SLO** — admitted-request p99 stays
  under ``plan.slo_s`` even at 24x the sustainable client count;
* **nothing is lost** — ``admitted + shed == submitted`` and
  ``completed + failed + inflight == admitted`` on the service's own
  counters, and the generator's view agrees with the service's;
* **bit-for-bit reproducible** — the same seed yields the identical
  report, latencies included.
"""

import numpy as np

from repro.core import paragrapher
from repro.core.policy import choose_admission
from repro.graph import rmat
from repro.query import (LoadGenerator, NeighborQueryEngine,
                         TraversalRequest, TraversalService)

SLO_S = 0.02
EDGE_BUDGET = 8192
PLAN = choose_admission(SLO_S, edge_budget=EDGE_BUDGET,
                        service_edges_per_s=5.0e6, servers=1)


def _make_request(rng: np.random.Generator, client_id: int):
    """Zipf-hot khop traffic (the cache-friendly seed mix real query
    logs show), bounded by the plan's per-request edge budget."""
    n = 512
    seeds = np.minimum(rng.zipf(1.8, size=3) - 1, n - 1)
    return TraversalRequest("khop", seeds, k=2, max_edges=EDGE_BUDGET)


def _run(graph_file, *, n_clients, think_s, seed=7, horizon_s=1.0):
    g = paragrapher.open_graph(graph_file, use_pgfuse=True,
                               pgfuse_block_size=1 << 12,
                               pgfuse_readahead=0,
                               pgfuse_eviction="clock")
    engine = NeighborQueryEngine(g, decode="host")
    svc = TraversalService(engine, admission=PLAN)
    try:
        gen = LoadGenerator(svc, _make_request, n_clients=n_clients,
                            horizon_s=horizon_s, think_s=think_s,
                            backoff_s=0.01, seed=seed)
        report = gen.run()
        return report, svc.stats.as_dict()
    finally:
        svc.close(), engine.close(), g.close()


def _graph(tmp_path):
    csr = rmat(9, 6, seed=3)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    return gp


def test_plan_arithmetic():
    """The gate is sized from the bounded-queue arithmetic: with
    t_req = 2.0 * 8192 / 5e6 s, one server and a 20 ms SLO admit
    floor(slo / t_req) = 6 concurrent requests."""
    assert PLAN.max_inflight == 6
    assert PLAN.max_edges_inflight == 6 * EDGE_BUDGET
    assert PLAN.servers == 1 and PLAN.slo_s == SLO_S


def test_light_load_admits_everything_under_slo(tmp_path):
    report, st = _run(_graph(tmp_path), n_clients=2, think_s=0.005)
    assert report.submitted > 50          # the loop really ran
    assert report.shed == 0               # 2 clients < 6 slots: no shedding
    assert report.completed == report.admitted == report.submitted
    assert report.p99_s <= SLO_S
    # generator's view == service's own counters
    assert st["submitted"] == report.submitted
    assert st["shed"] == 0 and st["inflight"] == 0
    assert st["submitted"] == st["admitted"] + st["shed"]
    assert st["admitted"] == st["completed"] + st["failed"]


def test_overload_sheds_but_admitted_requests_keep_slo(tmp_path):
    gp = _graph(tmp_path)
    light, _ = _run(gp, n_clients=2, think_s=0.005)
    heavy, st = _run(gp, n_clients=48, think_s=0.0)
    # overload surfaces as shedding, and MORE of it than light load
    assert heavy.shed > 0
    assert heavy.shed_rate > light.shed_rate
    assert heavy.shed_rate > 0.5          # 48 clients vs 6 slots
    # ...while every admitted request still keeps the SLO (queueing
    # delay included): the gate bounds in-flight work so p99 <= slo
    assert heavy.p99_s <= SLO_S
    assert light.p99_s <= SLO_S
    # conservation on the service's own counters, under churn
    assert st["submitted"] == st["admitted"] + st["shed"]
    assert st["admitted"] == st["completed"] + st["failed"]
    assert st["inflight"] == 0
    assert st["shed_rate"] == heavy.shed_rate
    # the shed requests were really refused work: admitted bounded by
    # what one virtual server can finish within the horizon
    assert heavy.admitted < heavy.submitted
    assert heavy.completed == heavy.admitted


def test_same_seed_is_bit_identical(tmp_path):
    """The whole simulated day is deterministic: same seed, same graph,
    same config => the identical report (every latency sample, every
    shed decision), so p50/p99/shed-rate can be CI-gated as numbers."""
    gp = _graph(tmp_path)
    a, sa = _run(gp, n_clients=16, think_s=0.001, seed=11)
    b, sb = _run(gp, n_clients=16, think_s=0.001, seed=11)
    assert a.as_dict() == b.as_dict()
    assert a.latencies_s == b.latencies_s
    assert sa == sb
    # a different seed shifts the trace (the determinism above is not
    # vacuous)
    c, _ = _run(gp, n_clients=16, think_s=0.001, seed=12)
    assert c.latencies_s != a.latencies_s


def test_service_latency_window_sees_virtual_latencies(tmp_path):
    """``svc.complete`` folds the generator's virtual latencies into
    ``TraversalStats``, so the service's own p99 is the gated one."""
    report, st = _run(_graph(tmp_path), n_clients=8, think_s=0.001)
    assert st["n_latencies"] > 0
    assert st["p99_s"] <= SLO_S
    assert st["p50_s"] <= st["p99_s"]
