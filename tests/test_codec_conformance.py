"""Codec conformance: every registered codec honors one contract.

The codec registry (:mod:`repro.core.codec`) is only worth its seam if
each codec is interchangeable behind it, so this module parametrizes the
load-bearing CompBin property/differential tests over EVERY registered
codec — and, for direct-addressing codecs, over the query engine:

* encode/decode roundtrip through the registry's write/open surface,
  including the empty graph;
* direct addressing: ``neighbors_of``/``read_partition`` against the
  in-memory CSR, for any vertex;
* engine-vs-CSR byte identity through PG-Fuse, host AND device decode
  arms (the differential the serving path stands on);
* storage-fault behavior: a transient EIO surfaces (and retries heal
  it), a short read raises IOError — identical contracts whichever
  codec is under the cache;
* the graph compiler's permutation round-trip property: reorder ->
  query in compiled-id space -> inverse-map == the original answers,
  for every (strategy, codec) pair.
"""

import io
import os

import numpy as np
import pytest

from repro.core import codec, paragrapher, pgfuse
from repro.core.csr import csr_from_edges
from repro.graph import reorder
from tests._prop import Draw
from tests.conftest import FaultyStorage

ALL_CODECS = sorted(codec.registered_codecs())
DIRECT_CODECS = codec.direct_codecs()

RANDOM_KW = dict(use_pgfuse=True, pgfuse_block_size=1 << 12,
                 pgfuse_readahead=0, pgfuse_eviction=pgfuse.EVICT_CLOCK)


def _graph(draw, max_v=2000, max_e=8000):
    nv = draw.int(2, max_v)
    ne = draw.int(0, max_e)
    # dedupe: WebGraph requires strictly increasing successor lists
    return csr_from_edges(draw.ints(0, nv - 1, ne),
                          draw.ints(0, nv - 1, ne), nv, dedupe=True)


@pytest.mark.parametrize("name", ALL_CODECS)
@pytest.mark.parametrize("case", range(8))
def test_registry_roundtrip(name, case):
    """write -> open -> read_full is the identity for every codec."""
    draw = Draw(np.random.default_rng(1000 + case))
    spec = codec.get_codec(name)
    csr = _graph(draw)
    buf = io.BytesIO()
    n = spec.write(buf, csr)
    assert n == len(buf.getvalue())
    if spec.nbytes is not None:
        assert n == spec.nbytes(csr.n_vertices, csr.n_edges)
    rdr = spec.open(io.BytesIO(buf.getvalue()))
    assert (rdr.n_vertices, rdr.n_edges) == (csr.n_vertices, csr.n_edges)
    assert rdr.read_full() == csr
    rdr.close()


@pytest.mark.parametrize("name", ALL_CODECS)
def test_registry_roundtrip_empty_graph(name):
    spec = codec.get_codec(name)
    csr = csr_from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), 0)
    buf = io.BytesIO()
    spec.write(buf, csr)
    rdr = spec.open(io.BytesIO(buf.getvalue()))
    assert rdr.read_full() == csr
    rdr.close()


@pytest.mark.parametrize("name", ALL_CODECS)
def test_magic_dispatch(name, tmp_path):
    """detect_format routes every codec's file back to it by magic."""
    spec = codec.get_codec(name)
    csr = csr_from_edges(np.array([0, 1]), np.array([1, 2]), 3)
    path = str(tmp_path / f"g.{spec.suffix}")
    spec.write(path, csr)
    assert paragrapher.detect_format(path) == name
    assert codec.codec_for_magic(open(path, "rb").read(4)) is spec


@pytest.mark.parametrize("name", DIRECT_CODECS)
@pytest.mark.parametrize("case", range(8))
def test_direct_addressing_random_access(name, case):
    """O(1) adjacency access (the paper's key CompBin property) holds
    for every direct codec, plus partition reads and offsets."""
    draw = Draw(np.random.default_rng(1000 + case))
    spec = codec.get_codec(name)
    csr = _graph(draw)
    buf = io.BytesIO()
    spec.write(buf, csr)
    rdr = spec.open(io.BytesIO(buf.getvalue()))
    for v in draw.ints(0, csr.n_vertices - 1, 8):
        np.testing.assert_array_equal(
            rdr.neighbors_of(int(v)).astype(np.int64),
            csr.neighbors_of(int(v)).astype(np.int64))
    v0 = draw.int(0, csr.n_vertices - 1)
    v1 = draw.int(v0, csr.n_vertices)
    offs, nbrs = rdr.read_partition(v0, v1)
    assert offs[0] == 0 and offs[-1] == len(nbrs)
    np.testing.assert_array_equal(
        nbrs.astype(np.int64),
        csr.neighbors[csr.offsets[v0]:csr.offsets[v1]].astype(np.int64))
    np.testing.assert_array_equal(rdr.offsets(v0, v1),
                                  csr.offsets[v0:v1 + 1])
    # raw bytes decode back through the codec-agnostic eq. (1) path
    raw = rdr.raw_neighbor_bytes(0, csr.n_edges)
    from repro.core import compbin
    np.testing.assert_array_equal(
        compbin.decode_ids(raw, rdr.b).astype(np.int64),
        csr.neighbors.astype(np.int64))
    rdr.close()


@pytest.mark.parametrize("name", DIRECT_CODECS)
def test_engine_byte_identity_host_and_device(name, tmp_path):
    """The engine's host and device decode arms return answers byte-
    identical to the in-memory CSR over every direct codec."""
    from repro.query import NeighborQueryEngine

    rng = np.random.default_rng(11)
    nv, ne = 1500, 12000
    csr = csr_from_edges(rng.integers(0, nv, ne), rng.integers(0, nv, ne),
                         nv)
    spec = codec.get_codec(name)
    path = str(tmp_path / f"g.{spec.suffix}")
    spec.write(path, csr)
    ids = rng.integers(0, nv, 400)
    with paragrapher.open_graph(path, **RANDOM_KW) as g:
        assert g.format == name
        assert g.bytes_per_id == spec.open(path).b
        for mode in ("host", "device"):
            with NeighborQueryEngine(g, decode=mode) as eng:
                for v, got in zip(ids, eng.neighbors_batch(ids)):
                    want = csr.neighbors[csr.offsets[v]:csr.offsets[v + 1]]
                    np.testing.assert_array_equal(
                        got, want.astype(np.int64), err_msg=f"{mode} v={v}")


@pytest.mark.parametrize("name", DIRECT_CODECS)
def test_faulty_storage_eio_and_short_read(name, tmp_path):
    """Storage-fault contracts are codec-independent: with retries a
    transient EIO heals invisibly; without, EIO propagates; a short
    read always surfaces as IOError."""
    import errno

    rng = np.random.default_rng(5)
    nv, ne = 400, 3000
    csr = csr_from_edges(rng.integers(0, nv, ne), rng.integers(0, nv, ne),
                         nv)
    spec = codec.get_codec(name)
    path = str(tmp_path / f"g.{spec.suffix}")
    spec.write(path, csr)

    # probe the LAST vertex: its neighbor bytes sit past the first
    # PG-Fuse block, so the lookup must hit backing storage (vertex 7
    # would be served from the block cached by the open-time header read)
    probe = nv - 1

    # transient EIO + retries: the answer is unaffected
    with paragrapher.open_graph(path, **RANDOM_KW,
                                pgfuse_retries=2) as g:
        faults = FaultyStorage()
        faults.install_graph(g)
        faults.fail_at[1] = OSError(errno.EIO, "flaky OST")
        got = g.neighbors_of(probe)
        np.testing.assert_array_equal(
            got.astype(np.int64),
            csr.neighbors_of(probe).astype(np.int64))
        assert faults.n_calls >= 2   # the retry actually happened

    # EIO without retries propagates
    with paragrapher.open_graph(path, **RANDOM_KW) as g:
        faults = FaultyStorage()
        faults.install_graph(g)
        faults.fail_at[1] = OSError(errno.EIO, "flaky OST")
        with pytest.raises(OSError):
            g.neighbors_of(probe)

    # short read surfaces as IOError, never silent truncation
    with paragrapher.open_graph(path, **RANDOM_KW) as g:
        faults = FaultyStorage()
        faults.install_graph(g)
        faults.truncate_at[1] = 3
        with pytest.raises(IOError):
            g.neighbors_of(probe)


@pytest.mark.parametrize("name", DIRECT_CODECS)
@pytest.mark.parametrize("strategy", ["bfs", "degree", "identity"])
@pytest.mark.parametrize("case", range(3))
def test_permutation_roundtrip_property(name, strategy, case, tmp_path):
    """The compiler's invariant: reorder -> encode -> query in compiled
    ids -> inverse-map == the ORIGINAL graph's answers, byte for byte,
    for every (strategy, codec) pair."""
    draw = Draw(np.random.default_rng(1000 + case))
    csr = _graph(draw, max_v=600, max_e=3000)
    src = str(tmp_path / f"in_{case}.cbin")
    out = str(tmp_path / f"out_{case}.{codec.get_codec(name).suffix}")
    paragrapher.save_graph(src, csr, format="compbin")
    report = reorder.compile_graph(src, out, codec=name,
                                   strategy=strategy, verify_samples=8)
    assert report.strategy == strategy
    assert os.path.exists(report.sidecar_path)
    old_of_new = reorder.read_sidecar(report.sidecar_path)
    new_of_old = reorder.invert_permutation(old_of_new)
    with paragrapher.open_graph(out) as g:
        assert g.format == name
        for v in draw.ints(0, csr.n_vertices - 1, 12):
            got = reorder.map_back(
                old_of_new, g.neighbors_of(int(new_of_old[v])))
            want = np.sort(csr.neighbors_of(int(v)).astype(np.int64))
            np.testing.assert_array_equal(got, want)


def test_webgraph_not_direct():
    """The sequential codec keeps refusing the random-access surface."""
    assert not codec.get_codec("webgraph").direct
    assert set(DIRECT_CODECS) == {"compbin", "logcsr"}


@pytest.mark.parametrize("name", DIRECT_CODECS)
def test_stream_decode_policy_covers_codec(name):
    """Every direct codec has a stream-decode placement (device for
    b<=4) and a registered device stream decoder behind the op surface."""
    from repro.core import policy
    from repro.kernels.compbin_decode import packed_stream_decoder

    assert policy.choose_stream_decode(name, 2).device
    assert not policy.choose_stream_decode(name, 5).device
    assert callable(packed_stream_decoder(name))
