"""Deterministic multi-tenant soak: two engines on ONE PG-Fuse mount
under per-engine budgets (the PR-5 tentpole's cache-shares layer).

Everything runs on an injectable virtual clock (PGFuseFS(clock=...)), so
eviction order — and therefore every assertion — is a property of the
access sequence alone.  The soak loops scans long past the budgets and
asserts the three invariants the share layer exists for:

* **isolation** — neither tenant's churn ever evicts the other tenant's
  warm set (the share is a reservation);
* **conservation** — the mount's resident accounting equals the sum of
  its files' at every step, every share stays at/below its budget after
  enforcement, and the mount stays inside its global budget;
* **termination** — clock-hand sweeps and share enforcement finish even
  when every block is pinned or every ref bit is set (no livelock).
"""

import numpy as np
import pytest

from repro.core import featstore, paragrapher, pgfuse
from repro.graph import featstore_for_graph, rmat
from repro.query import NeighborQueryEngine, gather_rows

BS = 1024


def _blob(tmp_path, name: str, n_blocks: int, seed: int):
    rng = np.random.default_rng(seed)
    p = tmp_path / name
    p.write_bytes(rng.integers(0, 256, n_blocks * BS,
                               dtype=np.uint8).tobytes())
    return str(p)


def test_two_tenant_soak_isolation_and_conservation(tmp_path):
    """Looped scans on a virtual clock: tenant A's warm set survives 50
    rounds of tenant B churning 4x its own share; budgets hold and
    accounting stays exact at every round."""
    hot_a = _blob(tmp_path, "a.bin", 4, 0)
    scan_b = _blob(tmp_path, "b.bin", 32, 1)
    vclock = [0.0]

    def tick() -> float:
        vclock[0] += 1.0
        return vclock[0]

    fs = pgfuse.PGFuseFS(block_size=BS, max_resident_bytes=12 * BS,
                         eviction="clock", clock=lambda: vclock[0])
    with fs:
        share_a = fs.register_engine("model-a", 4 * BS)
        share_b = fs.register_engine("model-b", 8 * BS)
        cf_a = share_a.mount(hot_a)
        cf_b = share_b.mount(scan_b)
        for _round in range(50):
            for b in range(4):          # tenant A touches its warm set
                cf_a.pread(b * BS, 64)
                tick()
            for b in range(32):         # tenant B loops a 4x-budget scan
                cf_b.pread(b * BS, 64)
                tick()
                # B reclaims from ITSELF: never over its share
                assert share_b.resident_bytes <= 8 * BS
                # conservation: mount accounting is exactly the sum
                assert fs.resident_bytes == \
                    cf_a.resident_bytes + cf_b.resident_bytes
                assert fs.resident_bytes <= 12 * BS
            # isolation: B's churn never touched A's warm set
            assert set(cf_a.resident_blocks()) == set(range(4)), _round
            assert share_a.resident_bytes == 4 * BS
        # A was warm on every acquisition after round one
        assert cf_a.stats.cache_misses == 4
        assert cf_b.stats.evictions > 0  # B's budget actually bit


def test_share_budgets_resize_at_runtime(tmp_path):
    """Re-registering a share shrinks it immediately (serving fleets
    resize tenants without remounting), and files may not defect to
    another tenant's share."""
    f1 = _blob(tmp_path, "f1.bin", 8, 2)
    with pgfuse.PGFuseFS(block_size=BS, eviction="clock") as fs:
        share = fs.register_engine("m", 8 * BS)
        cf = share.mount(f1)
        cf.pread(0, 8 * BS)
        assert share.resident_bytes == 8 * BS
        fs.register_engine("m", 3 * BS)  # shrink: enforced right here
        assert share.resident_bytes <= 3 * BS
        assert fs.resident_bytes == cf.resident_bytes
        other = fs.register_engine("other", None)
        with pytest.raises(ValueError, match="at most one share"):
            other.add_file(cf)


def test_mount_by_engine_name_preserves_budget(tmp_path):
    """Joining a file to a share BY NAME (the open_graph(pgfuse_engine=
    "name") form) must not rewrite the tenant's budget — only an
    explicit re-register resizes it."""
    f1 = _blob(tmp_path, "f1.bin", 4, 7)
    f2 = _blob(tmp_path, "f2.bin", 4, 8)
    with pgfuse.PGFuseFS(block_size=BS, eviction="clock") as fs:
        share = fs.register_engine("m", 2 * BS)
        fs.mount(f1, engine="m")
        fs.mount(f2, engine="m")          # by name: budget untouched
        assert share.max_resident_bytes == 2 * BS
        assert fs.engine_share("m") is share
        fs.mount(f1).pread(0, 4 * BS)
        fs.mount(f2).pread(0, 4 * BS)
        assert share.resident_bytes <= 2 * BS  # the cap still bites
        assert fs.mount(f1).share is share
        # a budget-less register is a FETCH, never an uncap
        assert fs.register_engine("m") is share
        assert share.max_resident_bytes == 2 * BS
        # an unregistered name is a loud error, not a silent share — and
        # raising for a NEW path must not leak a half-mounted file/fd
        f3 = _blob(tmp_path, "f3.bin", 2, 9)
        with pytest.raises(ValueError, match="unknown engine share"):
            fs.mount(f3, engine="mispelled")
        assert f3 not in fs._files


def test_shared_mount_join_inherits_readahead(tmp_path):
    """open_graph(pgfuse_fs=...) without an explicit readahead inherits
    the mount default and never clobbers a live file's setting."""
    csr = rmat(7, 4, seed=2)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    with pgfuse.PGFuseFS(block_size=1 << 12, readahead=4) as fs:
        g1 = paragrapher.open_graph(gp, pgfuse_fs=fs)
        assert fs.mount(gp).readahead == 4       # mount default inherited
        g2 = paragrapher.open_graph(gp, pgfuse_fs=fs)  # second handle
        assert fs.mount(gp).readahead == 4       # still untouched
        g3 = paragrapher.open_graph(gp, pgfuse_fs=fs, pgfuse_readahead=0)
        assert fs.mount(gp).readahead == 0       # explicit override wins
        g1.close(), g2.close(), g3.close()


def test_sweeps_terminate_under_pins_and_ref_bits(tmp_path):
    """Clock-hand sweeps are bounded: with every block PINNED a sweep
    frees nothing and returns; with every ref bit set it frees on the
    second lap; share enforcement over pinned files returns too."""
    f1 = _blob(tmp_path, "f1.bin", 6, 3)
    with pgfuse.PGFuseFS(block_size=BS, eviction="clock") as fs:
        share = fs.register_engine("m", BS)  # absurdly tight
        cf = share.mount(f1)
        for b in range(6):  # pin everything (readers never release)
            cf.acquire_block(b)
        assert cf.sweep(10 * BS) == 0          # bounded, frees nothing
        assert share.enforce() == 0            # terminates over pins
        for b in range(6):
            cf.release_block(b)
        cf._ref[:] = True                      # every bit set: lap 1
        assert cf.sweep(2 * BS) >= 2 * BS      # clears, lap 2 revokes


def test_two_query_engines_share_one_mount(tmp_path):
    """The serving shape end to end: two NeighborQueryEngines (their
    graphs + feature stores) on ONE shared mount via
    open_graph(pgfuse_fs=..., pgfuse_engine=...); tenant B's gather
    churn leaves tenant A's warm topology resident, and both answer
    correctly throughout."""
    csr_a, csr_b = rmat(8, 4, seed=5), rmat(9, 6, seed=6)
    gp_a, gp_b = str(tmp_path / "a.cbin"), str(tmp_path / "b.cbin")
    paragrapher.save_graph(gp_a, csr_a, format="compbin")
    paragrapher.save_graph(gp_b, csr_b, format="compbin")
    fp_b = featstore_for_graph(gp_b, str(tmp_path / "b.fst"), 16, seed=0,
                               data_align=1 << 12)
    vclock = [0.0]
    fs = pgfuse.PGFuseFS(block_size=1 << 12, max_resident_bytes=64 << 12,
                         eviction="clock", clock=lambda: vclock[0])
    with fs:
        share_a = fs.register_engine("tenant-a", 16 << 12)
        share_b = fs.register_engine("tenant-b", 32 << 12)
        g_a = paragrapher.open_graph(gp_a, pgfuse_fs=fs, pgfuse_engine=share_a)
        g_b = paragrapher.open_graph(gp_b, pgfuse_fs=fs, pgfuse_engine=share_b)
        feats_b = featstore.open_featstore(fp_b, fs=fs, pgfuse_engine=share_b,
                                           pgfuse_file_readahead=0)
        eng_a = NeighborQueryEngine(g_a)
        eng_b = NeighborQueryEngine(g_b)
        # warm tenant A, snapshot its resident topology
        eng_a.neighbors_batch(np.arange(0, csr_a.n_vertices, 3))
        warm_a = set(fs.mount(gp_a).resident_blocks())
        assert warm_a
        rng = np.random.default_rng(0)
        for _ in range(20):  # tenant B churns queries + feature gathers
            vclock[0] += 1.0
            ids = rng.integers(0, csr_b.n_vertices, 128)
            for v, nbrs in zip(ids, eng_b.neighbors_batch(ids)):
                assert np.array_equal(nbrs, csr_b.neighbors_of(int(v)))
            gather_rows(feats_b, rng.integers(0, csr_b.n_vertices, 64))
            assert share_b.resident_bytes <= 32 << 12
        # isolation: A's warm set is untouched by B's churn, and A still
        # answers correctly without another storage miss
        assert set(fs.mount(gp_a).resident_blocks()) >= warm_a
        misses = fs.mount(gp_a).stats.cache_misses
        got = eng_a.neighbors_batch([1, 2, 3])
        for v, nbrs in zip([1, 2, 3], got):
            assert np.array_equal(nbrs, csr_a.neighbors_of(v))
        assert fs.mount(gp_a).stats.cache_misses == misses
        g_a.close()  # shared mount: closing A must not disturb B...
        got_b = eng_b.neighbors_batch([7])
        assert np.array_equal(got_b[0], csr_b.neighbors_of(7))
        # ...and must fully release A: a dead tenant's share holds no
        # files and charges nothing against the live tenants
        assert share_a.files() == [] and share_a.resident_bytes == 0
        g_b.close()
        feats_b.close()


def test_failed_open_unwinds_shared_mount(tmp_path):
    """A constructor that fails AFTER mounting (valid magic, corrupt
    header) must unwind its retain and share membership — there is no
    handle left to release them later."""
    bad_g = tmp_path / "bad.cbin"
    bad_g.write_bytes(b"CBIN" + b"\x00" * 4)      # truncated header
    bad_f = tmp_path / "bad.fst"
    bad_f.write_bytes(b"FSTR" + b"\x00" * 4)
    with pgfuse.PGFuseFS(block_size=1024) as fs:
        share = fs.register_engine("m", 4096)
        with pytest.raises(Exception):
            paragrapher.open_graph(str(bad_g), pgfuse_fs=fs,
                                   pgfuse_engine=share)
        with pytest.raises(Exception):
            featstore.open_featstore(str(bad_f), fs=fs, pgfuse_engine=share)
        assert share.files() == []
        assert fs._files == {} and fs._file_refs == {}
        assert fs.resident_bytes == 0


def test_featstore_replicas_close_independently(tmp_path):
    """Two handles over the SAME feature store on a shared mount (model
    replicas): the store's file is refcount-retained per handle, so the
    first close must not drop the second replica's cache."""
    csr = rmat(7, 4, seed=3)
    gp = str(tmp_path / "g.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    fp = featstore_for_graph(gp, str(tmp_path / "g.fst"), 8, seed=0,
                             data_align=1 << 12)
    with pgfuse.PGFuseFS(block_size=1 << 12) as fs:
        h1 = featstore.open_featstore(fp, fs=fs)
        h2 = featstore.open_featstore(fp, fs=fs)
        rows = h1.read_rows(0, 4)
        h1.close()
        misses = fs.mount(fp).stats.cache_misses
        assert np.array_equal(h2.read_rows(0, 4), rows)  # cache intact
        assert fs.mount(fp).stats.cache_misses == misses
        h2.close()   # last retainer: NOW the file unmounts
        assert fs.resident_bytes == 0


def test_shared_topology_survives_one_tenants_close(tmp_path):
    """Two engines over the SAME CompBin file on one mount (the shared
    file stays outside any EngineShare): closing tenant A's handle must
    not drop tenant B's warm cache — the mount refcounts retained files
    and truly unmounts only when the last handle closes."""
    csr = rmat(8, 5, seed=9)
    gp = str(tmp_path / "shared.cbin")
    paragrapher.save_graph(gp, csr, format="compbin")
    with pgfuse.PGFuseFS(block_size=1 << 12, eviction="clock") as fs:
        g_a = paragrapher.open_graph(gp, pgfuse_fs=fs)
        g_b = paragrapher.open_graph(gp, pgfuse_fs=fs)
        eng_b = NeighborQueryEngine(g_b)
        eng_b.neighbors_batch(np.arange(0, csr.n_vertices, 2))  # warm
        warm = fs.mount(gp).resident_bytes
        misses = fs.mount(gp).stats.cache_misses
        assert warm > 0
        g_a.close()
        # B's cache is intact and still serves without a storage miss
        assert fs.mount(gp).resident_bytes == warm
        got = eng_b.neighbors_batch([3, 4])
        for v, nbrs in zip([3, 4], got):
            assert np.array_equal(nbrs, csr.neighbors_of(v))
        assert fs.mount(gp).stats.cache_misses == misses
        g_b.close()   # last handle: NOW the file really unmounts
        assert fs.resident_bytes == 0


def test_tenant_server_close_releases_all_files(tmp_path):
    """make_gnn_server teardown on a SHARED mount drops every one of the
    tenant's files (graph AND feature store) — dead tenants must not
    keep share-protected bytes resident against live ones."""
    import jax  # noqa: F401  (server construction needs a jax backend)

    from repro.configs import get_arch
    from repro.launch.serve import make_gnn_server

    import os

    cfg = get_arch("gcn-cora").make_reduced()
    fs = pgfuse.PGFuseFS(block_size=1 << 16, eviction="clock",
                         max_resident_bytes=512 << 16)
    with fs:
        # no explicit engine_name: SAME-arch tenants must still land in
        # two distinct shares (default name is keyed by the asset dir)
        a1, _e1, c1 = make_gnn_server(
            "gcn-cora", cfg, str(tmp_path / "t1"), fanouts=(3, 2),
            fs=fs, engine_budget=128 << 16)
        a2, _e2, c2 = make_gnn_server(
            "gcn-cora", cfg, str(tmp_path / "t2"), fanouts=(3, 2),
            fs=fs, engine_budget=256 << 16)
        name1 = f"gcn-cora:{os.path.abspath(tmp_path / 't1')}"
        name2 = f"gcn-cora:{os.path.abspath(tmp_path / 't2')}"
        share1, share2 = fs.engine_share(name1), fs.engine_share(name2)
        assert share1 is not None and share2 is not None \
            and share1 is not share2
        assert share1.max_resident_bytes == 128 << 16
        assert share2.max_resident_bytes == 256 << 16  # no budget merge
        assert a1(np.arange(4)).shape[0] == 4   # warms t1's caches
        assert a2(np.arange(4)).shape[0] == 4
        assert share1.resident_bytes > 0
        c1()
        assert share1.files() == [] and share1.resident_bytes == 0
        # the live tenant is untouched and still serves
        assert share2.resident_bytes > 0
        assert a2(np.arange(4)).shape[0] == 4
        c2()
        assert fs.resident_bytes == 0
