"""Multi-host streamed loading (data/multihost.py simulator) and the
full storage -> PG-Fuse -> packed CompBin -> device decode -> train loop.

Tier-1 (fast) on purpose: the simulator is the only way the multi-host
path gets exercised without a real multi-process JAX cluster, so it must
run on every PR."""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import compbin, paragrapher
from repro.data.graph_stream import (StreamStats, assemble_csr, merge_stats,
                                     stream_partitions)
from repro.data.multihost import aggregate_stats, all_shards, simulate_hosts
from repro.graph import rmat

OPEN_KW = dict(use_pgfuse=True, pgfuse_block_size=1 << 14,
               pgfuse_readahead=2)


@pytest.fixture(scope="module")
def graph_file(tmp_path_factory):
    d = tmp_path_factory.mktemp("mh")
    csr = rmat(9, 6, seed=3)
    p = str(d / "g.cbin")
    paragrapher.save_graph(p, csr, format="compbin")
    return p, csr


# ---------------------------------------------------------------------------
# the simulator: coverage, determinism, stats aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hosts", [2, 3])
def test_hosts_cover_graph_disjointly_and_reassemble(graph_file, hosts):
    path, csr = graph_file
    results = simulate_hosts(path, hosts, open_kwargs=OPEN_KW, n_parts=8)
    assert [r.process_index for r in results] == list(range(hosts))
    # ranges: contiguous, disjoint, covering [0, |V|)
    cursor = 0
    for r in results:
        if not r.plan:
            continue
        assert r.host_range[0] == cursor
        cursor = r.host_range[1]
    assert cursor == csr.n_vertices
    # the union of every host's device shards is the whole graph, byte-exact
    assert assemble_csr(all_shards(results)) == csr


def test_multihost_zero_host_decode_for_compbin(graph_file):
    path, csr = graph_file
    before = compbin.host_decoded_bytes()
    results = simulate_hosts(path, 2, open_kwargs=OPEN_KW, n_parts=8)
    assert compbin.host_decoded_bytes() - before == 0
    for r in results:
        assert r.stats.decode_mode == "device"
        assert r.stats.host_decode_bytes == 0


def test_per_host_stats_sum_to_single_host_totals(graph_file):
    """The acceptance invariant: per-process StreamStats are reported per
    host and their merge reproduces the single-host totals — exactly for
    plan/shard/transfer counters, and exactly for total block
    acquisitions (hits + misses), which is a pure function of the reads
    issued no matter how they are split across private caches."""
    path, csr = graph_file
    results = simulate_hosts(path, 2, open_kwargs=OPEN_KW, n_parts=8)
    single = simulate_hosts(path, 1, open_kwargs=OPEN_KW, n_parts=8)[0]
    agg = aggregate_stats(results)
    one = single.stats

    for r in results:  # reported per process, each with real traffic
        assert r.stats.partitions > 0
        assert r.stats.bytes_h2d > 0
        assert r.stats.cache_hits + r.stats.cache_misses > 0
    assert agg.partitions == one.partitions > 1
    assert agg.vertices == one.vertices == csr.n_vertices
    assert agg.edges == one.edges == csr.n_edges
    assert agg.bytes_h2d == one.bytes_h2d
    assert agg.host_decode_bytes == one.host_decode_bytes == 0
    assert (agg.cache_hits + agg.cache_misses
            == one.cache_hits + one.cache_misses)


def test_host_decode_stats_are_per_stream_under_concurrency(graph_file):
    """Forced host decode on concurrent simulated hosts: each host's
    host_decode_bytes must count only ITS packed bytes (a process-global
    counter delta would cross-contaminate overlapping hosts) and sum
    exactly to the single-host total (= n_edges * bytes_per_id)."""
    from repro.core import policy

    path, csr = graph_file
    plan = policy.StreamDecodePlan("host", "test: force host decode")
    results = simulate_hosts(path, 2, open_kwargs=OPEN_KW, n_parts=8,
                             decode_plan=plan)
    single = simulate_hosts(path, 1, open_kwargs=OPEN_KW, n_parts=8,
                            decode_plan=plan)[0]
    with paragrapher.open_graph(path) as g:
        b = g.bytes_per_id
    for r in results:
        assert r.stats.host_decode_bytes == r.stats.edges * b
    agg = aggregate_stats(results)
    assert agg.host_decode_bytes == single.stats.host_decode_bytes \
        == csr.n_edges * b


def test_sequential_equals_concurrent_simulation(graph_file):
    path, csr = graph_file
    conc = simulate_hosts(path, 2, open_kwargs=OPEN_KW, n_parts=8)
    seq = simulate_hosts(path, 2, open_kwargs=OPEN_KW, n_parts=8,
                         concurrent=False)
    for a, b in zip(conc, seq):
        assert a.plan == b.plan
        assert a.host_range == b.host_range
        assert assemble_csr(a.shards) == assemble_csr(b.shards)
        assert a.stats.bytes_h2d == b.stats.bytes_h2d


def test_more_hosts_than_partitions(graph_file):
    path, csr = graph_file
    results = simulate_hosts(path, 5, open_kwargs=OPEN_KW, n_parts=3)
    assert assemble_csr(all_shards(results)) == csr
    empty = [r for r in results if not r.plan]
    for r in empty:  # hosts with nothing to stream report quietly
        assert r.shards == []
        assert r.stats.partitions == 0
        assert r.stats.decode_edges_per_s == 0.0


def test_stream_process_args_validated(graph_file):
    path, _ = graph_file
    with paragrapher.open_graph(path) as g:
        with pytest.raises(ValueError):
            stream_partitions(g, None, process_index=2, process_count=2)
    with pytest.raises(ValueError):
        simulate_hosts(path, 0)


# ---------------------------------------------------------------------------
# StreamStats: zero-duration guards + associative merge
# ---------------------------------------------------------------------------

def test_stream_stats_zero_duration_guards():
    s = StreamStats(edges=1000, bytes_h2d=4096, decode_s=0.0, wall_s=0.0)
    assert s.decode_edges_per_s == 0.0
    assert s.h2d_bytes_per_s == 0.0
    assert s.edges_per_s == 0.0
    d = s.as_dict()
    assert d["decode_edges_per_s"] == 0.0 and d["h2d_bytes_per_s"] == 0.0
    live = StreamStats(edges=1000, decode_s=0.5, wall_s=2.0, bytes_h2d=4096)
    assert live.decode_edges_per_s == 2000.0
    assert live.h2d_bytes_per_s == 2048.0


def test_stream_stats_merge_associative_and_commutative_totals():
    from tests._prop import Draw, prop

    @prop(n_cases=50)
    def check(draw: Draw):
        def rand_stats():
            # durations drawn as multiples of 1/4 so float addition is
            # exact and associativity can be asserted with ==
            return StreamStats(
                partitions=draw.int(0, 5), vertices=draw.int(0, 100),
                edges=draw.int(0, 1000), cache_hits=draw.int(0, 50),
                cache_misses=draw.int(0, 50), bytes_h2d=draw.int(0, 4096),
                underlying_reads=draw.int(0, 9),
                underlying_bytes=draw.int(0, 1 << 16),
                readahead_blocks=draw.int(0, 9),
                host_decode_bytes=draw.int(0, 512),
                decode_s=draw.int(0, 8) / 4, wall_s=draw.int(0, 8) / 4,
                decode_mode=draw.choice(["device", "host"]))

        a, b, c = rand_stats(), rand_stats(), rand_stats()
        assert a.merge(b).merge(c) == a.merge(b.merge(c))
        # totals are order-independent even where tie-break strings differ
        x, y = a.merge(b), b.merge(a)
        assert dataclasses.replace(x, decode_mode="", decode_reason="") == \
            dataclasses.replace(y, decode_mode="", decode_reason="")

    check()


def test_merge_stats_fold_and_mode_collapse():
    dev = StreamStats(edges=5, decode_mode="device", wall_s=1.0)
    host = StreamStats(edges=7, decode_mode="host", wall_s=3.0)
    m = merge_stats([dev, host])
    assert m.edges == 12
    assert m.decode_mode == "mixed"
    assert m.wall_s == 3.0          # hosts run concurrently: max, not sum
    assert merge_stats([dev]).decode_mode == "device"
    assert merge_stats([]) == StreamStats()


# ---------------------------------------------------------------------------
# the acceptance test: end-to-end gcn-cora full-graph training from
# CompBin through the streamed path on a simulated 2-host mesh
# ---------------------------------------------------------------------------

def test_e2e_gcn_cora_full_graph_train_from_compbin_two_hosts(graph_file):
    import jax
    from jax.sharding import Mesh

    from repro.launch.data_gnn import streamed_graph_batch
    from repro.models.gnn import gcn
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    path, csr = graph_file
    mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))

    before = compbin.host_decoded_bytes()
    results = simulate_hosts(path, 2, mesh, open_kwargs=OPEN_KW, n_parts=8)
    single = simulate_hosts(path, 1, mesh, open_kwargs=OPEN_KW, n_parts=8)[0]

    # per-host stats reported per process and summing to single-host totals
    agg = aggregate_stats(results)
    for r in results:
        assert r.stats.bytes_h2d > 0
        assert r.stats.cache_hits + r.stats.cache_misses > 0
    assert agg.bytes_h2d == single.stats.bytes_h2d
    assert (agg.cache_hits + agg.cache_misses
            == single.stats.cache_hits + single.stats.cache_misses)
    assert agg.edges == single.stats.edges == csr.n_edges
    assert compbin.host_decoded_bytes() - before == 0  # all device decode

    # the streamed device shards become the full-graph training batch
    shards = all_shards(results)
    for s in shards:
        assert isinstance(s.neighbors, jax.Array)
    cfg = gcn.GCNConfig(n_layers=2, d_hidden=16, d_in=16, n_classes=7)
    assert results[0].n_vertices == csr.n_vertices
    batch = streamed_graph_batch("gcn-cora", cfg, shards,
                                 np.random.default_rng(0),
                                 n_classes=cfg.n_classes,
                                 n_vertices=results[0].n_vertices)
    assert int(batch["x"].shape[0]) == csr.n_vertices
    assert int(batch["edge_src"].shape[0]) == csr.n_edges

    params = gcn.init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=15)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn.loss_fn)(params, batch, cfg)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    losses = []
    for _ in range(15):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses  # full-batch training converges


def test_e2e_missing_host_shards_fail_loudly(graph_file):
    """Full-graph training on HALF the hosts' shards must raise, not
    silently train on a truncated graph."""
    from repro.launch.data_gnn import streamed_graph_batch
    from repro.models.gnn import gcn

    path, csr = graph_file
    results = simulate_hosts(path, 2, open_kwargs=OPEN_KW, n_parts=8)
    cfg = gcn.GCNConfig(n_layers=2, d_hidden=16, d_in=16, n_classes=7)
    with pytest.raises(ValueError, match="every host"):
        # interior/leading gap: host 0's shards missing
        streamed_graph_batch("gcn-cora", cfg, results[1].shards,
                             np.random.default_rng(0))
    with pytest.raises(ValueError, match="every host"):
        # trailing gap: host 1's shards missing — only detectable against
        # the graph's true vertex count
        streamed_graph_batch("gcn-cora", cfg, results[0].shards,
                             np.random.default_rng(0),
                             n_vertices=results[0].n_vertices)
