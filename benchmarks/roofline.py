"""Roofline report: render results/dryrun.json into the §Roofline table.

    python -m benchmarks.roofline [--in results/dryrun.json] [--mesh 16x16]
"""

from __future__ import annotations

import argparse
import json

from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def fmt_row(rec: dict) -> str:
    if rec.get("status") == "SKIP":
        return (f"| {rec['arch']} | {rec['shape']} | {rec.get('variant','baseline')} "
                f"| SKIP | — | — | — | — | — | {rec['skip_reason'][:60]}... |")
    if rec.get("status") != "OK":
        return (f"| {rec['arch']} | {rec['shape']} | {rec.get('variant','baseline')} "
                f"| {rec.get('status')} | — | — | — | — | — | |")
    r = rec["roofline"]
    dom_t = max(r["t_compute"], r["t_memory"], r["t_collective"])
    frac = r["t_compute"] / max(dom_t, 1e-30)
    mem_gb = rec["memory"]["peak_est_bytes"] / 2**30
    return (f"| {rec['arch']} | {rec['shape']} | {rec.get('variant','baseline')} "
            f"| {rec.get('kind','')} "
            f"| {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} "
            f"| {r['t_collective']*1e3:.2f} | {r['dominant']} "
            f"| {frac:.2f} | {r['useful_ratio'] if r['useful_ratio'] is None else round(r['useful_ratio'],3)} "
            f"| {mem_gb:.1f} |")


HEADER = ("| arch | shape | variant | kind | t_compute ms | t_memory ms "
          "| t_collective ms | dominant | compute/roofline | useful ratio "
          "| peak GB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.json")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    with open(args.inp) as f:
        data = json.load(f)
    recs = sorted(data.values(), key=lambda r: (r.get("family", ""),
                                                r["arch"], r["shape"],
                                                r.get("mesh", "")))
    print(f"Hardware model: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16/chip, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s/link ICI")
    print(HEADER)
    for rec in recs:
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        if args.variant and rec.get("variant", "baseline") != args.variant:
            continue
        print(fmt_row(rec))


if __name__ == "__main__":
    main()
