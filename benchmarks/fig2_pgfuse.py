"""Fig. 2 analogue: ParaGrapher loading time with vs. without PG-Fuse.

Claim validated (paper §V-B): PG-Fuse speeds up loading by coalescing
frequent small (<=128 kB) storage requests into 32 MiB cached blocks —
0.9-7.6x on the paper's system; small graphs can regress (block
overshoot / lost parallelism), which the block-size sensitivity column
reproduces.
"""

from __future__ import annotations

from benchmarks.datasets import build_suite
from benchmarks.loading import load_webgraph_direct, load_webgraph_pgfuse


def run(workdir: str, profile: str = "lustre_ssd", names=None) -> list[dict]:
    rows = []
    for ds in build_suite(workdir, names):
        base = load_webgraph_direct(ds.wg_path, profile)
        fuse = load_webgraph_pgfuse(ds.wg_path, profile)
        fuse_small = load_webgraph_pgfuse(ds.wg_path, profile,
                                          block_size=1 << 20)
        rows.append({
            "name": ds.name,
            "base_s": base.total_s, "pgfuse_s": fuse.total_s,
            "pgfuse_1MiB_s": fuse_small.total_s,
            "speedup": base.total_s / max(fuse.total_s, 1e-12),
            "speedup_1MiB": base.total_s / max(fuse_small.total_s, 1e-12),
            "base_requests": base.requests, "pgfuse_requests": fuse.requests,
        })
    return rows


def main(workdir: str = "/tmp/repro_bench", profile: str = "lustre_ssd") -> None:
    rows = run(workdir, profile)
    print(f"[fig2] storage profile: {profile}")
    print(f"{'name':<12}{'base_s':>9}{'pgfuse_s':>10}{'speedup':>9}"
          f"{'blk=1MiB':>10}{'reqs':>12}")
    for r in rows:
        print(f"{r['name']:<12}{r['base_s']:>9.3f}{r['pgfuse_s']:>10.3f}"
              f"{r['speedup']:>9.2f}{r['speedup_1MiB']:>10.2f}"
              f"{r['base_requests']:>6}/{r['pgfuse_requests']:<5}")


if __name__ == "__main__":
    main()
