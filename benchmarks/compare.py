"""BENCH json regression gate (CI's bench lane).

Compares a freshly produced ``BENCH_*.json`` against the committed
baseline and fails when any tracked throughput metric regresses more
than the allowed fraction:

    PYTHONPATH=src python -m benchmarks.compare BENCH_baseline.json \
        BENCH_loading.json --max-regression 0.30

Only the ``tracked`` section is gated.  Those metrics are deliberately
derived from the SimStorage *virtual* clock and deterministic byte
counters (see ``benchmarks/loading.py::run``) so they measure the
loader's request pattern — enlarged blocks, readahead, cache hit rates,
packed H2D transfer — not the speed of whichever machine CI landed on.
Everything else in the json (wall-clock decode times etc.) is advisory
and reported without gating.  Improvements are never an error; refresh
the baseline deliberately when one should become the new floor.
"""

from __future__ import annotations

import argparse
import json
import sys


def compare(baseline: dict, current: dict, max_regression: float
            ) -> tuple[list[str], list[str]]:
    """Returns (report_lines, failures)."""
    base_tracked = baseline.get("tracked", {})
    cur_tracked = current.get("tracked", {})
    lines, failures = [], []
    if not base_tracked:
        failures.append("baseline has no 'tracked' section")
        return lines, failures
    for key in sorted(base_tracked):
        old = base_tracked[key]
        if not isinstance(old, (int, float)):
            continue
        if key not in cur_tracked:
            failures.append(f"{key}: missing from current BENCH json")
            continue
        new = cur_tracked[key]
        if old <= 0:  # nothing to gate against; report only
            lines.append(f"  {key:<28} {old:>12.4g} -> {new:>12.4g}  (ungated)")
            continue
        ratio = new / old
        status = "OK" if ratio >= 1.0 - max_regression else "REGRESSED"
        lines.append(f"  {key:<28} {old:>12.4g} -> {new:>12.4g}  "
                     f"({ratio:6.2%}) {status}")
        if status == "REGRESSED":
            failures.append(
                f"{key}: {new:.4g} is {1 - ratio:.1%} below baseline "
                f"{old:.4g} (allowed {max_regression:.0%})")
    return lines, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if tracked BENCH throughput regressed")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="allowed fractional drop per metric (default 0.30)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    lines, failures = compare(baseline, current, args.max_regression)
    print(f"tracked metrics ({args.baseline} -> {args.current}, "
          f"max regression {args.max_regression:.0%}):")
    for line in lines:
        print(line)
    if failures:
        print("\nREGRESSION GATE FAILED:", file=sys.stderr)
        for fail in failures:
            print(f"  {fail}", file=sys.stderr)
        return 2
    print("\nregression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
