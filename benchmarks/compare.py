"""BENCH json regression gate (CI's bench lane).

Compares freshly produced ``BENCH_*.json`` files against the committed
baseline and fails when any tracked metric regresses more than the
allowed fraction:

    PYTHONPATH=src python -m benchmarks.compare BENCH_baseline.json \
        BENCH_loading.json BENCH_query.json --max-regression 0.30

Two gated sections, two directions:

* ``tracked`` — throughputs / rates where HIGHER is better: the gate
  fails when a metric drops more than the allowed fraction below the
  baseline.
* ``tracked_lower`` — latencies / charged time where LOWER is better:
  the gate fails when a metric RISES more than the allowed fraction
  above the baseline.

Several current files may be passed (one per suite); their sections are
merged before gating, so one committed ``BENCH_baseline.json`` holds the
union of every suite's gated metrics.  All gated metrics are
deliberately derived from the SimStorage *virtual* clock and
deterministic byte counters (see ``benchmarks/loading.py::run`` and
``benchmarks/query.py::run``) so they measure the loader's/engine's
request pattern — enlarged blocks, readahead, cache hit rates, packed
H2D transfer, query coalescing — not the speed of whichever machine CI
landed on.  Everything else in the json (wall-clock decode times etc.)
is advisory and reported without gating.  Improvements are never an
error; refresh the baseline deliberately when one should become the new
floor.
"""

from __future__ import annotations

import argparse
import json
import sys


def _gate_section(base: dict, cur: dict, max_regression: float,
                  lower_is_better: bool) -> tuple[list[str], list[str]]:
    lines, failures = [], []
    for key in sorted(base):
        old = base[key]
        if not isinstance(old, (int, float)):
            continue
        if key not in cur:
            failures.append(f"{key}: missing from current BENCH json")
            continue
        new = cur[key]
        if old <= 0:  # nothing to gate against; report only
            lines.append(f"  {key:<28} {old:>12.4g} -> {new:>12.4g}  (ungated)")
            continue
        ratio = new / old
        if lower_is_better:
            ok = ratio <= 1.0 + max_regression
        else:
            ok = ratio >= 1.0 - max_regression
        status = "OK" if ok else "REGRESSED"
        lines.append(f"  {key:<28} {old:>12.4g} -> {new:>12.4g}  "
                     f"({ratio:6.2%}) {status}")
        if not ok:
            word = "above" if lower_is_better else "below"
            failures.append(
                f"{key}: {new:.4g} is {abs(1 - ratio):.1%} {word} baseline "
                f"{old:.4g} (allowed {max_regression:.0%})")
    return lines, failures


def merge_tracked(currents: list[dict]) -> dict:
    """Union of the gated sections across several suites' BENCH dicts.

    A metric name owned by two suites would gate ambiguously, so
    collisions are an error rather than a silent last-writer-wins.
    """
    merged = {"tracked": {}, "tracked_lower": {}}
    for cur in currents:
        for section in merged:
            for k, v in cur.get(section, {}).items():
                if k in merged[section]:
                    raise ValueError(
                        f"metric {k!r} appears in more than one BENCH json; "
                        f"gated metric names must be unique across suites")
                merged[section][k] = v
    return merged


def compare(baseline: dict, current: dict, max_regression: float
            ) -> tuple[list[str], list[str]]:
    """Returns (report_lines, failures).  ``current`` may be one suite's
    dict or the :func:`merge_tracked` union of several."""
    lines, failures = [], []
    if not baseline.get("tracked") and not baseline.get("tracked_lower"):
        failures.append("baseline has no 'tracked'/'tracked_lower' section")
        return lines, failures
    up_lines, up_fail = _gate_section(
        baseline.get("tracked", {}), current.get("tracked", {}),
        max_regression, lower_is_better=False)
    down_lines, down_fail = _gate_section(
        baseline.get("tracked_lower", {}), current.get("tracked_lower", {}),
        max_regression, lower_is_better=True)
    lines.extend(up_lines)
    if down_lines:
        lines.append("  -- lower is better --")
        lines.extend(down_lines)
    failures.extend(up_fail)
    failures.extend(down_fail)
    return lines, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if tracked BENCH metrics regressed")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", nargs="+",
                    help="freshly produced BENCH_*.json (one per suite; "
                         "gated sections are merged)")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="allowed fractional change per metric "
                         "(default 0.30)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    currents = []
    for path in args.current:
        with open(path) as f:
            currents.append(json.load(f))
    current = merge_tracked(currents)

    lines, failures = compare(baseline, current, args.max_regression)
    print(f"tracked metrics ({args.baseline} -> {', '.join(args.current)}, "
          f"max regression {args.max_regression:.0%}):")
    for line in lines:
        print(line)
    if failures:
        print("\nREGRESSION GATE FAILED:", file=sys.stderr)
        for fail in failures:
            print(f"  {fail}", file=sys.stderr)
        return 2
    print("\nregression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
