"""Traversal-service benchmark (multi-hop serving over CompBin §IV).

Replays a deterministic zipf-seeded trace of k-hop traversals two ways
on identical simulated storage:

* **frontier-batched service** (:class:`repro.query.TraversalService`):
  every hop expands as ONE engine batch — dedup, merged range reads,
  span prefetch and the PG-Fuse block cache all apply to the frontier
  as a unit;
* **per-vertex naive baseline**: the same BFS issuing one uncached
  ``CompBinFile.neighbors_of`` per frontier vertex straight off storage
  (one offsets read + one neighbors read per vertex — the
  request-per-call server the paper's small-read critique, §III,
  applies to, now paying it at every hop).

Both arms visit identical vertex sets (asserted), so the advantage is
purely the engine stack.  All gated numbers come from the SimStorage
*virtual* clock: the engine's ``clock=`` is the charged-time counter,
so each request's ``latency_s`` is the virtual storage time it
observed — deterministic properties of the trace, not of the bench
machine.  Latency percentiles gate in ``tracked_lower`` (lower is
better), the frontier-batching speedup in ``tracked`` (higher is
better).  An overload replay through the closed-loop load generator
additionally reports the (deterministic) shed rate and admitted-p99.
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.storage_sim import PROFILES, SimStorage

PGFUSE_BLOCK = 1 << 14
KHOP_K = 2
EDGE_BUDGET = 1 << 16


def _seed_trace(n_vertices: int, n_requests: int, seeds_per_req: int,
                seed: int = 0) -> list:
    """Zipf-hot traversal seeds: half from a small scattered hub set
    (repeat ego-net queries around the same celebrities), half uniform."""
    rng = np.random.default_rng(seed)
    hubs = rng.permutation(n_vertices)[:max(8, n_vertices >> 10)]
    trace = []
    for _ in range(n_requests):
        hot = hubs[rng.integers(0, len(hubs), seeds_per_req)]
        cold = rng.integers(0, n_vertices, seeds_per_req)
        trace.append(np.where(rng.random(seeds_per_req) < 0.5, hot, cold))
    return trace


def _replay_service(path: str, trace, profile: str, budget: int):
    """Frontier-batched arm; returns (TraversalService stats snapshot,
    engine QueryStats, SimStorage, per-request visited counts)."""
    from repro.core import paragrapher, policy
    from repro.query import NeighborQueryEngine, TraversalService

    storage = SimStorage(PROFILES[profile])
    amode = policy.choose_access_mode("serve")
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=PGFUSE_BLOCK,
        pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
        pgfuse_max_resident_bytes=budget, pgfuse_pread_fn=storage.pread)
    try:
        engine = NeighborQueryEngine(g, decode="host",
                                     clock=lambda: storage.charged_s)
        svc = TraversalService(engine)
        visited = [svc.khop(seeds, KHOP_K, max_edges=EDGE_BUDGET).n_visited
                   for seeds in trace]
        return svc.stats, engine.stats, storage, visited
    finally:
        g.close()


def _replay_pervertex(path: str, trace, profile: str):
    """Naive arm: identical BFS semantics, one uncached
    ``neighbors_of`` per frontier vertex; returns (SimStorage,
    per-request latencies, per-request visited counts)."""
    from repro.core import compbin

    storage = SimStorage(PROFILES[profile])
    rd = compbin.CompBinFile(storage.open_reader(path))
    try:
        latencies, visited = [], []
        for seeds in trace:
            t0 = storage.charged_s
            seen = {int(s) for s in seeds}
            frontier = sorted(seen)
            for _ in range(KHOP_K):
                nxt = set()
                for v in frontier:
                    for u in rd.neighbors_of(int(v)):
                        if int(u) not in seen:
                            nxt.add(int(u))
                seen |= nxt
                frontier = sorted(nxt)
                if not frontier:
                    break
            latencies.append(storage.charged_s - t0)
            visited.append(len(seen))
        return storage, latencies, visited
    finally:
        rd.close()


#: overload traffic is single-hop with a tight budget: the admission
#: arithmetic bounds queueing only when one request's true cost stays
#: under t_req = overshoot * budget / rate, and one k-hop frontier can
#: overshoot its edge budget by a whole hop — 1-hop ego-nets keep the
#: overshoot bounded so the reported admitted-p99 <= SLO is the gate's
#: guarantee, not luck
OVERLOAD_EDGE_BUDGET = 8192


def _replay_overload(path: str, profile: str, budget: int,
                     n_clients: int = 32, horizon_s: float = 0.5) -> dict:
    """Closed-loop overload through the admission gate on the virtual
    clock: shed rate and admitted-p99 are deterministic numbers."""
    from repro.core import paragrapher, policy
    from repro.query import (LoadGenerator, NeighborQueryEngine,
                             TraversalRequest, TraversalService)

    storage = SimStorage(PROFILES[profile])
    amode = policy.choose_access_mode("serve")
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=PGFUSE_BLOCK,
        pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
        pgfuse_max_resident_bytes=budget, pgfuse_pread_fn=storage.pread)
    try:
        n = g.n_vertices
        engine = NeighborQueryEngine(g, decode="host",
                                     clock=lambda: storage.charged_s)
        plan = policy.choose_admission(
            0.02, edge_budget=OVERLOAD_EDGE_BUDGET,
            service_edges_per_s=5.0e6)
        svc = TraversalService(engine, admission=plan)

        def make_request(rng, _cid):
            seeds = np.minimum(rng.zipf(1.8, size=3) - 1, n - 1)
            return TraversalRequest("khop", seeds, k=1,
                                    max_edges=OVERLOAD_EDGE_BUDGET)

        gen = LoadGenerator(svc, make_request, n_clients=n_clients,
                            horizon_s=horizon_s, think_s=0.0,
                            backoff_s=0.01, seed=5)
        report = gen.run()
        assert svc.stats.conserved
        assert report.p99_s <= plan.slo_s, \
            "admitted requests broke the SLO the gate promises"
        return {**report.as_dict(), "slo_s": plan.slo_s,
                "max_inflight": plan.max_inflight}
    finally:
        g.close()


def run(workdir: str = "/tmp/repro_bench_traversal",
        profile: str = "lustre_ssd", scale: int = 15, edge_factor: int = 8,
        n_requests: int = 48, seeds_per_req: int = 4,
        out: str = "BENCH_traversal.json") -> dict:
    """The traversal suite -> one BENCH json dict (CI gates ``tracked``
    upward and ``tracked_lower`` downward)."""
    os.makedirs(workdir, exist_ok=True)

    from repro.core import paragrapher
    from repro.graph import rmat

    path = os.path.join(workdir, f"rmat{scale}x{edge_factor}.cbin")
    if not os.path.exists(path):
        paragrapher.save_graph(path, rmat(scale, edge_factor, seed=0),
                               format="compbin")
    with paragrapher.open_graph(path) as g:
        n_vertices = g.n_vertices
        file_bytes = os.path.getsize(path)
    trace = _seed_trace(n_vertices, n_requests, seeds_per_req)
    budget = max(4 * PGFUSE_BLOCK, file_bytes // 2)

    svc_stats, q_stats, svc_storage, svc_visited = _replay_service(
        path, trace, profile, budget)
    naive_storage, naive_lat, naive_visited = _replay_pervertex(
        path, trace, profile)
    # both arms ran the same traversals — the speedup is the stack,
    # not a semantics drift
    assert svc_visited == naive_visited, "arms diverged on visit sets"
    overload = _replay_overload(path, profile, budget)

    svc_d = svc_stats.as_dict()
    result = {
        "bench": "traversal_service",
        "profile": profile,
        "graph": {"scale": scale, "edge_factor": edge_factor,
                  "vertices": n_vertices, "file_bytes": file_bytes},
        "trace": {"n_requests": n_requests, "seeds_per_req": seeds_per_req,
                  "k": KHOP_K, "edge_budget": EDGE_BUDGET},
        "service": {**svc_d,
                    "engine_batches": q_stats.batches,
                    "engine_dedup_ratio": q_stats.dedup_ratio,
                    "io_s": svc_storage.charged_s,
                    "underlying_reads": svc_storage.requests,
                    "underlying_bytes": svc_storage.bytes},
        "pervertex_baseline": {
            "io_s": naive_storage.charged_s,
            "underlying_reads": naive_storage.requests,
            "underlying_bytes": naive_storage.bytes,
            "p50_s": float(np.quantile(naive_lat, 0.50)),
            "p99_s": float(np.quantile(naive_lat, 0.99))},
        "overload": overload,
    }
    result["tracked"] = {
        # what frontier batching (dedup + coalescing + span prefetch +
        # block cache, once per hop) buys over request-per-call BFS on
        # identical traversals and storage
        "traversal_frontier_advantage": naive_storage.charged_s
        / max(svc_storage.charged_s, 1e-12),
    }
    result["tracked_lower"] = {
        # charged-storage latency one traversal observes (virtual s)
        "traversal_vclock_p50_s": svc_d["p50_s"],
        "traversal_vclock_p99_s": svc_d["p99_s"],
    }

    print("BENCH " + json.dumps(result))
    if out and out != "-":
        with open(out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out}")
    return result


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_traversal")
    ap.add_argument("--profile", default="lustre_ssd",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=15)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--out", default="BENCH_traversal.json")
    args = ap.parse_args()
    run(workdir=args.workdir, profile=args.profile, scale=args.scale,
        edge_factor=args.edge_factor, n_requests=args.n_requests,
        out=args.out)


if __name__ == "__main__":
    _main()
