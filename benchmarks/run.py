"""Benchmark aggregator — one registered suite per artifact.

    PYTHONPATH=src python -m benchmarks.run [--workdir DIR] [--fast]
    PYTHONPATH=src python -m benchmarks.run --suites loading

Suites register in ``SUITES`` and the default run executes all of them:
the paper-figure harnesses print one ``name,value,derived`` CSV block
per table/figure, and every suite that measures loading bandwidth emits
its ``BENCH_*.json`` (the files CI's bench lane uploads and gates with
``benchmarks/compare.py``) — one entry point, all BENCH json.  Absolute
numbers are for THIS container (CPU + tmpfs + simulated storage
profiles); the paper's relative effects are the claims under test (see
EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _suite_figs(args):
    """Table I + Figs. 2-4 (CSV blocks; no BENCH json)."""
    names = ["web-sm", "social-sm", "web-md"] if args.fast else None

    from benchmarks import (fig2_pgfuse, fig3_compbin, fig4_crossover,
                            table1_datasets)

    print("=" * 72)
    print("Table I — datasets & format sizes")
    print("=" * 72)
    t1_rows = table1_datasets.run(args.workdir, names)
    for r in t1_rows:
        print(f"table1,{r['name']},wg_MiB={r['webgraph_MiB']:.2f},"
              f"cb_MiB={r['compbin_MiB']:.2f},ratio={r['compression_ratio']:.2f}")

    print("=" * 72)
    print("Fig. 2 — PG-Fuse on/off (WebGraph loading)")
    print("=" * 72)
    f2 = fig2_pgfuse.run(args.workdir, args.profile, names)
    for r in f2:
        print(f"fig2,{r['name']},base_s={r['base_s']:.4f},"
              f"pgfuse_s={r['pgfuse_s']:.4f},speedup={r['speedup']:.2f}")
    sp = [r["speedup"] for r in f2]
    print(f"fig2,SUMMARY,speedup_min={min(sp):.2f},speedup_max={max(sp):.2f},"
          f"paper_range=0.9-7.6")

    print("=" * 72)
    print("Fig. 3 — CompBin & PG-Fuse speedups over baseline")
    print("=" * 72)
    f3 = fig3_compbin.run(args.workdir, args.profile, names)
    for r in f3:
        print(f"fig3,{r['name']},compbin_x={r['compbin_speedup']:.2f},"
              f"pgfuse_x={r['pgfuse_speedup']:.2f}")
    cb = [r["compbin_speedup"] for r in f3]
    print(f"fig3,SUMMARY,compbin_max={max(cb):.2f},paper_max=21.8")

    print("=" * 72)
    print("Fig. 4 — PG-Fuse vs CompBin crossover (shared-contended profile)")
    print("=" * 72)
    f4 = fig4_crossover.run(args.workdir, "lustre_shared", names)
    for r in f4:
        print(f"fig4,{r['name']},size_diff_MiB={r['size_diff_MiB']:.2f},"
              f"ratio={r['pgfuse_over_compbin']:.3f}")
    x = fig4_crossover.crossover_MiB(f4)
    print(f"fig4,SUMMARY,crossover_MiB={x if x else 'none'}")
    return {"table1": {r["name"]: r for r in t1_rows},
            "fig2": {r["name"]: r for r in f2},
            "fig3": {r["name"]: r for r in f3},
            "fig4": {r["name"]: r for r in f4}}


def _suite_loading(args):
    """Streaming-loader bandwidth (topology + feature store) ->
    BENCH_loading.json, the artifact CI's bench regression lane gates."""
    from benchmarks import loading

    print("=" * 72)
    print("Loading — streamed topology + features (emits BENCH json)")
    print("=" * 72)
    return loading.run(workdir=args.workdir, profile=args.profile,
                scale=13 if args.fast else 16, hosts=args.hosts,
                out=args.bench_out)


def _suite_query(args):
    """Random-access query engine vs sequential policy on a zipf trace
    (+ host-vs-device decode arms on a large-fanout trace) ->
    BENCH_query.json (virtual-clock p50/p99 latency + hit rate, gated
    downward/upward respectively by the bench lane)."""
    from benchmarks import query

    print("=" * 72)
    print("Query — random-access neighbor engine (emits BENCH json)")
    print("=" * 72)
    return query.run(workdir=args.workdir, profile=args.profile,
              scale=14 if args.fast else 17,
              out=args.query_out)


def _suite_traversal(args):
    """Frontier-batched traversal service vs per-vertex naive BFS on a
    zipf seed trace (+ a deterministic overload replay through the
    admission gate) -> BENCH_traversal.json (virtual-clock p50/p99
    gated downward, frontier-batching advantage gated upward)."""
    from benchmarks import traversal

    print("=" * 72)
    print("Traversal — multi-hop service vs per-vertex BFS (emits BENCH json)")
    print("=" * 72)
    return traversal.run(workdir=args.workdir, profile=args.profile,
                  scale=13 if args.fast else 15,
                  out=args.traversal_out)


def _suite_sharded(args):
    """1/2/4-shard scatter-gather deployments replaying the same zipf
    hub trace on per-shard simulated storage -> BENCH_sharded.json
    (2-shard aggregate-makespan advantage gated upward with a hard
    >=1.5x floor, 2-shard virtual-clock p50/p99 gated downward)."""
    from benchmarks import sharded

    print("=" * 72)
    print("Sharded — scatter-gather scale-out 1/2/4 shards (emits BENCH json)")
    print("=" * 72)
    return sharded.run(workdir=args.workdir, profile=args.profile,
                scale=13 if args.fast else 15,
                out=args.sharded_out)


def _suite_hotset(args):
    """HBM-resident hot-set tier (decoded hub runs, degree-aware
    admission) vs the packed-byte-only engine on a degree-correlated
    zipf trace -> BENCH_hotset.json (hit advantage gated upward with a
    hard >=1.5x floor, hot-arm virtual-clock p50/p99 gated downward)."""
    from benchmarks import hotset

    print("=" * 72)
    print("Hotset — HBM decoded-run tier vs packed path (emits BENCH json)")
    print("=" * 72)
    return hotset.run(workdir=args.workdir,
               scale=13 if args.fast else 16,
               out=args.hotset_out)


def _suite_reorder(args):
    """Offline graph compiler (BFS locality reorder + LogCSR re-encode,
    inverse-permutation sidecar) vs the scrambled original on the same
    logical zipf trace and capped PG-Fuse budget -> BENCH_reorder.json
    (hit-rate gain gated upward with a hard in-bench floor, compiled-arm
    virtual-clock p50/p99 gated downward)."""
    from benchmarks import reorder

    print("=" * 72)
    print("Reorder — locality compile vs scrambled order (emits BENCH json)")
    print("=" * 72)
    return reorder.run(workdir=args.workdir, profile=args.profile,
                scale=13 if args.fast else 16,
                out=args.reorder_out)


#: registered suites, executed in order by default — add new benchmark
#: harnesses here so ``python -m benchmarks.run`` stays the one entry
#: point that emits every artifact (CSV blocks and BENCH_*.json alike)
SUITES = {
    "figs": _suite_figs,
    "loading": _suite_loading,
    "query": _suite_query,
    "traversal": _suite_traversal,
    "sharded": _suite_sharded,
    "hotset": _suite_hotset,
    "reorder": _suite_reorder,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench")
    ap.add_argument("--profile", default="lustre_ssd")
    ap.add_argument("--fast", action="store_true",
                    help="small suite only (CI)")
    ap.add_argument("--suites", default=",".join(SUITES),
                    help=f"comma list of suites to run "
                         f"(available: {', '.join(SUITES)})")
    ap.add_argument("--hosts", type=int, default=2,
                    help="simulated hosts for the loading suite")
    ap.add_argument("--bench-out", default="BENCH_loading.json",
                    help="where the loading suite writes its BENCH json")
    ap.add_argument("--query-out", default="BENCH_query.json",
                    help="where the query suite writes its BENCH json")
    ap.add_argument("--traversal-out", default="BENCH_traversal.json",
                    help="where the traversal suite writes its BENCH json")
    ap.add_argument("--sharded-out", default="BENCH_sharded.json",
                    help="where the sharded suite writes its BENCH json")
    ap.add_argument("--hotset-out", default="BENCH_hotset.json",
                    help="where the hotset suite writes its BENCH json")
    ap.add_argument("--reorder-out", default="BENCH_reorder.json",
                    help="where the reorder suite writes its BENCH json")
    args = ap.parse_args()

    picked = [s.strip() for s in args.suites.split(",") if s.strip()]
    unknown = [s for s in picked if s not in SUITES]
    if unknown:
        ap.error(f"unknown suites {unknown}; available: {', '.join(SUITES)}")

    t0 = time.time()
    for name in picked:
        result = SUITES[name](args)
        if isinstance(result, dict):
            # one flattened metrics sidecar per suite next to its BENCH
            # json — dotted numeric keys only (repro.obs.metrics
            # .flatten_numeric), uploaded by CI's bench lane so every
            # run doubles as a metrics-surface smoke artifact
            from repro.obs.metrics import flatten_numeric
            side = f"BENCH_{name}_metrics.json"
            with open(side, "w") as f:
                json.dump(flatten_numeric(result), f, indent=2,
                          sort_keys=True)
                f.write("\n")
            print(f"{name}: wrote {side} "
                  f"({len(flatten_numeric(result))} metrics)")
    print("=" * 72)
    print(f"done in {time.time()-t0:.1f}s  "
          f"(roofline table: python -m benchmarks.roofline)")


if __name__ == "__main__":
    sys.exit(main())
