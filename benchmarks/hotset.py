"""Hot-set tier benchmark: what the HBM-resident cache of DECODED runs
buys over the packed-byte serving path on hub-heavy traffic.

Replays a deterministic degree-correlated zipf trace — batched
``neighbors(v)`` lookups where the hot head IS the graph's top-degree
hub set, like real webgraph traffic — against two otherwise identical
:class:`repro.query.NeighborQueryEngine` configurations:

* **cold arm**: the plain engine (random-access PG-Fuse policy, host
  eq. (1) decode) — every batch pays offsets gather + packed gather +
  decode for its full deduplicated working set;
* **hot arm**: the same engine with the
  :class:`repro.query.HotSetCache` tier
  (:func:`repro.core.policy.choose_hotset_admission`): after warmup the
  hub vertices are answered from resident decoded runs, so only the
  cold remainder reaches the packed-byte path.

Both arms replay the IDENTICAL trace over the "null" storage profile
with the same charged decode-cost model as ``benchmarks/query.py`` —
the virtual clock advances only by the decode work a batch actually
performs, so the arms' charged-latency split is exactly the decode the
hot set skipped: a property of the trace and the admission policy, not
of this machine.  A running answer checksum asserts the two arms return
identical neighbor runs (the differential fuzzers prove full
byte-identity; the bench cross-checks it stayed true under the measured
config).

Gated numbers: ``hotset_hit_advantage`` (cold-arm p50 over hot-arm p50,
must hold >= the acceptance floor of 1.5x) and ``hotset_hit_rate`` in
``tracked`` (higher is better); the hot arm's charged p50/p99 in
``tracked_lower`` (lower is better; ``benchmarks/compare.py`` fails on
rises).
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.query import HOST_DECODE_EDGES_PER_S, PGFUSE_BLOCK
from benchmarks.storage_sim import PROFILES, SimStorage

# the in-bench floor mirroring the CI gate: hub traffic answered from
# the hot set must make the charged p50 at least this much better
MIN_HIT_ADVANTAGE = 1.5


def _degree_trace(degrees: np.ndarray, n_batches: int, batch: int,
                  *, hot_fraction: float = 0.6, seed: int = 0):
    """Deterministic hub-heavy traffic: ``hot_fraction`` of lookups hit
    the TOP-DEGREE hub set (webgraph request popularity tracks degree —
    exactly the head the degree-aware admission pins), the rest are
    uniform over the whole vertex range."""
    n = degrees.shape[0]
    hubs = np.argsort(degrees)[::-1][:max(16, n >> 10)].astype(np.int64)
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_batches):
        hot = hubs[rng.integers(0, len(hubs), batch)]
        cold = rng.integers(0, n, batch)
        trace.append(np.where(rng.random(batch) < hot_fraction, hot, cold))
    return trace, hubs


def _replay(path: str, trace, profile: str, *, budget: int,
            hotset: int = None):
    """One engine (optionally carrying the hot-set tier) over the whole
    trace; returns (QueryStats, HotSetStats | None, SimStorage,
    checksum).  The virtual clock is charged by the host decode-cost
    model for every run the engine actually decodes — including
    prefetch fills — so a hot-set hit's saving is exactly the decode it
    skipped."""
    from repro.core import paragrapher, policy
    from repro.query import NeighborQueryEngine

    amode = policy.choose_access_mode("serve")
    storage = SimStorage(PROFILES[profile])
    vdecode = [0.0]
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=PGFUSE_BLOCK,
        pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
        pgfuse_max_resident_bytes=budget, pgfuse_pread_fn=storage.pread)
    try:
        engine = NeighborQueryEngine(
            g, decode="host", hotset=hotset,
            clock=lambda: storage.charged_s + vdecode[0])
        b = g.bytes_per_id
        orig_host = engine._decode_host

        def charged_host(packed):
            vdecode[0] += (sum(p.size for p in packed) // b) \
                / HOST_DECODE_EDGES_PER_S
            return orig_host(packed)

        engine._decode_host = charged_host
        checksum = 0
        for ids in trace:
            for v, neigh in zip(ids, engine.neighbors_batch(ids)):
                checksum += int(v) * int(neigh.sum()) + neigh.size
        hs = engine.hotset.stats if engine.hotset is not None else None
        return engine.stats, hs, storage, checksum
    finally:
        g.close()


def run(workdir: str = "/tmp/repro_bench_hotset", profile: str = "null",
        scale: int = 16, edge_factor: int = 16, n_batches: int = 48,
        batch: int = 256, hot_fraction: float = 0.6,
        out: str = "BENCH_hotset.json") -> dict:
    """The hot-set suite: cold vs hot arm on one degree-correlated zipf
    trace, emitted as one BENCH json dict (CI gates ``tracked`` upward
    and ``tracked_lower`` downward)."""
    os.makedirs(workdir, exist_ok=True)

    from repro.core import paragrapher, policy
    from repro.graph import rmat

    csr = rmat(scale, edge_factor, seed=0)
    path = os.path.join(workdir, f"rmat{scale}x{edge_factor}.cbin")
    if not os.path.exists(path):
        paragrapher.save_graph(path, csr, format="compbin")
    file_bytes = os.path.getsize(path)
    degrees = np.diff(csr.offsets)
    trace, hubs = _degree_trace(degrees, n_batches, batch,
                                hot_fraction=hot_fraction)
    # PG-Fuse holds the whole file in both arms (identical middle tier)
    # so the split isolates what the TOP tier skips: gather + decode
    pg_budget = max(4 * PGFUSE_BLOCK, file_bytes)
    # hot-set budget: the decoded hub runs plus slack for the admitted
    # warm band — small next to the PG-Fuse budget, as in production
    hub_bytes = int(degrees[hubs].sum()) * 8
    hs_budget = max(1 << 16, int(1.5 * hub_bytes))
    plan = policy.choose_hotset_admission(csr.n_vertices, csr.n_edges,
                                          hs_budget)

    cold_q, _, cold_st, cold_sum = _replay(path, trace, profile,
                                           budget=pg_budget)
    hot_q, hs, hot_st, hot_sum = _replay(path, trace, profile,
                                         budget=pg_budget,
                                         hotset=hs_budget)
    assert cold_sum == hot_sum, \
        f"hot arm diverged from cold arm: {hot_sum} != {cold_sum}"
    assert hs.conserved, "hot-set stats conservation violated"

    advantage = cold_q.p50_s / max(hot_q.p50_s, 1e-12)
    assert advantage >= MIN_HIT_ADVANTAGE, \
        f"hotset_hit_advantage {advantage:.2f} < {MIN_HIT_ADVANTAGE}"

    result = {
        "bench": "hotset",
        "profile": profile,
        "graph": {"scale": scale, "edge_factor": edge_factor,
                  "vertices": csr.n_vertices, "edges": csr.n_edges,
                  "file_bytes": file_bytes, "hubs": int(len(hubs))},
        "trace": {"n_batches": n_batches, "batch": batch,
                  "hot_fraction": hot_fraction,
                  "requests": hot_q.requests},
        "plan": {"budget_bytes": plan.budget_bytes,
                 "min_degree": plan.min_degree,
                 "pin_degree": plan.pin_degree, "place": plan.place,
                 "reason": plan.reason},
        "cold_arm": {**cold_q.as_dict(), "io_s": cold_st.charged_s},
        "hot_arm": {**hot_q.as_dict(), "io_s": hot_st.charged_s,
                    "hotset": hs.as_dict()},
    }
    result["tracked"] = {
        # the tentpole quantity: charged p50 of the packed-byte-only
        # arm over the hot-set arm on identical traffic (the decode the
        # resident tier skipped; acceptance floor 1.5x)
        "hotset_hit_advantage": advantage,
        # fraction of lookups answered from resident decoded runs
        "hotset_hit_rate": hs.hit_rate,
        # prefetch usefulness: of the runs the trace-driven prefetcher
        # decoded ahead of demand, the fraction a later lookup actually
        # hit (the rest aged out unused — wasted charged decode)
        "hotset_prefetch_hit_rate": hs.prefetch_hit_rate,
    }
    result["tracked_lower"] = {
        # the hot arm's charged request latency (virtual seconds) —
        # the serving floor the tier establishes
        "hotset_vclock_p50_s": hot_q.p50_s,
        "hotset_vclock_p99_s": hot_q.p99_s,
    }

    print("BENCH " + json.dumps(result))
    if out and out != "-":
        with open(out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out}")
    return result


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_hotset")
    ap.add_argument("--profile", default="null", choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--n-batches", type=int, default=48)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--hot-fraction", type=float, default=0.6)
    ap.add_argument("--out", default="BENCH_hotset.json")
    args = ap.parse_args()
    run(workdir=args.workdir, profile=args.profile, scale=args.scale,
        edge_factor=args.edge_factor, n_batches=args.n_batches,
        batch=args.batch, hot_fraction=args.hot_fraction, out=args.out)


if __name__ == "__main__":
    _main()
