"""Scaled-down dataset suite standing in for the paper's Table I.

The paper evaluates 12 graphs up to 128 G edges on a 2 PB Lustre system;
this container is CPU+tmpfs, so we generate graphs spanning ~3 orders of
magnitude of |E| with the same *type* mix (web-like local graphs that
compress well under gap encoding, social/synthetic RMAT skew, uniform ER)
and record both format sizes.  Relative effects (decode cost vs. read
granularity vs. compression ratio) are preserved; absolute GiB/s differ
and are recorded with every benchmark output.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core import compbin, paragrapher, webgraph
from repro.core.csr import CSR, csr_from_edges
from repro.graph.generators import erdos_renyi, rmat


def weblike(n_vertices: int, avg_deg: int, *, seed: int = 0,
            locality: float = 0.95) -> CSR:
    """Web-graph-like: most links point to nearby IDs (crawl order
    locality) -> small gaps -> strong WebGraph compression (paper Table I:
    web graphs compress 10-20x better than CompBin)."""
    rng = np.random.default_rng(seed)
    n_e = n_vertices * avg_deg
    src = rng.integers(0, n_vertices, n_e)
    local = rng.random(n_e) < locality
    offs = rng.geometric(0.2, n_e) * rng.choice([-1, 1], n_e)
    dst = np.where(local, (src + offs) % n_vertices,
                   rng.integers(0, n_vertices, n_e))
    return csr_from_edges(src, dst, n_vertices, dedupe=True)


def crawl(n_vertices: int, avg_deg: int, *, seed: int = 0) -> CSR:
    """Crawl-order web graph: each page links to a mostly-CONSECUTIVE run
    of pages near itself (navigational templates) — gap == 1 for most
    successors, the regime where WebGraph's gap+zeta coding reaches the
    paper's 10-20x ratios (uk-2014: 8.2 vs 183.2 GiB)."""
    rng = np.random.default_rng(seed)
    deg = np.maximum(1, rng.poisson(avg_deg, n_vertices))
    src = np.repeat(np.arange(n_vertices, dtype=np.int64), deg)
    start = np.repeat(rng.integers(1, 4, n_vertices), deg)
    within = np.concatenate([np.arange(d) for d in deg])
    dst = (src + start + within) % n_vertices
    return csr_from_edges(src, dst, n_vertices, dedupe=True)


@dataclasses.dataclass
class Dataset:
    name: str
    kind: str
    csr: CSR
    wg_path: str
    cb_path: str
    wg_bytes: int
    cb_bytes: int


SUITE = [
    # (name, kind, builder) — ordered by size, mirroring Table I's spread
    ("web-sm", "web", lambda: weblike(1 << 12, 12, seed=1)),
    ("social-sm", "social", lambda: rmat(12, 12, seed=2)),
    ("web-md", "web", lambda: weblike(1 << 15, 16, seed=3)),
    ("er-md", "uniform", lambda: erdos_renyi(1 << 15, 1 << 19, seed=4)),
    ("social-md", "social", lambda: rmat(16, 16, seed=5)),
    ("web-lg", "web", lambda: weblike(1 << 18, 16, seed=6)),
    ("social-lg", "social", lambda: rmat(18, 16, seed=7)),
    ("er-lg", "uniform", lambda: erdos_renyi(1 << 19, 1 << 23, seed=8)),
    # the >=100 MiB regime where Fig. 4's crossover lives
    ("web-xl", "web", lambda: weblike(1 << 21, 16, seed=9)),
    ("social-xl", "social", lambda: rmat(20, 16, seed=10)),
    # crawl-order graphs: the 10-20x compression regime (uk-2014 analog)
    ("crawl-lg", "web", lambda: crawl(1 << 19, 16, seed=11)),
    ("crawl-xl", "web", lambda: crawl(1 << 22, 16, seed=12)),
]


def build_suite(workdir: str, names: list[str] | None = None) -> list[Dataset]:
    os.makedirs(workdir, exist_ok=True)
    out = []
    for name, kind, builder in SUITE:
        if names and name not in names:
            continue
        wg_path = os.path.join(workdir, f"{name}.wg")
        cb_path = os.path.join(workdir, f"{name}.cbin")
        if not (os.path.exists(wg_path) and os.path.exists(cb_path)):
            csr = builder()
            paragrapher.save_graph(wg_path, csr, format="webgraph")
            paragrapher.save_graph(cb_path, csr, format="compbin")
        else:
            csr = compbin.read_compbin(cb_path)
        out.append(Dataset(name, kind, csr, wg_path, cb_path,
                           os.path.getsize(wg_path), os.path.getsize(cb_path)))
    return out
