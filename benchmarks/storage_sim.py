"""Storage emulation for the loading benchmarks.

The container's tmpfs/page-cache hides the phenomenon the paper measures
(per-request latency + limited bandwidth of Lustre/SSD/HDD), so benchmarks
read through :class:`SimStorage`, which charges

    t(request) = latency + bytes / bandwidth

per underlying request before returning real file data.  Presets follow
the paper's environment (§V-A: 2 PB Lustre, SSD pool, shared) plus the
HDD/SSD contrast of the earlier ParaGrapher study.  Charged time is
*accumulated* (virtual clock) rather than slept when ``sleep=False``,
keeping benchmark wall time low while preserving the arithmetic.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time


@dataclasses.dataclass
class StorageProfile:
    name: str
    latency_s: float
    bandwidth: float  # bytes/s


PROFILES = {
    # per-request latency, sustained bandwidth
    "lustre_ssd": StorageProfile("lustre_ssd", 300e-6, 2.0e9),
    # the paper's filesystem is SHARED among cluster users (§V-A); under
    # contention the per-client bandwidth share drops by ~an order
    "lustre_shared": StorageProfile("lustre_shared", 300e-6, 300e6),
    "local_ssd": StorageProfile("local_ssd", 80e-6, 1.0e9),
    "hdd": StorageProfile("hdd", 8e-3, 150e6),
    "null": StorageProfile("null", 0.0, float("inf")),
}


class SimStorage:
    """pread-compatible callable charging simulated storage time."""

    def __init__(self, profile: StorageProfile, *, sleep: bool = False):
        self.profile = profile
        self.sleep = sleep
        self._lock = threading.Lock()
        self.charged_s = 0.0
        self.requests = 0
        self.bytes = 0

    def charge(self, nbytes: int) -> None:
        dt = self.profile.latency_s + nbytes / self.profile.bandwidth
        with self._lock:
            self.charged_s += dt
            self.requests += 1
            self.bytes += nbytes
        if self.sleep:
            time.sleep(dt)

    def pread(self, fd: int, n: int, off: int) -> bytes:
        data = os.pread(fd, n, off)
        self.charge(len(data))
        return data

    def open_reader(self, path: str) -> "SimFile":
        return SimFile(path, self)

    def reset(self) -> None:
        with self._lock:
            self.charged_s = 0.0
            self.requests = 0
            self.bytes = 0


class SimFile:
    """Seekable file-like reading through a SimStorage (the *uncached*
    path: every consumer read is charged at consumer granularity — this is
    what the Java WebGraph reader does with its <=128 kB requests)."""

    def __init__(self, path: str, storage: SimStorage):
        self._f = open(path, "rb")
        self._storage = storage

    def seek(self, off: int, whence: int = os.SEEK_SET) -> int:
        return self._f.seek(off, whence)

    def tell(self) -> int:
        return self._f.tell()

    def read(self, n: int = -1) -> bytes:
        data = self._f.read(n)
        self._storage.charge(len(data))
        return data

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
