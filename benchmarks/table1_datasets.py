"""Table I analogue: dataset suite with per-format storage sizes."""

from __future__ import annotations

from repro.core import compbin
from benchmarks.datasets import build_suite


def run(workdir: str, names=None) -> list[dict]:
    rows = []
    for ds in build_suite(workdir, names):
        b = compbin.bytes_per_vertex(ds.csr.n_vertices)
        expected_cb = compbin.compbin_nbytes(ds.csr.n_vertices, ds.csr.n_edges)
        rows.append({
            "name": ds.name, "type": ds.kind,
            "V": ds.csr.n_vertices, "E": ds.csr.n_edges,
            "bytes_per_id": b,
            "webgraph_MiB": ds.wg_bytes / 2**20,
            "compbin_MiB": ds.cb_bytes / 2**20,
            "compression_ratio": ds.cb_bytes / max(ds.wg_bytes, 1),
        })
        assert ds.cb_bytes == expected_cb  # Table I accounting holds
    return rows


def main(workdir: str = "/tmp/repro_bench") -> None:
    rows = run(workdir)
    print(f"{'name':<12}{'type':<9}{'|V|':>9}{'|E|':>10}{'b':>3}"
          f"{'WG MiB':>9}{'CB MiB':>9}{'CB/WG':>7}")
    for r in rows:
        print(f"{r['name']:<12}{r['type']:<9}{r['V']:>9}{r['E']:>10}"
              f"{r['bytes_per_id']:>3}{r['webgraph_MiB']:>9.2f}"
              f"{r['compbin_MiB']:>9.2f}{r['compression_ratio']:>7.2f}")


if __name__ == "__main__":
    main()
