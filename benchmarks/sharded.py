"""Sharded scatter-gather serving benchmark (scale-out §IV).

Replays ONE deterministic zipf-hub k-hop trace through 1-, 2- and
4-shard :class:`repro.query.ShardedQueryService` deployments, each
shard replica a simulated process with its OWN :class:`SimStorage`
instance and its own slice of the total cache budget — the multihost
topology, on the serving path.  Every arm must visit identical vertex
sets (asserted: sharding is a layout change, not a semantics change);
the gated numbers are virtual-clock properties of the trace:

* **aggregate makespan** = max over shards of that shard's charged
  storage time (shards serve in parallel in a real deployment, so the
  slowest shard is the wall clock).  ``sharded_scaling_2x`` =
  1-shard makespan / 2-shard makespan, gated UPWARD in ``tracked`` and
  floor-asserted >= 1.5x here (the CI scale-out gate): splitting the
  range halves each shard's working set, so each shard's smaller cache
  budget holds its hot set — the advantage is locality + parallel
  storage, not accounting;
* **per-request latency** on the 2-shard arm (``sharded_vclock_p50_s``
  / ``_p99_s``, gated DOWNWARD in ``tracked_lower``): the service
  clock sums all shards' charged time, so one request's latency is
  the total storage work its scatter-gathered frontiers cost.
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.storage_sim import PROFILES, SimStorage
from benchmarks.traversal import _seed_trace

PGFUSE_BLOCK = 1 << 14
KHOP_K = 2
EDGE_BUDGET = 1 << 16
MIN_SCALING_2X = 1.5    # the CI scale-out gate (aggregate throughput)


def _replay_sharded(path: str, trace, profile: str, total_budget: int,
                    n_shards: int):
    """One arm: the whole trace through an ``n_shards`` deployment.

    Returns ``(traversal stats dict, per-shard storages, router dict,
    per-request visited counts)``.  Each shard's SimStorage starts at
    zero and charges only that shard's reads; the cache budget splits
    evenly, so no arm holds more total resident bytes than another —
    the comparison is layout, not capacity.
    """
    from repro.query import ShardedQueryService, TraversalService

    storages = [SimStorage(PROFILES[profile]) for _ in range(n_shards)]

    def open_kwargs(s: int, r: int) -> dict:
        return dict(pgfuse_block_size=PGFUSE_BLOCK,
                    pgfuse_max_resident_bytes=max(
                        4 * PGFUSE_BLOCK, total_budget // n_shards),
                    pgfuse_pread_fn=storages[s].pread)

    def clock() -> float:
        return sum(st.charged_s for st in storages)

    svc = ShardedQueryService(path, n_shards=n_shards, decode="host",
                              open_kwargs=open_kwargs, clock=clock)
    trav = TraversalService(svc)
    try:
        visited = [trav.khop(seeds, KHOP_K, max_edges=EDGE_BUDGET).n_visited
                   for seeds in trace]
        assert svc.conserved, "router/stat conservation broke"
        return (trav.stats.as_dict(), storages, svc.router.as_dict(),
                visited)
    finally:
        trav.close(), svc.close()


def run(workdir: str = "/tmp/repro_bench_sharded",
        profile: str = "lustre_ssd", scale: int = 15, edge_factor: int = 8,
        n_requests: int = 48, seeds_per_req: int = 4,
        out: str = "BENCH_sharded.json") -> dict:
    """The sharded-serving suite -> one BENCH json dict."""
    os.makedirs(workdir, exist_ok=True)

    from repro.core import paragrapher
    from repro.graph import rmat

    path = os.path.join(workdir, f"rmat{scale}x{edge_factor}.cbin")
    if not os.path.exists(path):
        paragrapher.save_graph(path, rmat(scale, edge_factor, seed=0),
                               format="compbin")
    with paragrapher.open_graph(path) as g:
        n_vertices = g.n_vertices
        file_bytes = os.path.getsize(path)
    trace = _seed_trace(n_vertices, n_requests, seeds_per_req)
    # HALF the file fits in cache in total (same pressure as the
    # traversal bench): 1 shard spills, N shards' slices fit better
    total_budget = max(4 * PGFUSE_BLOCK, file_bytes // 2)

    arms = {}
    ref_visited = None
    for n_shards in (1, 2, 4):
        st, storages, router, visited = _replay_sharded(
            path, trace, profile, total_budget, n_shards)
        if ref_visited is None:
            ref_visited = visited
        else:
            assert visited == ref_visited, \
                f"{n_shards}-shard arm diverged from 1-shard visit sets"
        arms[n_shards] = {
            "stats": st,
            "router": router,
            "makespan_s": max(s.charged_s for s in storages),
            "per_shard_io_s": [s.charged_s for s in storages],
            "underlying_reads": sum(s.requests for s in storages),
            "underlying_bytes": sum(s.bytes for s in storages),
        }

    scaling_2x = arms[1]["makespan_s"] / max(arms[2]["makespan_s"], 1e-12)
    scaling_4x = arms[1]["makespan_s"] / max(arms[4]["makespan_s"], 1e-12)
    assert scaling_2x >= MIN_SCALING_2X, (
        f"2-shard aggregate-throughput advantage {scaling_2x:.2f}x fell "
        f"below the {MIN_SCALING_2X}x scale-out gate")

    result = {
        "bench": "sharded_service",
        "profile": profile,
        "graph": {"scale": scale, "edge_factor": edge_factor,
                  "vertices": n_vertices, "file_bytes": file_bytes},
        "trace": {"n_requests": n_requests, "seeds_per_req": seeds_per_req,
                  "k": KHOP_K, "edge_budget": EDGE_BUDGET,
                  "total_cache_budget": total_budget},
        "arms": {str(k): v for k, v in arms.items()},
        "scaling_4x": scaling_4x,
    }
    result["tracked"] = {
        # what splitting the vertex range across 2 simulated processes
        # buys in aggregate makespan on identical traffic and total
        # cache bytes (parallel storage clocks + per-shard locality)
        "sharded_scaling_2x": scaling_2x,
    }
    result["tracked_lower"] = {
        # total charged-storage time one traversal observes on the
        # 2-shard deployment (virtual s; the summed-shards clock)
        "sharded_vclock_p50_s": arms[2]["stats"]["p50_s"],
        "sharded_vclock_p99_s": arms[2]["stats"]["p99_s"],
    }

    print("BENCH " + json.dumps(result))
    if out and out != "-":
        with open(out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out}")
    return result


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_sharded")
    ap.add_argument("--profile", default="lustre_ssd",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=15)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--out", default="BENCH_sharded.json")
    args = ap.parse_args()
    run(workdir=args.workdir, profile=args.profile, scale=args.scale,
        edge_factor=args.edge_factor, n_requests=args.n_requests,
        out=args.out)


if __name__ == "__main__":
    _main()
