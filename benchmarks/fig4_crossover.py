"""Fig. 4 analogue: PG-Fuse-vs-CompBin speedup against format size diff.

Claim validated (paper §V-D): when (CompBin size - WebGraph size) is small
the ratio is < 1 (CompBin loads faster); as the difference grows the ratio
crosses 1 and WebGraph+PG-Fuse wins.  Thresholds are system-dependent
(storage bandwidth vs. decode rate) — we report the measured crossover for
each storage profile.
"""

from __future__ import annotations

from benchmarks.datasets import build_suite
from benchmarks.loading import load_compbin, load_webgraph_pgfuse


def run(workdir: str, profile: str = "lustre_shared", names=None) -> list[dict]:
    # default profile: the bandwidth-constrained regime; the paper's
    # 50-100 GiB thresholds scale with (storage bw x decode rate), §V-D
    rows = []
    for ds in build_suite(workdir, names):
        fuse = load_webgraph_pgfuse(ds.wg_path, profile)
        cb = load_compbin(ds.cb_path, profile)
        rows.append({
            "name": ds.name,
            "size_diff_MiB": (ds.cb_bytes - ds.wg_bytes) / 2**20,
            "pgfuse_over_compbin": cb.total_s / max(fuse.total_s, 1e-12),
        })
    rows.sort(key=lambda r: r["size_diff_MiB"])
    return rows


def crossover_MiB(rows: list[dict]):
    prev = None
    for r in rows:
        if prev and prev["pgfuse_over_compbin"] < 1 <= r["pgfuse_over_compbin"]:
            return 0.5 * (prev["size_diff_MiB"] + r["size_diff_MiB"])
        prev = r
    return None


def main(workdir: str = "/tmp/repro_bench", profile: str = "lustre_shared") -> None:
    rows = run(workdir, profile)
    print(f"[fig4] storage profile: {profile} "
          "(y>1: PG-Fuse faster; y<1: CompBin faster)")
    print(f"{'name':<12}{'size diff MiB':>14}{'PGFuse/CompBin':>16}")
    for r in rows:
        print(f"{r['name']:<12}{r['size_diff_MiB']:>14.2f}"
              f"{r['pgfuse_over_compbin']:>16.2f}")
    x = crossover_MiB(rows)
    print(f"crossover at ~{x:.1f} MiB size difference" if x
          else "no crossover within suite (one format dominates)")


if __name__ == "__main__":
    main()
