"""Graph-compiler benchmark: what locality reordering + recompression
buy the serving path on identical logical traffic.

Builds one RMAT graph, destroys its incidental vertex locality with a
seeded random permutation (the "whatever order the crawl emitted" arm
the paper's loaders inherit), then runs the offline compiler
(:func:`repro.graph.reorder.compile_graph`) over the scrambled graph:
BFS locality ordering + re-encode through the bit-packed LogCSR codec,
with the inverse permutation persisted in the sidecar.

Both arms then replay the IDENTICAL logical zipf trace (hub-heavy,
degree-correlated — ids drawn in the scrambled space, translated into
compiled ids for the reordered arm) through the same budget-capped
PG-Fuse cache and the same charged host-decode model as
``benchmarks/query.py``.  An order-invariant answer checksum — the
reordered arm's runs inverse-mapped through the sidecar
(:func:`repro.graph.reorder.map_back`) — asserts the compiled graph
answers byte-identically to the original.

Gated numbers (``tracked``, higher is better): ``reorder_hit_rate``
(the compiled arm's PG-Fuse block hit rate), ``reorder_hit_rate_gain``
(compiled minus scrambled hit rate on the same trace; in-bench floor
``MIN_HIT_GAIN``), ``reorder_blocks_advantage`` (scrambled-arm block
loads over compiled-arm block loads — the misses reordering removed),
and ``reorder_compression_ratio`` (input CompBin bytes per output
LogCSR byte).  ``tracked_lower``: the compiled arm's charged p50/p99.
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.query import HOST_DECODE_EDGES_PER_S, PGFUSE_BLOCK
from benchmarks.storage_sim import PROFILES, SimStorage

# the in-bench floor mirroring the CI gate: on the same logical trace
# and cache budget, the compiled graph's block hit rate must beat the
# scrambled original's by at least this much (absolute)
MIN_HIT_GAIN = 0.02


def _degree_trace(degrees: np.ndarray, n_batches: int, batch: int,
                  *, hot_fraction: float = 0.6, seed: int = 0):
    """Hub-heavy deterministic traffic in the ORIGINAL id space — same
    shape as the hotset suite's trace: ``hot_fraction`` of lookups hit
    the top-degree hub set, the rest are uniform."""
    n = degrees.shape[0]
    hubs = np.argsort(degrees)[::-1][:max(16, n >> 10)].astype(np.int64)
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_batches):
        hot = hubs[rng.integers(0, len(hubs), batch)]
        cold = rng.integers(0, n, batch)
        trace.append(np.where(rng.random(batch) < hot_fraction, hot, cold))
    return trace, hubs


def _replay(path: str, trace, profile: str, *, budget: int,
            old_of_new: np.ndarray = None):
    """One engine over the whole logical trace; returns (QueryStats,
    PGFuseStats, SimStorage, checksum).  When ``old_of_new`` is given
    the file is a COMPILED graph: request ids are translated into
    compiled ids before the lookup and every answered run is inverse-
    mapped back (:func:`repro.graph.reorder.map_back`) before it enters
    the checksum — so equal checksums mean the compiled arm's answers,
    in original ids, match the original arm's."""
    from repro.core import paragrapher, policy
    from repro.graph import reorder as _reorder
    from repro.query import NeighborQueryEngine

    amode = policy.choose_access_mode("serve")
    storage = SimStorage(PROFILES[profile])
    vdecode = [0.0]
    new_of_old = None if old_of_new is None \
        else _reorder.invert_permutation(old_of_new)
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=PGFUSE_BLOCK,
        pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
        pgfuse_max_resident_bytes=budget, pgfuse_pread_fn=storage.pread)
    try:
        engine = NeighborQueryEngine(
            g, decode="host",
            clock=lambda: storage.charged_s + vdecode[0])
        b = g.bytes_per_id
        orig_host = engine._decode_host

        def charged_host(packed):
            vdecode[0] += (sum(p.size for p in packed) // b) \
                / HOST_DECODE_EDGES_PER_S
            return orig_host(packed)

        engine._decode_host = charged_host
        checksum = 0
        for ids in trace:
            lookup = ids if new_of_old is None else new_of_old[ids]
            for v, neigh in zip(ids, engine.neighbors_batch(lookup)):
                if old_of_new is not None:
                    neigh = _reorder.map_back(old_of_new, neigh)
                checksum += int(v) * int(neigh.sum()) + neigh.size
        return engine.stats, g.pgfuse_stats(), storage, checksum
    finally:
        g.close()


def run(workdir: str = "/tmp/repro_bench_reorder",
        profile: str = "lustre_ssd",
        scale: int = 16, edge_factor: int = 16, n_batches: int = 48,
        batch: int = 256, hot_fraction: float = 0.6,
        out: str = "BENCH_reorder.json") -> dict:
    """The reorder suite: scrambled original vs BFS-compiled LogCSR on
    one logical zipf trace, emitted as one BENCH json dict (CI gates
    ``tracked`` upward and ``tracked_lower`` downward)."""
    os.makedirs(workdir, exist_ok=True)

    from repro.core import paragrapher
    from repro.graph import reorder as _reorder
    from repro.graph.generators import rmat

    base = rmat(scale, edge_factor, seed=0)
    # RMAT already clusters its hubs at low ids; a random relabeling
    # recreates the no-locality ordering real crawls hand the loader
    scramble = np.random.default_rng(7).permutation(
        base.n_vertices).astype(np.int64)
    csr = _reorder.permute_csr(base, scramble)
    orig_path = os.path.join(workdir,
                             f"rmat{scale}x{edge_factor}_scrambled.cbin")
    if not os.path.exists(orig_path):
        paragrapher.save_graph(orig_path, csr, format="compbin")

    # the offline compile: BFS locality order + LogCSR re-encode
    reord_path = os.path.join(workdir,
                              f"rmat{scale}x{edge_factor}_bfs.lgsr")
    report = _reorder.compile_graph(orig_path, reord_path, codec="logcsr",
                                    strategy="bfs", verify_samples=64)
    old_of_new = _reorder.read_sidecar(report.sidecar_path)

    degrees = np.diff(csr.offsets)
    trace, hubs = _degree_trace(degrees, n_batches, batch,
                                hot_fraction=hot_fraction)
    # budget-capped cache: far smaller than the file, so the block hit
    # rate IS the locality of the byte layout under this trace
    orig_bytes = os.path.getsize(orig_path)
    budget = max(8 * PGFUSE_BLOCK, orig_bytes // 8)

    orig_q, orig_pg, orig_st, orig_sum = _replay(
        orig_path, trace, profile, budget=budget)
    reord_q, reord_pg, reord_st, reord_sum = _replay(
        reord_path, trace, profile, budget=budget, old_of_new=old_of_new)
    assert reord_sum == orig_sum, \
        f"compiled arm diverged from original: {reord_sum} != {orig_sum}"

    def hit_rate(pg):
        n = pg.cache_hits + pg.cache_misses
        return pg.cache_hits / n if n else 0.0

    gain = hit_rate(reord_pg) - hit_rate(orig_pg)
    assert gain >= MIN_HIT_GAIN, \
        f"reorder_hit_rate_gain {gain:.4f} < {MIN_HIT_GAIN}"
    blocks_advantage = orig_pg.cache_misses / max(reord_pg.cache_misses, 1)

    result = {
        "bench": "reorder",
        "profile": profile,
        "graph": {"scale": scale, "edge_factor": edge_factor,
                  "vertices": csr.n_vertices, "edges": csr.n_edges,
                  "hubs": int(len(hubs))},
        "trace": {"n_batches": n_batches, "batch": batch,
                  "hot_fraction": hot_fraction,
                  "requests": reord_q.requests},
        "compile": report.as_dict(),
        "budget_bytes": budget,
        "original_arm": {**orig_q.as_dict(),
                         "pgfuse": orig_pg.as_dict(),
                         "io_s": orig_st.charged_s,
                         "file_bytes": orig_bytes},
        "compiled_arm": {**reord_q.as_dict(),
                         "pgfuse": reord_pg.as_dict(),
                         "io_s": reord_st.charged_s,
                         "file_bytes": os.path.getsize(reord_path)},
    }
    result["tracked"] = {
        # block hit rate of the compiled (BFS + LogCSR) arm under the
        # capped cache — the locality the compiler manufactured
        "reorder_hit_rate": hit_rate(reord_pg),
        # compiled minus scrambled hit rate on the identical logical
        # trace (acceptance floor MIN_HIT_GAIN)
        "reorder_hit_rate_gain": gain,
        # block loads the reordering removed: scrambled-arm misses over
        # compiled-arm misses
        "reorder_blocks_advantage": blocks_advantage,
        # input CompBin bytes per output LogCSR byte (the bit-packed
        # offsets + thinner neighbor ids)
        "reorder_compression_ratio": report.compression_ratio,
    }
    result["tracked_lower"] = {
        # the compiled arm's charged request latency (virtual seconds)
        "reorder_vclock_p50_s": reord_q.p50_s,
        "reorder_vclock_p99_s": reord_q.p99_s,
    }

    print("BENCH " + json.dumps(result))
    if out and out != "-":
        with open(out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out}")
    return result


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_reorder")
    ap.add_argument("--profile", default="lustre_ssd",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--n-batches", type=int, default=48)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--hot-fraction", type=float, default=0.6)
    ap.add_argument("--out", default="BENCH_reorder.json")
    args = ap.parse_args()
    run(workdir=args.workdir, profile=args.profile, scale=args.scale,
        edge_factor=args.edge_factor, n_batches=args.n_batches,
        batch=args.batch, hot_fraction=args.hot_fraction, out=args.out)


if __name__ == "__main__":
    _main()
