"""Fig. 3 analogue: CompBin & PG-Fuse speedup over baseline ParaGrapher.

Claim validated (paper §V-C): CompBin (eq. 1 shift+add decode) beats
WebGraph decode for small graphs (paper: up to 21.8x); for large
well-compressed web graphs the fat CompBin read becomes storage-bound and
WebGraph(+PG-Fuse) wins.
"""

from __future__ import annotations

from benchmarks.datasets import build_suite
from benchmarks.loading import (load_compbin, load_webgraph_direct,
                                load_webgraph_pgfuse)


def run(workdir: str, profile: str = "lustre_ssd", names=None) -> list[dict]:
    rows = []
    for ds in build_suite(workdir, names):
        base = load_webgraph_direct(ds.wg_path, profile)
        fuse = load_webgraph_pgfuse(ds.wg_path, profile)
        cb = load_compbin(ds.cb_path, profile)
        rows.append({
            "name": ds.name, "E": ds.csr.n_edges,
            "base_s": base.total_s,
            "compbin_speedup": base.total_s / max(cb.total_s, 1e-12),
            "pgfuse_speedup": base.total_s / max(fuse.total_s, 1e-12),
            "compbin_decode_s": cb.decode_s, "webgraph_decode_s": base.decode_s,
        })
    return rows


def main(workdir: str = "/tmp/repro_bench", profile: str = "lustre_ssd") -> None:
    rows = run(workdir, profile)
    print(f"[fig3] storage profile: {profile}")
    print(f"{'name':<12}{'|E|':>10}{'CompBin x':>10}{'PG-Fuse x':>10}"
          f"{'decode CB/WG s':>18}")
    for r in rows:
        print(f"{r['name']:<12}{r['E']:>10}{r['compbin_speedup']:>10.2f}"
              f"{r['pgfuse_speedup']:>10.2f}"
              f"{r['compbin_decode_s']:>9.3f}/{r['webgraph_decode_s']:<8.3f}")


if __name__ == "__main__":
    main()
