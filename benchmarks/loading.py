"""Measured graph-loading pipelines (the quantity in Figs. 2-4).

load time = charged storage time (SimStorage virtual clock, paper §V-A
profiles) + decode time.  The no-PG-Fuse path charges requests at the
Java WebGraph consumer granularity (<=128 kB, §III) — on Lustre the
per-request RPC latency does NOT overlap away, which is exactly why small
requests cap effective bandwidth (128 kB / (128 kB/2 GBps + 300 us)
~ 350 MB/s vs the 2 GB/s sequential rate — the 5-7x headroom PG-Fuse
recovers).  The PG-Fuse path reads 32 MiB blocks through the cache.

**Host-scale calibration**: the paper's machine decodes on 128 cores;
this container has one.  Decode wall time is measured serially and
divided by ``decode_parallelism`` (default 128, perfect-scaling
assumption — conservative for the PG-Fuse comparison since it shrinks
the term PG-Fuse does NOT accelerate). Recorded with every output.
"""

from __future__ import annotations

import dataclasses
import io
import time

from repro.core import compbin, pgfuse, webgraph
from benchmarks.storage_sim import PROFILES, SimStorage

JAVA_REQUEST = 128 << 10      # the paper's observed JVM request size
PGFUSE_BLOCK = 32 << 20       # paper default
DECODE_PARALLELISM = 128      # paper host: 2x AMD 7702, 128 cores


@dataclasses.dataclass
class LoadResult:
    io_s: float
    decode_s: float
    requests: int
    bytes_read: int

    @property
    def total_s(self) -> float:
        return self.io_s + self.decode_s


class _ChargedFile:
    """File-like charging SimStorage per consumer request, split at the
    consumer granularity (emulating many small JVM reads)."""

    def __init__(self, path: str, storage: SimStorage, granularity: int):
        self._f = open(path, "rb")
        self._storage = storage
        self._gran = granularity

    def seek(self, *a):
        return self._f.seek(*a)

    def tell(self):
        return self._f.tell()

    def read(self, n: int = -1) -> bytes:
        data = self._f.read(n)
        off = 0
        while off < len(data):  # one storage request per granularity chunk
            self._storage.charge(min(self._gran, len(data) - off))
            off += self._gran
        return data

    def close(self):
        self._f.close()


def _timed_decode(reader, parallelism: int) -> float:
    t0 = time.perf_counter()
    reader.read_full()
    return (time.perf_counter() - t0) / max(1, parallelism)


def load_webgraph_direct(path: str, profile: str = "lustre_ssd",
                         decode_parallelism: int = DECODE_PARALLELISM
                         ) -> LoadResult:
    """ParaGrapher without PG-Fuse: small-granularity charged reads."""
    storage = SimStorage(PROFILES[profile])
    f = _ChargedFile(path, storage, JAVA_REQUEST)
    rd = webgraph.WebGraphFile(f)
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    f.close()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_webgraph_pgfuse(path: str, profile: str = "lustre_ssd",
                         block_size: int = PGFUSE_BLOCK,
                         decode_parallelism: int = DECODE_PARALLELISM
                         ) -> LoadResult:
    """ParaGrapher with PG-Fuse: 32 MiB blocks + in-memory cache."""
    storage = SimStorage(PROFILES[profile])
    fs = pgfuse.PGFuseFS(block_size=block_size, pread_fn=storage.pread)
    rd = webgraph.WebGraphFile(fs.open(path))
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    fs.unmount()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_compbin(path: str, profile: str = "lustre_ssd",
                 use_pgfuse: bool = False,
                 decode_parallelism: int = DECODE_PARALLELISM) -> LoadResult:
    """CompBin/binary-CSR load: bigger read, shift+add decode (eq. 1)."""
    storage = SimStorage(PROFILES[profile])
    if use_pgfuse:
        fs = pgfuse.PGFuseFS(block_size=PGFUSE_BLOCK, pread_fn=storage.pread)
        f = fs.open(path)
    else:
        # binary CSR maps/streams the file at large granularity natively
        f = _ChargedFile(path, storage, PGFUSE_BLOCK)
    rd = compbin.CompBinFile(f)
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)
