"""Measured graph-loading pipelines (the quantity in Figs. 2-4).

load time = charged storage time (SimStorage virtual clock, paper §V-A
profiles) + decode time.  The no-PG-Fuse path charges requests at the
Java WebGraph consumer granularity (<=128 kB, §III) — on Lustre the
per-request RPC latency does NOT overlap away, which is exactly why small
requests cap effective bandwidth (128 kB / (128 kB/2 GBps + 300 us)
~ 350 MB/s vs the 2 GB/s sequential rate — the 5-7x headroom PG-Fuse
recovers).  The PG-Fuse path reads 32 MiB blocks through the cache.

**Host-scale calibration**: the paper's machine decodes on 128 cores;
this container has one.  Decode wall time is measured serially and
divided by ``decode_parallelism`` (default 128, perfect-scaling
assumption — conservative for the PG-Fuse comparison since it shrinks
the term PG-Fuse does NOT accelerate). Recorded with every output.
"""

from __future__ import annotations

import dataclasses
import io
import time

from repro.core import compbin, pgfuse, webgraph
from benchmarks.storage_sim import PROFILES, SimStorage

JAVA_REQUEST = 128 << 10      # the paper's observed JVM request size
PGFUSE_BLOCK = 32 << 20       # paper default
DECODE_PARALLELISM = 128      # paper host: 2x AMD 7702, 128 cores


@dataclasses.dataclass
class LoadResult:
    io_s: float
    decode_s: float
    requests: int
    bytes_read: int

    @property
    def total_s(self) -> float:
        return self.io_s + self.decode_s


class _ChargedFile:
    """File-like charging SimStorage per consumer request, split at the
    consumer granularity (emulating many small JVM reads)."""

    def __init__(self, path: str, storage: SimStorage, granularity: int):
        self._f = open(path, "rb")
        self._storage = storage
        self._gran = granularity

    def seek(self, *a):
        return self._f.seek(*a)

    def tell(self):
        return self._f.tell()

    def read(self, n: int = -1) -> bytes:
        data = self._f.read(n)
        off = 0
        while off < len(data):  # one storage request per granularity chunk
            self._storage.charge(min(self._gran, len(data) - off))
            off += self._gran
        return data

    def close(self):
        self._f.close()


def _timed_decode(reader, parallelism: int) -> float:
    t0 = time.perf_counter()
    reader.read_full()
    return (time.perf_counter() - t0) / max(1, parallelism)


def load_webgraph_direct(path: str, profile: str = "lustre_ssd",
                         decode_parallelism: int = DECODE_PARALLELISM
                         ) -> LoadResult:
    """ParaGrapher without PG-Fuse: small-granularity charged reads."""
    storage = SimStorage(PROFILES[profile])
    f = _ChargedFile(path, storage, JAVA_REQUEST)
    rd = webgraph.WebGraphFile(f)
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    f.close()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_webgraph_pgfuse(path: str, profile: str = "lustre_ssd",
                         block_size: int = PGFUSE_BLOCK,
                         decode_parallelism: int = DECODE_PARALLELISM
                         ) -> LoadResult:
    """ParaGrapher with PG-Fuse: 32 MiB blocks + in-memory cache."""
    storage = SimStorage(PROFILES[profile])
    fs = pgfuse.PGFuseFS(block_size=block_size, pread_fn=storage.pread)
    rd = webgraph.WebGraphFile(fs.open(path))
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    fs.unmount()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_compbin(path: str, profile: str = "lustre_ssd",
                 use_pgfuse: bool = False,
                 decode_parallelism: int = DECODE_PARALLELISM) -> LoadResult:
    """CompBin/binary-CSR load: bigger read, shift+add decode (eq. 1)."""
    storage = SimStorage(PROFILES[profile])
    if use_pgfuse:
        fs = pgfuse.PGFuseFS(block_size=PGFUSE_BLOCK, pread_fn=storage.pread)
        f = fs.open(path)
    else:
        # binary CSR maps/streams the file at large granularity natively
        f = _ChargedFile(path, storage, PGFUSE_BLOCK)
    rd = compbin.CompBinFile(f)
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_streaming(path: str, profile: str = "lustre_ssd",
                   block_size: int = PGFUSE_BLOCK,
                   readahead: int = 2, n_parts: int = 16,
                   n_buffers: int = 2, feature_path: str = None,
                   align: int = 1):
    """The streaming partition->device loader (data/graph_stream.py).

    Storage is charged through the same SimStorage virtual clock as the
    host loaders; decode happens in the Pallas kernel on device, so
    ``decode_s`` here is measured device time (no /128 host-parallelism
    rescale).  ``feature_path`` streams a node-feature store through the
    same mount (its reads charge the same clock).  Returns
    (LoadResult, StreamStats).
    """
    from repro.core import paragrapher
    from repro.data.graph_stream import stream_partitions

    storage = SimStorage(PROFILES[profile])
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=block_size,
        pgfuse_readahead=readahead, pgfuse_pread_fn=storage.pread)
    try:
        with stream_partitions(g, None, n_buffers=n_buffers,
                               readahead=readahead, n_parts=n_parts,
                               feature_path=feature_path,
                               align=align) as stream:
            for _ in stream:
                pass
            stats = stream.stats
    finally:
        g.close()
    return (LoadResult(storage.charged_s, stats.decode_s,
                       storage.requests, storage.bytes), stats)


def load_streaming_multihost(path: str, hosts: int,
                             profile: str = "lustre_ssd",
                             block_size: int = PGFUSE_BLOCK,
                             readahead: int = 2, n_parts: int = 16,
                             n_buffers: int = 2, feature_path: str = None,
                             align: int = 1, shares=None):
    """Multi-host simulated streamed load (data/multihost.py).

    Every simulated host mounts its own PG-Fuse cache over its own
    SimStorage clock (hosts do not share a storage port in the modeled
    cluster), streams its slice of the shared plan, and reports its own
    StreamStats.  Returns (io_s, per_host, aggregate) where ``io_s`` is
    the max charged storage time over hosts (the cluster's wall-clock:
    hosts load concurrently and training starts when the slowest
    finishes) and ``per_host`` is [(StreamStats, SimStorage), ...] in
    process order.
    """
    from repro.data.multihost import aggregate_stats, simulate_hosts

    storages = [SimStorage(PROFILES[profile]) for _ in range(hosts)]
    results = simulate_hosts(
        path, hosts,
        open_kwargs=lambda i: dict(
            use_pgfuse=True, pgfuse_block_size=block_size,
            pgfuse_readahead=readahead, pgfuse_pread_fn=storages[i].pread),
        n_buffers=n_buffers, readahead=readahead, n_parts=n_parts,
        feature_path=feature_path, align=align, shares=shares)
    agg = aggregate_stats(results)
    io_s = max((st.charged_s for st in storages), default=0.0)
    return io_s, [(r.stats, st) for r, st in zip(results, storages)], agg


def run(workdir: str = "/tmp/repro_bench_stream",
        profile: str = "lustre_ssd", scale: int = 16, edge_factor: int = 24,
        readahead: int = 2, n_parts: int = 16, hosts: int = 1,
        d_feat: int = 16, out: str = "BENCH_loading.json") -> dict:
    """The loading suite: streaming loader (topology + feature store) vs
    the host path, emitted as one BENCH json dict.

    ``out`` also writes the dict to a JSON file (the artifact CI's bench
    lane tracks); pass None/"-" to skip the file.  The ``tracked``
    section holds the regression-gated throughput metrics: every one is
    derived from the SimStorage VIRTUAL clock and deterministic byte
    counters, so the numbers are a property of the loader's request
    pattern, not of the machine running CI (``benchmarks/compare.py``
    gates on these; wall-clock figures elsewhere in the dict are
    advisory).
    """
    import json
    import os

    os.makedirs(workdir, exist_ok=True)

    from repro.core import paragrapher, policy
    from repro.graph import featstore_for_graph, rmat

    path = os.path.join(workdir, f"rmat{scale}x{edge_factor}.cbin")
    if not os.path.exists(path):
        csr = rmat(scale, edge_factor, seed=0)
        paragrapher.save_graph(path, csr, format="compbin")
    feature_path = None
    align = 1
    if d_feat > 0:
        feature_path = os.path.join(
            workdir, f"rmat{scale}x{edge_factor}_d{d_feat}.fst")
        if not os.path.exists(feature_path):
            featstore_for_graph(path, feature_path, d_feat, seed=0,
                                data_align=PGFUSE_BLOCK)
        with paragrapher.open_graph(path) as g:
            align = policy.choose_feature_align(
                PGFUSE_BLOCK, d_feat * 4, g.n_vertices, max(1, hosts))

    host = load_compbin(path, profile, use_pgfuse=True, decode_parallelism=1)
    res, stats = load_streaming(path, profile, readahead=readahead,
                                n_parts=n_parts, feature_path=feature_path,
                                align=align)
    result = {
        "bench": "streaming_loader",
        "profile": profile,
        "graph": {"scale": scale, "edge_factor": edge_factor,
                  "edges": stats.edges, "vertices": stats.vertices,
                  "d_feat": d_feat},
        "streaming": {"io_s": res.io_s, "decode_s": res.decode_s,
                      "total_s": res.total_s, "requests": res.requests,
                      "bytes_read": res.bytes_read, **stats.as_dict()},
        "host_pgfuse": {"io_s": host.io_s, "decode_s": host.decode_s,
                        "total_s": host.total_s, "requests": host.requests,
                        "bytes_read": host.bytes_read},
        "h2d_saving": 1.0 - stats.bytes_h2d / max(1, 4 * stats.edges),
    }
    io_s = max(res.io_s, 1e-12)
    tracked = {
        # bytes/s off virtual storage: drops when the request pattern
        # degrades (smaller requests, lost readahead, cache thrash)
        "streaming_io_MBps": res.bytes_read / io_s / 1e6,
        "streaming_edges_per_io_s": stats.edges / io_s,
        "host_pgfuse_io_MBps": host.bytes_read / max(host.io_s, 1e-12) / 1e6,
        # pure byte arithmetic: the packed-transfer saving and the
        # feature cache's block hit rate
        "h2d_saving": result["h2d_saving"],
    }
    if d_feat > 0:
        tracked["feature_MBps"] = stats.feature_bytes / io_s / 1e6
        tracked["feature_hit_rate"] = stats.feature_hit_rate
    if hosts > 1:
        mh_io, per_host, agg = load_streaming_multihost(
            path, hosts, profile, readahead=readahead,
            n_parts=max(n_parts, hosts), feature_path=feature_path,
            align=align)
        result["multihost"] = {
            "hosts": hosts,
            "io_s": mh_io,                   # slowest host's charged time
            "aggregate": agg.as_dict(),
            "per_host": [{"process_index": i, "io_s": st.charged_s,
                          **s.as_dict()}
                         for i, (s, st) in enumerate(per_host)],
        }
        total_bytes = sum(st.bytes for _, st in per_host)
        tracked["multihost_io_MBps"] = total_bytes / max(mh_io, 1e-12) / 1e6
        tracked["multihost_edges_per_io_s"] = agg.edges / max(mh_io, 1e-12)
    result["tracked"] = tracked

    line = "BENCH " + json.dumps(result)
    print(line)
    if out and out != "-":
        with open(out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out}")
    return result


def _bench_streaming_main() -> None:
    """Emit the loading BENCH json (stdout + ``--out`` file).

        PYTHONPATH=src python -m benchmarks.loading [--hosts 2] [--scale 16]
    """
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_stream")
    ap.add_argument("--profile", default="lustre_ssd",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--edge-factor", type=int, default=24)
    ap.add_argument("--readahead", type=int, default=2)
    ap.add_argument("--n-parts", type=int, default=16)
    ap.add_argument("--hosts", type=int, default=1,
                    help="also measure an N-host simulated streamed load")
    ap.add_argument("--d-feat", type=int, default=16,
                    help="feature dim of the streamed node-feature store "
                         "(0 disables the feature stage)")
    ap.add_argument("--out", default="BENCH_loading.json",
                    help='output JSON path ("-" to skip the file)')
    args = ap.parse_args()
    run(workdir=args.workdir, profile=args.profile, scale=args.scale,
        edge_factor=args.edge_factor, readahead=args.readahead,
        n_parts=args.n_parts, hosts=args.hosts, d_feat=args.d_feat,
        out=args.out)


if __name__ == "__main__":
    _bench_streaming_main()
