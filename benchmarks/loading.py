"""Measured graph-loading pipelines (the quantity in Figs. 2-4).

load time = charged storage time (SimStorage virtual clock, paper §V-A
profiles) + decode time.  The no-PG-Fuse path charges requests at the
Java WebGraph consumer granularity (<=128 kB, §III) — on Lustre the
per-request RPC latency does NOT overlap away, which is exactly why small
requests cap effective bandwidth (128 kB / (128 kB/2 GBps + 300 us)
~ 350 MB/s vs the 2 GB/s sequential rate — the 5-7x headroom PG-Fuse
recovers).  The PG-Fuse path reads 32 MiB blocks through the cache.

**Host-scale calibration**: the paper's machine decodes on 128 cores;
this container has one.  Decode wall time is measured serially and
divided by ``decode_parallelism`` (default 128, perfect-scaling
assumption — conservative for the PG-Fuse comparison since it shrinks
the term PG-Fuse does NOT accelerate). Recorded with every output.
"""

from __future__ import annotations

import dataclasses
import io
import time

from repro.core import compbin, pgfuse, webgraph
from benchmarks.storage_sim import PROFILES, SimStorage

JAVA_REQUEST = 128 << 10      # the paper's observed JVM request size
PGFUSE_BLOCK = 32 << 20       # paper default
DECODE_PARALLELISM = 128      # paper host: 2x AMD 7702, 128 cores


@dataclasses.dataclass
class LoadResult:
    io_s: float
    decode_s: float
    requests: int
    bytes_read: int

    @property
    def total_s(self) -> float:
        return self.io_s + self.decode_s


class _ChargedFile:
    """File-like charging SimStorage per consumer request, split at the
    consumer granularity (emulating many small JVM reads)."""

    def __init__(self, path: str, storage: SimStorage, granularity: int):
        self._f = open(path, "rb")
        self._storage = storage
        self._gran = granularity

    def seek(self, *a):
        return self._f.seek(*a)

    def tell(self):
        return self._f.tell()

    def read(self, n: int = -1) -> bytes:
        data = self._f.read(n)
        off = 0
        while off < len(data):  # one storage request per granularity chunk
            self._storage.charge(min(self._gran, len(data) - off))
            off += self._gran
        return data

    def close(self):
        self._f.close()


def _timed_decode(reader, parallelism: int) -> float:
    t0 = time.perf_counter()
    reader.read_full()
    return (time.perf_counter() - t0) / max(1, parallelism)


def load_webgraph_direct(path: str, profile: str = "lustre_ssd",
                         decode_parallelism: int = DECODE_PARALLELISM
                         ) -> LoadResult:
    """ParaGrapher without PG-Fuse: small-granularity charged reads."""
    storage = SimStorage(PROFILES[profile])
    f = _ChargedFile(path, storage, JAVA_REQUEST)
    rd = webgraph.WebGraphFile(f)
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    f.close()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_webgraph_pgfuse(path: str, profile: str = "lustre_ssd",
                         block_size: int = PGFUSE_BLOCK,
                         decode_parallelism: int = DECODE_PARALLELISM
                         ) -> LoadResult:
    """ParaGrapher with PG-Fuse: 32 MiB blocks + in-memory cache."""
    storage = SimStorage(PROFILES[profile])
    fs = pgfuse.PGFuseFS(block_size=block_size, pread_fn=storage.pread)
    rd = webgraph.WebGraphFile(fs.open(path))
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    fs.unmount()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_compbin(path: str, profile: str = "lustre_ssd",
                 use_pgfuse: bool = False,
                 decode_parallelism: int = DECODE_PARALLELISM) -> LoadResult:
    """CompBin/binary-CSR load: bigger read, shift+add decode (eq. 1)."""
    storage = SimStorage(PROFILES[profile])
    if use_pgfuse:
        fs = pgfuse.PGFuseFS(block_size=PGFUSE_BLOCK, pread_fn=storage.pread)
        f = fs.open(path)
    else:
        # binary CSR maps/streams the file at large granularity natively
        f = _ChargedFile(path, storage, PGFUSE_BLOCK)
    rd = compbin.CompBinFile(f)
    dt = _timed_decode(rd, decode_parallelism)
    rd.close()
    return LoadResult(storage.charged_s, dt, storage.requests, storage.bytes)


def load_streaming(path: str, profile: str = "lustre_ssd",
                   block_size: int = PGFUSE_BLOCK,
                   readahead: int = 2, n_parts: int = 16,
                   n_buffers: int = 2):
    """The streaming partition->device loader (data/graph_stream.py).

    Storage is charged through the same SimStorage virtual clock as the
    host loaders; decode happens in the Pallas kernel on device, so
    ``decode_s`` here is measured device time (no /128 host-parallelism
    rescale).  Returns (LoadResult, StreamStats).
    """
    from repro.core import paragrapher
    from repro.data.graph_stream import stream_partitions

    storage = SimStorage(PROFILES[profile])
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=block_size,
        pgfuse_readahead=readahead, pgfuse_pread_fn=storage.pread)
    try:
        with stream_partitions(g, None, n_buffers=n_buffers,
                               readahead=readahead, n_parts=n_parts) as stream:
            for _ in stream:
                pass
            stats = stream.stats
    finally:
        g.close()
    return (LoadResult(storage.charged_s, stats.decode_s,
                       storage.requests, storage.bytes), stats)


def load_streaming_multihost(path: str, hosts: int,
                             profile: str = "lustre_ssd",
                             block_size: int = PGFUSE_BLOCK,
                             readahead: int = 2, n_parts: int = 16,
                             n_buffers: int = 2):
    """Multi-host simulated streamed load (data/multihost.py).

    Every simulated host mounts its own PG-Fuse cache over its own
    SimStorage clock (hosts do not share a storage port in the modeled
    cluster), streams its slice of the shared plan, and reports its own
    StreamStats.  Returns (io_s, per_host, aggregate) where ``io_s`` is
    the max charged storage time over hosts (the cluster's wall-clock:
    hosts load concurrently and training starts when the slowest
    finishes) and ``per_host`` is [(StreamStats, SimStorage), ...] in
    process order.
    """
    from repro.data.multihost import aggregate_stats, simulate_hosts

    storages = [SimStorage(PROFILES[profile]) for _ in range(hosts)]
    results = simulate_hosts(
        path, hosts,
        open_kwargs=lambda i: dict(
            use_pgfuse=True, pgfuse_block_size=block_size,
            pgfuse_readahead=readahead, pgfuse_pread_fn=storages[i].pread),
        n_buffers=n_buffers, readahead=readahead, n_parts=n_parts)
    agg = aggregate_stats(results)
    io_s = max((st.charged_s for st in storages), default=0.0)
    return io_s, [(r.stats, st) for r, st in zip(results, storages)], agg


def _bench_streaming_main() -> None:
    """Emit a BENCH json line for the streaming loader vs the host path.

        PYTHONPATH=src python -m benchmarks.loading [--scale 16] [--edge-factor 24]
    """
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_stream")
    ap.add_argument("--profile", default="lustre_ssd",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--edge-factor", type=int, default=24)
    ap.add_argument("--readahead", type=int, default=2)
    ap.add_argument("--n-parts", type=int, default=16)
    ap.add_argument("--hosts", type=int, default=1,
                    help="also measure an N-host simulated streamed load")
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)

    from repro.core import paragrapher
    from repro.graph import rmat

    path = os.path.join(args.workdir,
                        f"rmat{args.scale}x{args.edge_factor}.cbin")
    if not os.path.exists(path):
        csr = rmat(args.scale, args.edge_factor, seed=0)
        paragrapher.save_graph(path, csr, format="compbin")

    host = load_compbin(path, args.profile, use_pgfuse=True,
                        decode_parallelism=1)
    res, stats = load_streaming(path, args.profile,
                                readahead=args.readahead,
                                n_parts=args.n_parts)
    out = {
        "bench": "streaming_loader",
        "profile": args.profile,
        "graph": {"scale": args.scale, "edge_factor": args.edge_factor,
                  "edges": stats.edges, "vertices": stats.vertices},
        "streaming": {"io_s": res.io_s, "decode_s": res.decode_s,
                      "total_s": res.total_s, "requests": res.requests,
                      "bytes_read": res.bytes_read, **stats.as_dict()},
        "host_pgfuse": {"io_s": host.io_s, "decode_s": host.decode_s,
                        "total_s": host.total_s, "requests": host.requests,
                        "bytes_read": host.bytes_read},
        "h2d_saving": 1.0 - stats.bytes_h2d / max(1, 4 * stats.edges),
    }
    if args.hosts > 1:
        io_s, per_host, agg = load_streaming_multihost(
            path, args.hosts, args.profile, readahead=args.readahead,
            n_parts=max(args.n_parts, args.hosts))
        out["multihost"] = {
            "hosts": args.hosts,
            "io_s": io_s,                    # slowest host's charged time
            "aggregate": agg.as_dict(),
            "per_host": [{"process_index": i, "io_s": st.charged_s,
                          **s.as_dict()}
                         for i, (s, st) in enumerate(per_host)],
        }
    print("BENCH " + json.dumps(out))


if __name__ == "__main__":
    _bench_streaming_main()
