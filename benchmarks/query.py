"""Random-access query benchmark (the serving side of CompBin §IV).

Replays a deterministic zipf-ish request trace — batched ``neighbors(v)``
lookups with a hot head, like online inference traffic — against the
:class:`repro.query.NeighborQueryEngine` twice:

* **random-access policy** (`core.policy.choose_access_mode("serve")`):
  readahead off, clock/second-chance eviction, per-file churn caps;
* **sequential policy** (the streaming loader's config: always-on
  readahead, LRU) — deliberately mismatched, to measure what the policy
  split is worth on random traffic.

* **device-decode arm**: the same random-access policy with the engine
  pinned to ``decode="device"`` — every micro-batch's merged packed
  runs ship in ONE transfer and the Pallas kernel runs eq. (1); the
  virtual clock additionally charges a deterministic decode-cost model
  (host shift+adds vs dispatch + H2D + VPU lanes), so the host/device
  p50 split is a property of the batch shapes, not of this machine.

All gated numbers come from the SimStorage *virtual* clock and the
deterministic PG-Fuse counters, so they are properties of the request
pattern, not of the benchmark machine: the engine's ``clock=`` is the
virtual clock, which advances only when a request actually reaches
storage (plus the charged decode model above) — p50/p99 "latency" is
then the charged time a request observed.  Latency percentiles are
gated in the ``tracked_lower`` section (LOWER is better;
``benchmarks/compare.py`` fails on rises), hit rate / dedup /
policy-advantage / device-decode advantage in ``tracked`` (higher is
better).
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.storage_sim import PROFILES, SimStorage

PGFUSE_BLOCK = 1 << 14     # 16 KiB: scaled down with the reduced graph so
                           # the file spans hundreds of blocks and random
                           # lookups stay SPARSE in block space (the regime
                           # the policy split targets; production uses the
                           # paper's 32 MiB blocks over TB-scale files)

# Deterministic decode-cost model charged to the virtual clock (rates in
# the ballpark of policy.SystemModel and a PCIe-class link; the ratios,
# not the absolutes, are what the gate protects): host runs eq. (1) at
# numpy shift+add rate; the device pays a fixed dispatch + one H2D of
# the packed bytes + VPU-lane decode — so small batches favor host,
# large fanouts favor the device, exactly the policy's crossover.
HOST_DECODE_EDGES_PER_S = 2.0e8
DEVICE_DISPATCH_S = 30e-6
DEVICE_H2D_BYTES_PER_S = 16.0e9
DEVICE_DECODE_EDGES_PER_S = 2.0e9


def _request_trace(n_vertices: int, n_batches: int, batch: int,
                   seed: int = 0) -> list:
    """Deterministic synthetic user traffic: half the lookups hit a small
    SCATTERED hub set (zipf-ish head — hubs are spread across the file,
    not clustered at low ids), the rest are uniform over the tail."""
    rng = np.random.default_rng(seed)
    hubs = rng.permutation(n_vertices)[:max(8, n_vertices >> 11)]
    trace = []
    for _ in range(n_batches):
        hot = hubs[rng.integers(0, len(hubs), batch)]
        cold = rng.integers(0, n_vertices, batch)
        trace.append(np.where(rng.random(batch) < 0.5, hot, cold))
    return trace


def _replay(path: str, trace, profile: str, *, readahead: int,
            eviction: str, budget: int, decode: str = "host"):
    """One engine over one policy config; returns (QueryStats, PGFuseStats,
    SimStorage) after replaying the whole trace.  ``decode`` pins the
    engine's eq. (1) placement; either way the virtual clock is charged
    by the decode-cost model above, so host and device arms are
    comparable on identical storage traffic."""
    from repro.core import paragrapher
    from repro.query import NeighborQueryEngine

    storage = SimStorage(PROFILES[profile])
    vdecode = [0.0]
    g = paragrapher.open_graph(
        path, use_pgfuse=True, pgfuse_block_size=PGFUSE_BLOCK,
        pgfuse_readahead=readahead, pgfuse_eviction=eviction,
        pgfuse_max_resident_bytes=budget, pgfuse_pread_fn=storage.pread)
    try:
        engine = NeighborQueryEngine(
            g, decode=decode,
            clock=lambda: storage.charged_s + vdecode[0])
        b = g.bytes_per_id
        orig_host, orig_dev = engine._decode_host, engine._decode_device

        def charged_host(packed):
            vdecode[0] += (sum(p.size for p in packed) // b) \
                / HOST_DECODE_EDGES_PER_S
            return orig_host(packed)

        def charged_device(packed):
            nbytes = sum(p.size for p in packed)
            vdecode[0] += (DEVICE_DISPATCH_S
                           + nbytes / DEVICE_H2D_BYTES_PER_S
                           + (nbytes // b) / DEVICE_DECODE_EDGES_PER_S)
            return orig_dev(packed)

        engine._decode_host = charged_host
        engine._decode_device = charged_device
        for ids in trace:
            engine.neighbors_batch(ids)
        return engine.stats, g.pgfuse_stats(), storage
    finally:
        g.close()


def _replay_pervertex(path: str, trace, profile: str):
    """The naive serving baseline: every lookup is an independent
    ``CompBinFile.neighbors_of`` straight off storage — one offsets read
    + one neighbors read per vertex, no cache, no dedup, no coalescing
    (the request-per-call server the paper's small-read critique, §III,
    applies to).  Returns the charged SimStorage."""
    from repro.core import compbin

    storage = SimStorage(PROFILES[profile])
    rd = compbin.CompBinFile(storage.open_reader(path))
    try:
        for ids in trace:
            for v in ids:
                rd.neighbors_of(int(v))
        return storage
    finally:
        rd.close()


def run(workdir: str = "/tmp/repro_bench_query",
        profile: str = "lustre_ssd", scale: int = 17, edge_factor: int = 16,
        n_batches: int = 16, batch: int = 128,
        out: str = "BENCH_query.json") -> dict:
    """The query suite: random-access vs sequential policy on the same
    trace, emitted as one BENCH json dict (CI gates ``tracked`` upward
    and ``tracked_lower`` downward)."""
    os.makedirs(workdir, exist_ok=True)

    from repro.core import paragrapher, policy
    from repro.graph import rmat

    path = os.path.join(workdir, f"rmat{scale}x{edge_factor}.cbin")
    if not os.path.exists(path):
        paragrapher.save_graph(path, rmat(scale, edge_factor, seed=0),
                               format="compbin")
    with paragrapher.open_graph(path) as g:
        n_vertices = g.n_vertices
        file_bytes = os.path.getsize(path)
    trace = _request_trace(n_vertices, n_batches, batch)
    # budget ~1/2 of the file: enough for the hot set (offsets + the zipf
    # head), real eviction pressure from the cold uniform tail
    budget = max(4 * PGFUSE_BLOCK, file_bytes // 2)

    amode = policy.choose_access_mode("serve")
    rand_q, rand_pg, rand_st = _replay(
        path, trace, profile, readahead=amode.readahead,
        eviction=amode.eviction, budget=budget)
    # the decode arms: LARGE-FANOUT request batches (whole sampler
    # layers / hub-heavy frontiers) over the "null" storage profile —
    # storage charges zero virtual time, so the arms' charged latency
    # IS the decode stage and nothing else: identical trace, identical
    # policy, the ONLY difference is where eq. (1) runs.  The device
    # arm ships each micro-batch's merged packed runs in ONE transfer
    # to the Pallas kernel and pays dispatch + H2D + VPU lanes; the
    # host arm pays shift+adds per edge.
    fan_trace = _request_trace(n_vertices, max(4, n_batches // 4),
                               batch * 16, seed=1)
    host_q, host_pg, host_st = _replay(
        path, fan_trace, "null", readahead=amode.readahead,
        eviction=amode.eviction, budget=budget, decode="host")
    dev_q, dev_pg, dev_st = _replay(
        path, fan_trace, "null", readahead=amode.readahead,
        eviction=amode.eviction, budget=budget, decode="device")
    seq = policy.choose_access_mode("stream")
    seq_q, seq_pg, seq_st = _replay(
        path, trace, profile, readahead=seq.readahead,
        eviction=seq.eviction, budget=budget)
    naive_st = _replay_pervertex(path, trace, profile)

    def hit_rate(pg):
        n = pg.cache_hits + pg.cache_misses
        return pg.cache_hits / n if n else 0.0

    result = {
        "bench": "query_engine",
        "profile": profile,
        "graph": {"scale": scale, "edge_factor": edge_factor,
                  "vertices": n_vertices, "file_bytes": file_bytes},
        "trace": {"n_batches": n_batches, "batch": batch,
                  "requests": rand_q.requests},
        "random_policy": {**rand_q.as_dict(), "hit_rate": hit_rate(rand_pg),
                          "io_s": rand_st.charged_s,
                          "underlying_reads": rand_pg.underlying_reads,
                          "underlying_bytes": rand_pg.underlying_bytes},
        "host_decode_arm": {**host_q.as_dict(),
                            "hit_rate": hit_rate(host_pg),
                            "io_s": host_st.charged_s},
        "device_decode_arm": {**dev_q.as_dict(),
                              "hit_rate": hit_rate(dev_pg),
                              "io_s": dev_st.charged_s},
        "sequential_policy": {**seq_q.as_dict(), "hit_rate": hit_rate(seq_pg),
                              "io_s": seq_st.charged_s,
                              "underlying_reads": seq_pg.underlying_reads,
                              "underlying_bytes": seq_pg.underlying_bytes},
        "pervertex_baseline": {"io_s": naive_st.charged_s,
                               "underlying_reads": naive_st.requests,
                               "underlying_bytes": naive_st.bytes},
    }
    result["tracked"] = {
        # cache effectiveness of the random-access policy on random traffic
        "query_hit_rate": hit_rate(rand_pg),
        # in-batch + cross-batch request sharing the engine recovers
        "query_dedup_ratio": rand_q.dedup_ratio,
        # what the engine stack (dedup + coalescing + span-fetch + block
        # cache) buys over uncached request-per-call serving on identical
        # traffic and storage — the serving analogue of paper Fig. 2
        "query_engine_advantage": naive_st.charged_s
        / max(rand_st.charged_s, 1e-12),
        # the policy split: charged storage time of the mismatched
        # sequential config over the random-access config
        "query_policy_io_advantage": seq_st.charged_s
        / max(rand_st.charged_s, 1e-12),
        # what shipping eq. (1) to the device buys on warm large-fanout
        # batches: host-arm p50 over device-arm p50 on identical traffic
        # (>= 1 when the device path pays — the acceptance criterion)
        "query_device_decode_advantage": host_q.p50_s
        / max(dev_q.p50_s, 1e-12),
    }
    result["tracked_lower"] = {
        # charged-storage latency a request observes (virtual seconds)
        "query_vclock_p50_s": rand_q.p50_s,
        "query_vclock_p99_s": rand_q.p99_s,
        "query_vclock_io_s": rand_st.charged_s,
        # the device-decode arm's charged latencies (the new serving
        # floor CI gates so the accelerator path cannot quietly regress)
        "query_device_vclock_p50_s": dev_q.p50_s,
        "query_device_vclock_p99_s": dev_q.p99_s,
    }

    print("BENCH " + json.dumps(result))
    if out and out != "-":
        with open(out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out}")
    return result


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_bench_query")
    ap.add_argument("--profile", default="lustre_ssd",
                    choices=sorted(PROFILES))
    ap.add_argument("--scale", type=int, default=17)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--n-batches", type=int, default=32)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--out", default="BENCH_query.json")
    args = ap.parse_args()
    run(workdir=args.workdir, profile=args.profile, scale=args.scale,
        edge_factor=args.edge_factor, n_batches=args.n_batches,
        batch=args.batch, out=args.out)


if __name__ == "__main__":
    _main()
