#!/usr/bin/env python
"""Quickstart: the paper in one file.

Generates an RMAT graph, saves it as WebGraph-style and CompBin, loads it
back through ParaGrapher with and without PG-Fuse, verifies the loads are
identical, and prints the loading/decode split for each path.

    PYTHONPATH=src python examples/quickstart.py [--format compbin]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import paragrapher
from repro.graph import rmat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--format", choices=["compbin", "webgraph", "both"],
                    default="both")
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--workdir", default="/tmp/repro_quickstart")
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)

    print(f"generating RMAT scale={args.scale} ...")
    csr = rmat(args.scale, 16, seed=0)
    print(f"  |V|={csr.n_vertices:,} |E|={csr.n_edges:,}")

    formats = ["compbin", "webgraph"] if args.format == "both" else [args.format]
    results = {}
    for fmt in formats:
        path = os.path.join(args.workdir, f"g.{fmt}")
        n = paragrapher.save_graph(path, csr, format=fmt)
        print(f"[{fmt}] wrote {n/2**20:.2f} MiB")

        for use_fuse in (False, True):
            t0 = time.perf_counter()
            with paragrapher.open_graph(path, use_pgfuse=use_fuse,
                                        pgfuse_block_size=1 << 22) as g:
                loaded = g.read_full()
                dt = time.perf_counter() - t0
                stats = g.pgfuse_stats()
            assert loaded == csr, "loaded graph differs!"
            tag = "PG-Fuse" if use_fuse else "direct "
            extra = (f" underlying_reads={stats.underlying_reads} "
                     f"hits={stats.cache_hits}" if stats else "")
            print(f"[{fmt}] {tag} loaded+verified in {dt*1e3:8.1f} ms{extra}")
            results[(fmt, use_fuse)] = dt

    if len(formats) == 2:
        speedup = results[("webgraph", False)] / results[("compbin", False)]
        print(f"\nCompBin vs WebGraph decode speedup on this host: "
              f"{speedup:.1f}x (paper: up to 21.8x on 128-core EPYC)")

    # async partitioned load (the ParaGrapher consumer/producer pattern)
    path = os.path.join(args.workdir, f"g.{formats[0]}")
    with paragrapher.open_graph(path, use_pgfuse=True) as g:
        got = []
        ar = g.read_async(g.partition_plan(8),
                          lambda buf: got.append(len(buf.neighbors)),
                          n_buffers=3, n_workers=4)
        ar.wait(60)
        print(f"async load: {len(got)} partitions, {sum(got):,} edges total")

    # Streaming loader: partition -> PG-Fuse -> raw packed bytes -> H2D ->
    # on-device Pallas decode -> device-resident CSR shards.  For CompBin
    # the neighbor IDs are never decoded on the host — eq. (1) runs in the
    # kernel, so the (4-b)/4 byte saving also applies to the host->device
    # link.  stream.stats carries the per-stage accounting.
    from repro.data import assemble_csr, stream_partitions
    cb_path = os.path.join(args.workdir, "g.compbin")
    if not os.path.exists(cb_path):
        paragrapher.save_graph(cb_path, csr, format="compbin")
    with paragrapher.open_graph(cb_path, use_pgfuse=True,
                                pgfuse_block_size=1 << 22,
                                pgfuse_readahead=2) as g:
        with stream_partitions(g, None, n_buffers=2, readahead=2) as stream:
            shards = list(stream)
        assert assemble_csr(shards) == csr, "streamed graph differs!"
        st = stream.stats
        print(f"streaming loader: {st.partitions} device shards "
              f"[{st.decode_mode} decode], {st.underlying_reads} storage "
              f"reads (+{st.readahead_blocks} readahead blocks), "
              f"{st.bytes_h2d/2**20:.2f} MiB H2D, "
              f"{st.host_decode_bytes} host-decoded bytes, "
              f"{st.decode_edges_per_s/1e3:.0f}k edges/s on-device decode")


if __name__ == "__main__":
    main()
