#!/usr/bin/env python
"""End-to-end GNN training from a CompBin graph on storage.

The full loop the paper accelerates: graph lives compressed on (simulated
slow) storage -> ParaGrapher + PG-Fuse load/sample it -> GCN trains on
sampled blocks.  Run:

    PYTHONPATH=src python examples/train_gnn_from_compbin.py --steps 60
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paragrapher
from repro.data import PrefetchIterator, assemble_csr, stream_partitions
from repro.graph import NeighborSampler, rmat
from repro.launch.data_gnn import block_to_batch
from repro.models.gnn import gcn
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch-nodes", type=int, default=64)
    ap.add_argument("--workdir", default="/tmp/repro_gnn_example")
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)

    path = os.path.join(args.workdir, "graph.cbin")
    if not os.path.exists(path):
        csr = rmat(12, 8, seed=1)
        paragrapher.save_graph(path, csr, format="compbin")
        print(f"wrote {os.path.getsize(path)/2**20:.1f} MiB CompBin graph")

    g = paragrapher.open_graph(path, use_pgfuse=True,
                               pgfuse_block_size=1 << 20,
                               pgfuse_readahead=2)

    # Load the graph through the streaming partition->device pipeline
    # (data/graph_stream.py): packed bytes go straight to the accelerator,
    # the Pallas kernel decodes them there, and the sampler's hot loop then
    # runs over the reassembled in-memory CSR instead of re-reading storage
    # for every minibatch.
    with stream_partitions(g, None, n_buffers=2, readahead=2) as stream:
        shards = list(stream)
    st = stream.stats
    print(f"streamed {st.partitions} partitions, {st.edges:,} edges "
          f"[{st.decode_mode} decode] in {st.wall_s:.2f}s: "
          f"{st.underlying_reads} storage reads, {st.cache_hits} cache hits, "
          f"{st.bytes_h2d/2**20:.1f} MiB H2D, "
          f"{st.host_decode_bytes} host-decoded bytes, "
          f"{st.decode_edges_per_s/1e3:.0f}k edges/s decode")
    csr_mem = assemble_csr(shards)
    pg_stats = g.pgfuse_stats()
    n_vertices = g.n_vertices
    g.close()  # graph now lives in memory; free the fd and block cache
    sampler = NeighborSampler(csr_mem, fanouts=(10, 5), seed=0)
    cfg = gcn.GCNConfig(n_layers=2, d_hidden=32, d_in=32, n_classes=8)
    params = gcn.init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    opt = adamw_init(params, opt_cfg)

    rng = np.random.default_rng(0)

    def batches():
        while True:
            seeds = rng.integers(0, n_vertices, args.batch_nodes)
            yield block_to_batch("gcn-cora", cfg, sampler.sample(seeds), rng)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn.loss_fn)(params, batch, cfg)
        params, opt, met = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    it = PrefetchIterator(batches(), depth=2)
    t0 = time.time()
    for i in range(1, args.steps + 1):
        params, opt, loss = step(params, opt, next(it))
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps/dt:.1f} steps/s, sampler overlapped via prefetch)")
    print(f"PG-Fuse (load phase): {pg_stats.underlying_reads} underlying "
          f"reads, {pg_stats.cache_hits:,} cache hits, "
          f"{pg_stats.readahead_blocks} readahead blocks")


if __name__ == "__main__":
    main()
