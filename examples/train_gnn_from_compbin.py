#!/usr/bin/env python
"""End-to-end GNN training from a CompBin graph on storage.

The full loop the paper accelerates, carried all the way into the model:
graph lives compressed on (simulated slow) storage -> PG-Fuse enlarges +
caches the reads -> packed CompBin bytes cross to the device undecoded ->
the Pallas kernel decodes them there -> GCN trains full-batch on the
device-resident edge index.  With ``--hosts N`` the load runs as N
simulated processes (data/multihost.py), each streaming its own
contiguous slice of the shared partition plan through its own PG-Fuse
cache — the single-node rehearsal of a multi-host cluster load.  Run:

    PYTHONPATH=src python examples/train_gnn_from_compbin.py --steps 60
    PYTHONPATH=src python examples/train_gnn_from_compbin.py --hosts 2
    PYTHONPATH=src python examples/train_gnn_from_compbin.py --sampled

``--sampled`` switches to the random-access regime: minibatch blocks are
drawn through the :mod:`repro.query` neighbor-query engine (deduplicated,
coalesced CompBin reads under the PG-Fuse random-access policy), with
features and seed labels gathered from the column-family stores on the
same mount.  Both regimes stream the label/mask family, so NO tensor in
the batch is synthesized on the host.
"""

import argparse
import itertools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import featstore, paragrapher, policy
from repro.data import aggregate_stats, all_shards, simulate_hosts
from repro.graph import (NeighborSampler, featstore_for_graph,
                         labelstore_for_graph, rmat,
                         synthesize_node_features,
                         synthesize_separable_labels)
from repro.launch.data_gnn import sampled_store_batch, streamed_graph_batch
from repro.models.gnn import gcn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.query import NeighborQueryEngine


def _print_host_stats(results) -> None:
    for r in results:
        st = r.stats
        print(f"  host {r.process_index}: vertices [{r.host_range[0]},"
              f"{r.host_range[1]}) {st.partitions} partitions "
              f"{st.edges:,} edges [{st.decode_mode} decode] "
              f"{st.bytes_h2d/2**10:.0f} KiB H2D, {st.cache_hits} cache "
              f"hits, {st.underlying_reads} storage reads")
    agg = aggregate_stats(results)
    print(f"streamed {agg.edges:,} edges + {agg.feature_rows:,} feature "
          f"rows total: {(agg.bytes_h2d + agg.feature_bytes_h2d)/2**20:.2f} "
          f"MiB H2D, {agg.host_decode_bytes} host-decoded bytes, "
          f"{agg.decode_edges_per_s/1e3:.0f}k edges/s decode, feature "
          f"hit rate {agg.feature_hit_rate:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--hosts", type=int, default=2,
                    help="simulated streaming processes")
    ap.add_argument("--sampled", action="store_true",
                    help="minibatch sampling instead of full-graph")
    ap.add_argument("--batch-nodes", type=int, default=64)
    ap.add_argument("--workdir", default="/tmp/repro_gnn_example")
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)

    block_size = 1 << 20
    d_in = 32
    path = os.path.join(args.workdir, "graph.cbin")
    if not os.path.exists(path):
        csr = rmat(12, 8, seed=1)
        paragrapher.save_graph(path, csr, format="compbin")
        print(f"wrote {os.path.getsize(path)/2**20:.1f} MiB CompBin graph")
    feat_path = os.path.join(args.workdir, f"graph_d{d_in}.fst")
    if not os.path.exists(feat_path):
        featstore_for_graph(path, feat_path, d_in, seed=0,
                            data_align=block_size)
        print(f"wrote {os.path.getsize(feat_path)/2**20:.1f} MiB feature "
              f"store ({d_in} float32/row)")
    label_path = os.path.join(args.workdir, "graph_labels.lbl")
    if not os.path.exists(label_path):
        with paragrapher.open_graph(path) as g:
            x = synthesize_node_features(g.n_vertices, d_in, seed=0)
        labelstore_for_graph(path, label_path, 8, seed=0,
                             labels=synthesize_separable_labels(x, 8),
                             data_align=block_size)

    cfg = gcn.GCNConfig(n_layers=2, d_hidden=32, d_in=32, n_classes=8)
    params = gcn.init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    opt = adamw_init(params, opt_cfg)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(gcn.loss_fn)(params, batch, cfg)
        params, opt, met = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    if args.sampled:
        # random-access regime: adjacency through the query engine
        # (dedup + coalesced span fetches), features + seed labels
        # gathered from the column-family stores on the SAME mount
        amode = policy.choose_access_mode("sample")
        g = paragrapher.open_graph(
            path, use_pgfuse=True, pgfuse_block_size=block_size,
            pgfuse_readahead=amode.readahead,
            pgfuse_eviction=amode.eviction)
        feats = featstore.open_featstore(feat_path, fs=g.fs,
                                         pgfuse_file_readahead=0)
        labels = featstore.open_featstore(label_path, fs=g.fs,
                                          pgfuse_file_readahead=0)
        engine = NeighborQueryEngine(g)
        sampler = NeighborSampler(engine, fanouts=(10, 5), seed=0)
        print(f"sampled regime: {amode.reason}")

        def batches():
            while True:
                seeds = rng.integers(0, g.n_vertices, args.batch_nodes)
                yield sampled_store_batch("gcn-cora", cfg,
                                          sampler.sample(seeds), feats,
                                          labels)

        it = batches()
    else:
        # full-graph regime: the streamed shards ARE the training batch —
        # neighbor IDs never exist decoded on the host, and features AND
        # labels ride the same stream; cut vertices snap to the feature
        # block grid so neighboring hosts' caches never double-fetch
        with paragrapher.open_graph(path) as g:
            align = policy.choose_feature_align(block_size, d_in * 4,
                                                g.n_vertices, args.hosts)
        results = simulate_hosts(
            path, args.hosts,
            open_kwargs=dict(use_pgfuse=True, pgfuse_block_size=block_size,
                             pgfuse_readahead=2),
            n_buffers=2, readahead=2, feature_path=feat_path,
            label_path=label_path, align=align)
        _print_host_stats(results)
        shards = all_shards(results)
        batch = streamed_graph_batch("gcn-cora", cfg, shards, rng,
                                     n_classes=cfg.n_classes,
                                     n_vertices=results[0].n_vertices)
        it = itertools.repeat(batch)

    t0 = time.time()
    for i in range(1, args.steps + 1):
        params, opt, loss = step(params, opt, next(it))
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
    dt = time.time() - t0
    mode = "sampled" if args.sampled else "full-graph"
    print(f"\n{args.steps} {mode} steps in {dt:.1f}s "
          f"({args.steps/dt:.1f} steps/s)")
    if args.sampled:
        st = engine.stats
        print(f"query engine: {st.batches} coalesced batches, dedup "
              f"{st.dedup_ratio:.2f}x, {st.blocks_touched} blocks touched, "
              f"p50 {st.p50_s*1e3:.2f} ms")
        engine.close()
        feats.close()
        labels.close()
        g.close()


if __name__ == "__main__":
    main()
