#!/usr/bin/env python
"""Train a ~100M-param LM for a few hundred steps from CompBin-packed
token shards (the paper's byte-packing applied to the LM input pipeline).

Default config is a ~103M-param llama-style model; --tiny switches to a
seconds-scale config for CI.

    PYTHONPATH=src python examples/train_lm_packed_tokens.py --steps 300
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import PrefetchIterator, TokenShardReader, write_token_shard
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.checkpoint import AsyncCheckpointer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--workdir", default="/tmp/repro_lm_example")
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)

    if args.tiny:
        cfg = tf.TransformerConfig(
            name="lm-tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
            d_head=16, d_ff=128, vocab=2048, dtype=jnp.float32,
            tie_embeddings=True)
    else:
        # ~103M params: 12L x 640d x (10H/5KV) x 2560ff, 32k vocab
        cfg = tf.TransformerConfig(
            name="lm-100m", n_layers=12, d_model=640, n_heads=10,
            n_kv_heads=5, d_head=64, d_ff=2560, vocab=32_768,
            dtype=jnp.float32, tie_embeddings=True, attn_chunk=128)
    print(f"model: {cfg.name}, {cfg.n_params()/1e6:.1f}M params")

    # synthetic corpus with learnable bigram structure (loss must drop
    # clearly below the unigram entropy)
    shard = os.path.join(args.workdir, f"corpus_{cfg.vocab}.ctok")
    if not os.path.exists(shard):
        rng = np.random.default_rng(0)
        n = 2_000_000 if not args.tiny else 100_000
        nxt = rng.integers(0, cfg.vocab, cfg.vocab)  # deterministic bigram
        toks = np.empty(n, np.int64)
        toks[0] = 1
        noise = rng.random(n) < 0.1
        rand = rng.integers(0, cfg.vocab, n)
        for i in range(1, n):
            toks[i] = rand[i] if noise[i] else nxt[toks[i - 1]]
        write_token_shard(shard, toks, cfg.vocab)
        print(f"wrote {os.path.getsize(shard)/2**20:.1f} MiB packed shard "
              f"({3}B/token vs {4}B int32: 25% smaller)")

    reader = TokenShardReader(shard, use_pgfuse=True,
                              pgfuse_block_size=1 << 20)
    raw = reader.batches(args.batch, args.seq, seed=0)
    batches = PrefetchIterator(
        ({"tokens": jnp.asarray(b[:, :-1]), "labels": jnp.asarray(b[:, 1:])}
         for b in raw), depth=2)

    params = tf.init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    opt = adamw_init(params, opt_cfg)
    ckpt = AsyncCheckpointer(os.path.join(args.workdir, "ckpt"), keep_last=2)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(tf.loss_fn)(
            params, batch["tokens"], batch["labels"], cfg)
        params, opt, met = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(1, args.steps + 1):
        params, opt, loss = step(params, opt, next(batches))
        losses.append(float(loss))
        if i % 25 == 0:
            tok_s = args.batch * args.seq * i / (time.time() - t0)
            print(f"step {i:4d} loss {losses[-1]:.4f} ({tok_s:,.0f} tok/s)")
        if i % 100 == 0:
            ckpt.save(i, {"params": params, "opt": opt})
    ckpt.wait()
    print(f"\nloss: {np.mean(losses[:20]):.3f} -> {np.mean(losses[-20:]):.3f} "
          f"(bigram structure learned: must be well below "
          f"ln(vocab)={np.log(cfg.vocab):.2f})")
    st = reader.pgfuse_stats()
    print(f"PG-Fuse: {st.underlying_reads} underlying reads / "
          f"{st.cache_hits:,} hits")
    reader.close()


if __name__ == "__main__":
    main()
