#!/usr/bin/env python
"""Serve a DIN CTR model with batched requests, CompBin-packed ID streams.

Request history/candidate IDs arrive CompBin-packed (3 bytes per ID for a
10M-item catalog — the paper's byte-packing applied to the recsys request
path), are decoded with eq. (1), embedded via the take+segment EmbeddingBag,
and scored with target attention.

    PYTHONPATH=src python examples/serve_din_requests.py --requests 20
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compbin
from repro.models.recsys import din


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--items", type=int, default=100_000)
    args = ap.parse_args()

    cfg = din.DINConfig(name="din-serve", embed_dim=18, seq_len=100,
                        n_items=args.items, n_cates=1000,
                        attn_mlp=(80, 40), mlp=(200, 80))
    params = din.init_params(cfg, jax.random.key(0))
    b = compbin.bytes_per_vertex(cfg.n_items)
    print(f"DIN catalog {cfg.n_items:,} items -> {b} bytes/ID on the wire "
          f"({(4-b)/4:.0%} smaller than int32)")

    fwd = jax.jit(lambda p, batch: din.forward(p, batch, cfg))
    rng = np.random.default_rng(0)
    lat = []
    wire_bytes = 0
    for _ in range(args.requests):
        # requests arrive packed (as they would over the network / from
        # the feature store through PG-Fuse)
        hist = rng.integers(0, cfg.n_items, (args.batch, cfg.seq_len))
        cand = rng.integers(0, cfg.n_items, args.batch)
        packed_hist = compbin.encode_ids(hist.reshape(-1).astype(np.uint64), b)
        packed_cand = compbin.encode_ids(cand.astype(np.uint64), b)
        wire_bytes += packed_hist.nbytes + packed_cand.nbytes

        t0 = time.perf_counter()
        hist_ids = compbin.decode_ids(packed_hist, b).astype(np.int32)
        cand_ids = compbin.decode_ids(packed_cand, b).astype(np.int32)
        batch = {
            "hist_items": jnp.asarray(hist_ids.reshape(args.batch, cfg.seq_len)),
            "hist_cates": jnp.asarray(hist_ids.reshape(args.batch, cfg.seq_len) % cfg.n_cates),
            "cand_item": jnp.asarray(cand_ids),
            "cand_cate": jnp.asarray(cand_ids % cfg.n_cates),
        }
        scores = fwd(params, batch)
        scores.block_until_ready()
        lat.append(time.perf_counter() - t0)

    lat_ms = np.asarray(lat[2:]) * 1e3
    print(f"batch={args.batch}: p50 {np.percentile(lat_ms, 50):.2f} ms, "
          f"p99 {np.percentile(lat_ms, 99):.2f} ms "
          f"({args.batch/np.percentile(lat_ms,50)*1e3:,.0f} req/s/replica)")
    print(f"wire traffic: {wire_bytes/2**20:.2f} MiB packed "
          f"(int32 would be {wire_bytes/b*4/2**20:.2f} MiB)")


if __name__ == "__main__":
    main()
