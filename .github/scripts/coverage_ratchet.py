"""Coverage ratchet: fail CI when tier-1 line coverage drops below the
committed floor.

    python .github/scripts/coverage_ratchet.py coverage.xml .github/coverage_floor

The floor file holds one fraction in [0, 1] (lines starting with '#' are
comments).  The gate fails when the fresh ``coverage.xml`` line rate is
more than ``--tolerance`` (default 0.01, i.e. one percentage point)
BELOW the floor — so refactors can wiggle, but a PR cannot quietly land
untested code.  Rises never fail; when the measured rate exceeds the
floor by more than the tolerance the script prints the value to commit,
and a PR that raises coverage should ratchet the floor up to it.
"""

from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET


def read_floor(path: str) -> float:
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                floor = float(line)
                if not 0.0 <= floor <= 1.0:
                    raise SystemExit(f"floor {floor} outside [0, 1]")
                return floor
    raise SystemExit(f"{path}: no floor value found")


def read_line_rate(path: str) -> float:
    root = ET.parse(path).getroot()
    rate = root.get("line-rate")
    if rate is None:
        raise SystemExit(f"{path}: no line-rate attribute on <coverage>")
    return float(rate)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("coverage_xml")
    ap.add_argument("floor_file")
    ap.add_argument("--tolerance", type=float, default=0.01,
                    help="allowed drop below the floor (fraction of lines)")
    args = ap.parse_args()

    rate = read_line_rate(args.coverage_xml)
    floor = read_floor(args.floor_file)
    print(f"coverage line rate {rate:.4f} vs committed floor {floor:.4f} "
          f"(tolerance {args.tolerance:.2%})")
    if rate < floor - args.tolerance:
        print(f"FAIL: coverage dropped {floor - rate:.2%} below the floor; "
              f"add tests or (deliberately) lower {args.floor_file}")
        return 1
    if rate > floor + args.tolerance:
        print(f"note: coverage is {rate - floor:.2%} above the floor — "
              f"ratchet it up: echo {rate:.4f} > {args.floor_file}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
