"""Metrics-namespace drift gate: every ``*Stats.as_dict()`` key must be
declared in ``repro.obs.metrics.NAMESPACE`` and vice versa.

Thin CI wrapper over :func:`repro.obs.metrics.metrics_drift` (the logic
lives in the package so ``tests/test_docs_sync.py`` asserts the same
thing).  Importing the stats classes needs numpy but not jax, so this
runs in the fast docs lane.

Exit code 0 = in sync; 1 = drift (one line per violation).
"""

from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    from repro.obs.metrics import NAMESPACE, metrics_drift

    problems = metrics_drift()
    for p in problems:
        print(f"METRICS DRIFT: {p}")
    if problems:
        print(f"\n{len(problems)} violation(s). Fix by updating "
              f"repro.obs.metrics.NAMESPACE (and the table in "
              f"docs/observability.md) to match the as_dict() surface, "
              f"or the surface to match the namespace.")
        return 1
    n = sum(len(v) for v in NAMESPACE.values())
    print(f"metrics namespace in sync: {n} keys across "
          f"{len(NAMESPACE)} prefixes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
