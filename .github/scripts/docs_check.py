"""Docs drift checker: every concrete reference in docs/ must resolve.

Scans ``docs/*.md`` and ``README.md`` for the *checkable* reference
kinds and verifies each against the tree, so a rename/removal in src/
fails CI instead of silently rotting the docs:

* **file paths** — backticked or code-fenced tokens like
  ``src/repro/query/hotset.py`` or ``docs/architecture.md`` (rooted at
  ``src/ docs/ tests/ benchmarks/ examples/ .github/``) must exist;
* **anchored symbols** — ``tests/test_hotset.py::test_x`` or
  ``benchmarks/storage_sim.py::SimStorage``: the file must exist AND
  contain the name after ``::``;
* **dotted symbols** — ``repro.query.loadgen.LoadGenerator`` or
  ``core.policy.choose_hotset_admission``: the dotted prefix must map
  to a module under ``src/repro`` (or ``benchmarks``/``tests``), and
  every trailing attribute must appear in that module's source;
* **CLI flags** — ``--hotset-bytes`` mentioned in docs must be the
  literal string ``"--hotset-bytes"`` somewhere in the repo's .py
  files (i.e. an argparse flag that still exists).

Deliberately NOT checked: bare prose words and un-dotted class names —
too many false positives. Precision over recall: everything this
script flags is a real dangling reference.

Exit code 0 = clean; 1 = drift (one line per dangling reference).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]

# First segments of dotted references we know how to root. "repro"
# resolves under src/; the bare subpackage spellings ("core.policy...")
# are how the docs refer to modules from inside the package.
_PKG_ROOTS = {
    "repro": ROOT / "src" / "repro",
    "core": ROOT / "src" / "repro" / "core",
    "query": ROOT / "src" / "repro" / "query",
    "data": ROOT / "src" / "repro" / "data",
    "graph": ROOT / "src" / "repro" / "graph",
    "launch": ROOT / "src" / "repro" / "launch",
    "distributed": ROOT / "src" / "repro" / "distributed",
    "benchmarks": ROOT / "benchmarks",
    "tests": ROOT / "tests",
}

_PATH_RE = re.compile(
    r"\b(?:src|docs|tests|benchmarks|examples|\.github)/[\w./-]+"
)
_ANCHOR_RE = re.compile(r"([\w./-]+\.py)::(\w+)")
_DOTTED_RE = re.compile(r"\b([A-Za-z_]\w*(?:\.[A-Za-z_]\w*){1,})\b")
_FLAG_RE = re.compile(r"--[a-z][a-z0-9-]+")


def _code_spans(text: str) -> list[str]:
    """All inline-code spans plus fenced code blocks."""
    spans = re.findall(r"`([^`\n]+)`", text)
    spans += re.findall(r"```[a-z]*\n(.*?)```", text, flags=re.S)
    return spans


def _defined_flags() -> set[str]:
    flags: set[str] = set()
    for base in (ROOT / "src", ROOT / "benchmarks"):
        for py in base.rglob("*.py"):
            flags.update(
                m.group(0).strip("\"'")
                for m in re.finditer(r"[\"']--[a-z][a-z0-9-]+[\"']", py.read_text())
            )
    return flags


def _resolve_dotted(token: str) -> str | None:
    """Return an error string if a rooted dotted token does not resolve."""
    parts = token.split(".")
    if parts[0] not in _PKG_ROOTS:
        return None  # not ours to check
    cur = _PKG_ROOTS[parts[0]]
    i = 1
    # walk packages/modules as far as the path goes
    while i < len(parts):
        if (cur / parts[i]).is_dir():
            cur = cur / parts[i]
            i += 1
        elif (cur / (parts[i] + ".py")).is_file():
            cur = cur / (parts[i] + ".py")
            i += 1
            break
        else:
            break
    if cur.is_dir():
        init = cur / "__init__.py"
        if not init.is_file():
            return f"{token}: no module/package at {cur.relative_to(ROOT)}"
        cur = init
    source = cur.read_text()
    for attr in parts[i:]:
        if not re.search(rf"\b{re.escape(attr)}\b", source):
            return (
                f"{token}: `{attr}` not found in "
                f"{cur.relative_to(ROOT)}"
            )
    return None


def check_file(md: Path) -> list[str]:
    text = md.read_text()
    errors: list[str] = []
    seen: set[str] = set()

    def err(msg: str) -> None:
        if msg not in seen:
            seen.add(msg)
            errors.append(f"{md.relative_to(ROOT)}: {msg}")

    spans = _code_spans(text)
    flags_defined = _defined_flags()

    for span in spans:
        for m in _ANCHOR_RE.finditer(span):
            path, name = m.group(1), m.group(2)
            # docs may spell paths repo-rooted or package-relative
            f = ROOT / path
            if not f.is_file():
                f = ROOT / "src" / "repro" / path
            if not f.is_file():
                err(f"{path}::{name}: file missing")
            elif not re.search(rf"\b{re.escape(name)}\b", f.read_text()):
                err(f"{path}::{name}: `{name}` not in file")
        for m in _PATH_RE.finditer(span):
            token = m.group(0).rstrip("/.")
            if not (ROOT / token).exists():
                err(f"path does not exist: {token}")
        for m in _DOTTED_RE.finditer(span):
            token = m.group(0)
            # skip the filename-ish tokens already handled above
            if "/" in span[max(0, m.start() - 1) : m.start() + 1]:
                continue
            if token.endswith(".py") or token.endswith(".md") or token.endswith(".json"):
                continue
            bad = _resolve_dotted(token)
            if bad:
                err(bad)
        for m in _FLAG_RE.finditer(span):
            if m.group(0) not in flags_defined:
                err(f"flag not defined anywhere in src/ or benchmarks/: {m.group(0)}")
    return errors


def main() -> int:
    docs = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    all_errors: list[str] = []
    for md in docs:
        all_errors.extend(check_file(md))
    for e in all_errors:
        print(e)
    if all_errors:
        print(f"\n{len(all_errors)} dangling doc reference(s)")
        return 1
    print(f"docs_check: {len(docs)} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
