"""Serving driver: batched LM decode, DIN CTR scoring, online GNN
inference, or multi-hop graph traversals over the random-access graph
query engine (CPU-scale).

    python -m repro.launch.serve --arch smollm-360m --reduced --tokens 32
    python -m repro.launch.serve --arch din --reduced --requests 4
    python -m repro.launch.serve --arch gcn-cora --reduced --requests 8
    python -m repro.launch.serve --arch gcn-cora --reduced --traversal \\
        --requests 32
"""

from __future__ import annotations

import argparse
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch

log = logging.getLogger("repro.serve")


def serve_lm(cfg, *, batch: int, prompt_len: int, n_tokens: int) -> None:
    from repro.models import transformer as tf
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)))
    max_len = prompt_len + n_tokens

    prefill = jax.jit(lambda p, t: tf.prefill(p, t, cfg, max_len=max_len))
    decode = jax.jit(lambda p, t, c: tf.decode_step(p, t, c, cfg),
                     donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    outs = [toks]
    t0 = time.perf_counter()
    for _ in range(n_tokens - 1):
        logits, cache = decode(params, toks, cache)
        toks = jnp.argmax(logits, -1)[:, None]
        outs.append(toks)
    jax.block_until_ready(outs[-1])
    t_decode = time.perf_counter() - t0
    total = batch * (n_tokens - 1)
    log.info("prefill %.1f ms (%d x %d); decode %.2f ms/token/batch "
             "(%.0f tok/s)", t_prefill * 1e3, batch, prompt_len,
             t_decode / max(1, n_tokens - 1) * 1e3,
             total / max(t_decode, 1e-9))


def serve_din(cfg, *, batch: int, n_requests: int) -> None:
    from repro.models.recsys import din as m_din
    params = m_din.init_params(cfg, jax.random.key(0))
    fwd = jax.jit(lambda p, b: m_din.forward(p, b, cfg))
    rng = np.random.default_rng(0)
    lat = []
    for _ in range(n_requests):
        b = {
            "hist_items": jnp.asarray(rng.integers(-1, cfg.n_items, (batch, cfg.seq_len))),
            "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (batch, cfg.seq_len))),
            "cand_item": jnp.asarray(rng.integers(0, cfg.n_items, batch)),
            "cand_cate": jnp.asarray(rng.integers(0, cfg.n_cates, batch)),
        }
        t0 = time.perf_counter()
        fwd(params, b).block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_ms = np.array(lat[1:]) * 1e3  # drop compile
    log.info("DIN batch=%d: p50 %.2f ms p99 %.2f ms (%d reqs)",
             batch, np.percentile(lat_ms, 50), np.percentile(lat_ms, 99),
             len(lat_ms))


def collect_service_metrics(service) -> "MetricsRegistry":
    """Fold EVERY stats surface the serving stack exposes into one
    :class:`repro.obs.metrics.MetricsRegistry` — the ``--metrics-json``
    snapshot and the Prometheus text both render from this.

    Works for either backend shape behind a
    :class:`repro.query.TraversalService`: a single
    :class:`repro.query.NeighborQueryEngine` (its ``query.*`` stats plus
    its mount's ``pgfuse.*``), or a
    :class:`repro.query.ShardedQueryService` (fleet-folded ``query.*``
    already, plus ``router.*`` and every replica mount's ``pgfuse.*``
    folded by re-registration — the registry's fold matches
    ``PGFuseStats.merge``, so per-shard sums equal these totals).
    """
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    d = service.as_dict()
    reg.register_stats("traversal", d["traversal"])
    reg.register_stats("query", d["query"])
    if "hotset" in d:
        reg.register_stats("hotset", d["hotset"])
    backend = service.engine
    if hasattr(backend, "router"):          # ShardedQueryService
        reg.register_stats("router", backend.router.as_dict())
        for row in backend.replicas:
            for rep in row:
                pg = rep.graph.pgfuse_stats()
                if pg is not None:
                    reg.register_stats("pgfuse", pg.as_dict())
    else:
        pg = backend.graph.pgfuse_stats()
        if pg is not None:
            reg.register_stats("pgfuse", pg.as_dict())
    return reg


def _emit_metrics(reg, tracer, metrics_json) -> None:
    """Shared exposition tail: log Prometheus text + the per-tier
    bottleneck report for any sampled traces, then persist the JSON
    snapshot when ``--metrics-json`` asked for one."""
    from repro.obs.report import render_report

    if tracer is not None:
        traces = tracer.drain()
        reg.set("obs.sampled_traces", len(traces))
        reg.set("obs.dropped_traces", tracer.dropped_traces)
        if traces:
            log.info("trace report (%d sampled requests):\n%s",
                     len(traces), render_report(traces))
    log.info("metrics snapshot:\n%s", reg.to_prometheus())
    if metrics_json:
        reg.write_json(metrics_json)
        log.info("wrote metrics snapshot to %s", metrics_json)


def make_gnn_server(arch_id: str, cfg, workdir: str, *,
                    fanouts=(5, 5), use_pgfuse: bool = True,
                    seed: int = 0, decode: str = "auto",
                    fs=None, engine_name: str = None,
                    engine_budget: int = None,
                    hotset_bytes: int = None,
                    tracer=None):
    """Build the end-to-end GNN inference server over CompBin storage.

    Returns ``(answer, engine, close)``: ``answer(vertex_ids)`` runs one
    request batch — k-hop fanout sample through the
    :class:`repro.query.NeighborQueryEngine` (deduplicated, coalesced
    random access; ``decode`` places eq. (1) per micro-batch —
    "auto" routes large fanouts to the Pallas device kernel, one H2D of
    merged packed runs per batch), feature gather from the column-family
    store on the SAME PG-Fuse mount, GCN forward — and returns the
    seeds' logits as a numpy array; the whole batch crosses to the
    device as ONE transfer (``data_gnn.device_batch``).  The mount runs
    the random-access policy
    (:func:`repro.core.policy.choose_access_mode`): readahead off, clock
    eviction, feature churn capped so the hot offset blocks stay
    resident.  The sampler is seeded, so a given request stream is
    reproducible — tests replay it against an in-memory CSR and demand
    byte-identical answers.

    Multi-tenant: pass ``fs=`` (a shared
    :class:`repro.core.pgfuse.PGFuseFS` mount) plus ``engine_name`` /
    ``engine_budget`` and this server's files join ONE
    :class:`~repro.core.pgfuse.EngineShare` — several models then serve
    from one budget without evicting each other's warm sets.

    ``hotset_bytes`` adds the HBM-resident hot-set tier
    (:class:`repro.query.HotSetCache`, sized by
    :func:`repro.core.policy.choose_hotset_admission`): hub
    neighborhoods are answered from resident decoded runs and skip the
    packed-byte path entirely, byte-identically (docs/architecture.md).
    """
    import jax

    from repro.core import featstore, paragrapher, policy
    from repro.graph import NeighborSampler
    from repro.launch.data_gnn import ensure_gnn_assets, sampled_store_batch
    from repro.launch.steps import _GNN_MODULES
    from repro.query import NeighborQueryEngine

    d_in = getattr(cfg, "d_in", getattr(cfg, "d_node_in", 16))
    n_classes = getattr(cfg, "n_classes", 7)
    block_size = 1 << 16
    gp, fp, _ = ensure_gnn_assets(workdir, d_in, n_classes,
                                  block_size=block_size)
    amode = policy.choose_access_mode("serve")
    budget = engine_budget if engine_budget is not None else 256 * block_size
    share = None
    if fs is not None:
        # default share name is keyed by the asset dir, NOT just the
        # arch: two same-arch tenants on one mount must land in two
        # distinct shares (register_engine by an existing name returns —
        # and resizes — that share)
        share = fs.register_engine(
            engine_name or f"{arch_id}:{os.path.abspath(workdir)}", budget)
        g = paragrapher.open_graph(
            gp, pgfuse_fs=fs, pgfuse_readahead=amode.readahead,
            pgfuse_engine=share)
    else:
        g = paragrapher.open_graph(
            gp, use_pgfuse=use_pgfuse, pgfuse_block_size=block_size,
            pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
            pgfuse_max_resident_bytes=budget if use_pgfuse else None)
    churn_cap = (int(amode.churn_budget_fraction * budget)
                 if amode.churn_budget_fraction else None)
    feats = featstore.open_featstore(fp, fs=g.fs,
                                     pgfuse_file_budget=churn_cap,
                                     pgfuse_file_readahead=0,
                                     pgfuse_engine=share)
    engine = NeighborQueryEngine(g, decode=decode, hotset=hotset_bytes,
                                 tracer=tracer)
    sampler = NeighborSampler(engine, fanouts=fanouts, seed=seed)
    mod = _GNN_MODULES[arch_id]
    params = mod.init_params(cfg, jax.random.key(0))
    fwd = jax.jit(lambda p, b: mod.forward(p, b, cfg))

    def answer(vertex_ids) -> np.ndarray:
        """One inference request batch: logits for ``vertex_ids``."""
        block = sampler.sample(np.asarray(vertex_ids, dtype=np.int64))
        batch = sampled_store_batch(arch_id, cfg, block, feats)
        logits = fwd(params, batch)
        return np.asarray(logits[:len(block.seeds)])

    def close() -> None:
        # both handles hold refcounted retains on the (possibly shared)
        # mount: each close releases its own file, and a file other
        # tenants still retain stays warm for them
        engine.close()
        feats.close()
        g.close()

    return answer, engine, close


def make_traversal_server(workdir: str, *, decode: str = "auto",
                          slo_s: float = 0.5,
                          edge_budget: int = 1 << 16,
                          service_edges_per_s: float = 5.0e6,
                          servers: int = 2, seed: int = 1,
                          shards: int = 1, replication: int = 1,
                          hotset_bytes: int = None,
                          tracer=None):
    """The traversal request type next to GNN inference: a
    :class:`repro.query.TraversalService` over the SAME CompBin bytes
    (and the same random-access PG-Fuse policy) the inference server
    reads.  Returns ``(service, close)``; answer requests with
    ``service.khop(...)`` / ``service.bfs_visit(...)`` /
    ``service.shortest_path(...)`` or ``service.submit(request)``.

    The admission gate is sized by
    :func:`repro.core.policy.choose_admission` from the latency SLO
    and the per-request edge budget — overload sheds immediately
    (:class:`repro.query.TraversalShed`) instead of queueing into SLO
    violations.

    ``shards > 1`` (or ``replication > 1``) scales out: the frontier
    backend becomes a :class:`repro.query.ShardedQueryService` with
    ``shards`` vertex-range shards × ``replication`` replicas, each a
    simulated process with its own PG-Fuse mount, and the admission
    gate is re-sized for the scaled aggregate service rate
    (``service_edges_per_s * shards`` across ``servers * shards``
    executors).  Traversal answers stay byte-identical to ``shards=1``
    (see docs/sharded_serving.md).

    ``hotset_bytes`` gives each engine (the single backend, or every
    shard replica) an HBM-resident hot-set tier of that byte budget —
    frontier hub vertices then skip the storage gather
    (docs/architecture.md).
    """
    from repro.core import paragrapher, policy
    from repro.launch.data_gnn import ensure_gnn_assets
    from repro.query import (NeighborQueryEngine, ShardedQueryService,
                             TraversalService)

    block_size = 1 << 16
    gp, _, _ = ensure_gnn_assets(workdir, 16, 7, block_size=block_size,
                                 seed=seed)
    amode = policy.choose_access_mode("serve")
    if shards > 1 or replication > 1:
        # each shard replica mounts its own cache slice of the same
        # budget one mount would have had (the locality the split buys)
        backend = ShardedQueryService(
            gp, n_shards=shards, replication=replication, decode=decode,
            hotset_bytes=hotset_bytes, tracer=tracer,
            open_kwargs=dict(
                pgfuse_block_size=block_size,
                pgfuse_max_resident_bytes=max(
                    block_size, 256 * block_size // max(1, shards))))
        engine = None
        plan = policy.choose_admission(
            slo_s, edge_budget=edge_budget,
            service_edges_per_s=service_edges_per_s * shards,
            servers=servers * shards)
    else:
        g = paragrapher.open_graph(
            gp, use_pgfuse=True, pgfuse_block_size=block_size,
            pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
            pgfuse_max_resident_bytes=256 * block_size)
        engine = NeighborQueryEngine(g, decode=decode, hotset=hotset_bytes,
                                     tracer=tracer)
        backend = engine
        plan = policy.choose_admission(
            slo_s, edge_budget=edge_budget,
            service_edges_per_s=service_edges_per_s, servers=servers)
    service = TraversalService(backend, admission=plan,
                               default_max_edges=edge_budget,
                               tracer=tracer)

    def close() -> None:
        service.close()
        if engine is not None:
            engine.close()
            g.close()
        else:
            backend.close()

    return service, close


def serve_traversal(*, n_requests: int, batch: int, workdir: str,
                    shards: int = 1, replication: int = 1,
                    hotset_bytes: int = None,
                    metrics_json: str = None,
                    trace_sample: int = 0) -> None:
    """Synthetic zipf traversal traffic against
    :func:`make_traversal_server`: k-hop neighborhoods, bounded BFS
    visits and shortest paths over hub-biased seeds.

    ``trace_sample=N`` turns on span tracing for every Nth request
    (:class:`repro.obs.Tracer`); the per-tier attribution report is
    logged on exit.  ``metrics_json`` persists the folded
    :func:`collect_service_metrics` snapshot there on exit."""
    from repro.obs import Tracer
    from repro.query import TraversalShed

    tracer = Tracer(sample_every=trace_sample) if trace_sample else None
    service, close = make_traversal_server(workdir, shards=shards,
                                           replication=replication,
                                           hotset_bytes=hotset_bytes,
                                           tracer=tracer)
    try:
        n = service.n_vertices
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        shed = 0
        for i in range(n_requests):
            hot = rng.integers(0, max(1, n // 16), batch)
            cold = rng.integers(0, n, batch)
            seeds = np.where(rng.random(batch) < 0.5, hot, cold)
            try:
                if i % 3 == 0:
                    service.khop(seeds, k=2)
                elif i % 3 == 1:
                    service.bfs_visit(seeds[:1], max_vertices=4 * batch)
                else:
                    service.shortest_path(int(seeds[0]), int(seeds[1]))
            except TraversalShed:
                shed += 1
        wall = time.perf_counter() - t0
        st = service.stats
        qs = service.engine.stats
        log.info("traversal serve: %d reqs in %.2fs (%.0f req/s); "
                 "p50 %.3f ms p99 %.3f ms, shed %d (%.1f%%); "
                 "%d frontier batches, %d edges scanned, "
                 "engine dedup %.2fx, %d/%d device batches",
                 st.completed, wall, st.completed / max(wall, 1e-9),
                 st.p50_s * 1e3, st.p99_s * 1e3, shed,
                 100 * st.shed_rate, st.frontier_batches,
                 st.edges_scanned, qs.dedup_ratio, qs.device_batches,
                 qs.batches)
        hs = service.as_dict().get("hotset")
        if hs:
            log.info("hot set: hit rate %.2f (%d/%d lookups), "
                     "%d resident entries (%.1f KiB), %d pinned",
                     hs["hit_rate"], hs["hits"], hs["lookups"],
                     hs["resident_entries"], hs["resident_bytes"] / 1024,
                     hs["pinned"])
        if metrics_json or tracer is not None:
            _emit_metrics(collect_service_metrics(service), tracer,
                          metrics_json)
    finally:
        close()


def serve_gnn(arch_id: str, cfg, *, batch: int, n_requests: int,
              workdir: str, hotset_bytes: int = None,
              metrics_json: str = None, trace_sample: int = 0) -> None:
    """Synthetic user-inference traffic against :func:`make_gnn_server`.

    Requests draw vertices zipf-style (a hot head, like real user
    traffic), so consecutive batches share neighborhoods — the dedup
    ratio and cache hit rate below are the quantities the engine exists
    to maximize.
    """
    from repro.obs import Tracer

    tracer = Tracer(sample_every=trace_sample) if trace_sample else None
    answer, engine, close = make_gnn_server(arch_id, cfg, workdir,
                                            hotset_bytes=hotset_bytes,
                                            tracer=tracer)
    try:
        n = engine.n_vertices
        rng = np.random.default_rng(0)
        lat = []
        for _ in range(n_requests):
            # zipf-ish: half the traffic hits the top ~1/16 of vertices
            hot = rng.integers(0, max(1, n // 16), batch)
            cold = rng.integers(0, n, batch)
            seeds = np.where(rng.random(batch) < 0.5, hot, cold)
            t0 = time.perf_counter()
            logits = answer(seeds)
            lat.append(time.perf_counter() - t0)
            assert logits.shape[0] == batch
        lat_ms = np.array(lat[1:] or lat) * 1e3  # drop compile
        st = engine.stats
        pg = engine.graph.pgfuse_stats()
        hit = (pg.cache_hits / max(1, pg.cache_hits + pg.cache_misses)
               if pg else 0.0)
        log.info("GNN serve batch=%d: p50 %.2f ms p99 %.2f ms (%d reqs); "
                 "query dedup %.2fx, %d blocks touched, %d coalesced "
                 "reads, cache hit rate %.2f; %d/%d batches device-"
                 "decoded (%.1f KiB H2D), window closes %s",
                 batch, np.percentile(lat_ms, 50), np.percentile(lat_ms, 99),
                 len(lat_ms), st.dedup_ratio, st.blocks_touched,
                 st.coalesced_reads, hit, st.device_batches, st.batches,
                 st.bytes_h2d / 1024, st.close_reasons)
        if engine.hotset is not None:
            hs = engine.hotset.stats
            log.info("hot set: hit rate %.2f (%d/%d lookups), "
                     "%d resident entries (%.1f KiB), %d pinned",
                     hs.hit_rate, hs.hits, hs.lookups,
                     hs.resident_entries, hs.resident_bytes / 1024,
                     hs.pinned)
        if metrics_json or tracer is not None:
            from repro.obs.metrics import MetricsRegistry
            reg = MetricsRegistry()
            reg.register_stats("query", st.as_dict())
            if pg is not None:
                reg.register_stats("pgfuse", pg.as_dict())
            if engine.hotset is not None:
                reg.register_stats("hotset", engine.hotset.stats.as_dict())
            _emit_metrics(reg, tracer, metrics_json)
    finally:
        close()


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--workdir", default="/tmp/repro_serve")
    ap.add_argument("--traversal", action="store_true",
                    help="serve multi-hop traversal requests (k-hop / "
                         "BFS visit / shortest path) over the graph "
                         "assets instead of model inference")
    ap.add_argument("--shards", type=int, default=1,
                    help="vertex-range shards for --traversal serving "
                         "(each a simulated process with its own "
                         "PG-Fuse mount; answers stay byte-identical)")
    ap.add_argument("--replication", type=int, default=1,
                    help="replicas per shard for --traversal serving "
                         "(round-robin load balancing + failover)")
    ap.add_argument("--hotset-bytes", type=int, default=None,
                    help="byte budget for the HBM-resident hot-set tier "
                         "of decoded hub runs (gnn/traversal serving; "
                         "default: no hot set). Admission is degree-"
                         "aware — see policy.choose_hotset_admission")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a JSON metrics snapshot on exit (every "
                         "stats surface folded across all shards into "
                         "the repro.obs.metrics namespace; gnn/"
                         "traversal serving)")
    ap.add_argument("--trace-sample", type=int, default=0, metavar="N",
                    help="span-trace every Nth request through the full "
                         "stack (route/gather/storage/decode/H2D) and "
                         "log the per-tier attribution report on exit "
                         "(0: tracing off, the no-op tracer)")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.make_reduced() if args.reduced else spec.make_config()
    if args.traversal:
        if spec.family != "gnn":
            raise SystemExit("--traversal serves graph requests; pick a "
                             "gnn arch for its graph assets")
        serve_traversal(n_requests=args.requests, batch=args.batch,
                        workdir=args.workdir, shards=args.shards,
                        replication=args.replication,
                        hotset_bytes=args.hotset_bytes,
                        metrics_json=args.metrics_json,
                        trace_sample=args.trace_sample)
        return
    if spec.family == "lm":
        serve_lm(cfg, batch=args.batch, prompt_len=args.prompt_len,
                 n_tokens=args.tokens)
    elif spec.family == "recsys":
        serve_din(cfg, batch=args.batch, n_requests=args.requests)
    elif spec.family == "gnn":
        serve_gnn(args.arch, cfg, batch=args.batch,
                  n_requests=args.requests, workdir=args.workdir,
                  hotset_bytes=args.hotset_bytes,
                  metrics_json=args.metrics_json,
                  trace_sample=args.trace_sample)
    else:
        raise SystemExit(f"unknown family {spec.family!r}")


if __name__ == "__main__":
    main()
