"""Serving driver: batched LM decode or DIN CTR scoring (CPU-scale).

    python -m repro.launch.serve --arch smollm-360m --reduced --tokens 32
    python -m repro.launch.serve --arch din --reduced --requests 4
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch

log = logging.getLogger("repro.serve")


def serve_lm(cfg, *, batch: int, prompt_len: int, n_tokens: int) -> None:
    from repro.models import transformer as tf
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)))
    max_len = prompt_len + n_tokens

    prefill = jax.jit(lambda p, t: tf.prefill(p, t, cfg, max_len=max_len))
    decode = jax.jit(lambda p, t, c: tf.decode_step(p, t, c, cfg),
                     donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    outs = [toks]
    t0 = time.perf_counter()
    for _ in range(n_tokens - 1):
        logits, cache = decode(params, toks, cache)
        toks = jnp.argmax(logits, -1)[:, None]
        outs.append(toks)
    jax.block_until_ready(outs[-1])
    t_decode = time.perf_counter() - t0
    total = batch * (n_tokens - 1)
    log.info("prefill %.1f ms (%d x %d); decode %.2f ms/token/batch "
             "(%.0f tok/s)", t_prefill * 1e3, batch, prompt_len,
             t_decode / max(1, n_tokens - 1) * 1e3,
             total / max(t_decode, 1e-9))


def serve_din(cfg, *, batch: int, n_requests: int) -> None:
    from repro.models.recsys import din as m_din
    params = m_din.init_params(cfg, jax.random.key(0))
    fwd = jax.jit(lambda p, b: m_din.forward(p, b, cfg))
    rng = np.random.default_rng(0)
    lat = []
    for _ in range(n_requests):
        b = {
            "hist_items": jnp.asarray(rng.integers(-1, cfg.n_items, (batch, cfg.seq_len))),
            "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (batch, cfg.seq_len))),
            "cand_item": jnp.asarray(rng.integers(0, cfg.n_items, batch)),
            "cand_cate": jnp.asarray(rng.integers(0, cfg.n_cates, batch)),
        }
        t0 = time.perf_counter()
        fwd(params, b).block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_ms = np.array(lat[1:]) * 1e3  # drop compile
    log.info("DIN batch=%d: p50 %.2f ms p99 %.2f ms (%d reqs)",
             batch, np.percentile(lat_ms, 50), np.percentile(lat_ms, 99),
             len(lat_ms))


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.make_reduced() if args.reduced else spec.make_config()
    if spec.family == "lm":
        serve_lm(cfg, batch=args.batch, prompt_len=args.prompt_len,
                 n_tokens=args.tokens)
    elif spec.family == "recsys":
        serve_din(cfg, batch=args.batch, n_requests=args.requests)
    else:
        raise SystemExit(f"{args.arch}: GNN archs are trained, not served")


if __name__ == "__main__":
    main()
