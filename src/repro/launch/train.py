"""End-to-end training driver (CPU-scale here; same step as the dry-run).

Wires together every substrate: ParaGrapher/CompBin/PG-Fuse data loading,
the model zoo, AdamW(+ZeRO specs on a real mesh), async checkpointing with
restart-from-latest, straggler monitoring, and optional error-feedback
gradient compression on the data axis (shard_map path).

    python -m repro.launch.train --arch smollm-360m --steps 50 --reduced
    python -m repro.launch.train --arch gcn-cora --steps 100 --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.distributed.fault_tolerance import ResilientTrainer, StragglerMonitor
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         ef_compress_psum, ef_state_init)

log = logging.getLogger("repro.train")


# ---------------------------------------------------------------------------
# data generators (reduced-scale synthetic; real runs pass shard paths)
# ---------------------------------------------------------------------------

def _lm_batches(cfg, batch: int, seq: int, tmpdir: str, use_pgfuse: bool):
    """Token batches from a CompBin-packed shard through PG-Fuse."""
    from repro.data import PrefetchIterator, TokenShardReader, write_token_shard
    path = os.path.join(tmpdir, "tokens.ctok")
    if not os.path.exists(path):
        rng = np.random.default_rng(0)
        write_token_shard(path, rng.integers(0, cfg.vocab, 200_000), cfg.vocab)
    reader = TokenShardReader(path, use_pgfuse=use_pgfuse,
                              pgfuse_block_size=1 << 16)
    raw = reader.batches(batch, seq, seed=0)
    return PrefetchIterator(
        ({"tokens": jnp.asarray(b[:, :-1]), "labels": jnp.asarray(b[:, 1:])}
         for b in raw), depth=2)


def _gnn_batches(arch_id: str, cfg, tmpdir: str, use_pgfuse: bool):
    """Minibatch sampling through the ParaGrapher API over CompBin."""
    from repro.core import paragrapher
    from repro.graph import NeighborSampler, rmat
    from repro.launch.data_gnn import block_to_batch

    path = os.path.join(tmpdir, "graph.cbin")
    csr = rmat(10, 8, seed=1)
    if not os.path.exists(path):
        paragrapher.save_graph(path, csr, format="compbin")
    g = paragrapher.open_graph(path, use_pgfuse=use_pgfuse,
                               pgfuse_block_size=1 << 16)
    sampler = NeighborSampler(g, fanouts=(5, 5), seed=0)
    rng = np.random.default_rng(0)

    def gen():
        while True:
            block = sampler.sample(rng.integers(0, csr.n_vertices, 64))
            yield block_to_batch(arch_id, cfg, block, rng)

    return gen()


def _gnn_sampled_batches(arch_id: str, cfg, tmpdir: str, use_pgfuse: bool,
                         batch_seeds: int = 64, fanouts=(5, 5)):
    """``--sampled``: minibatch training through the random-access query
    engine.  Adjacency comes from :class:`repro.query.NeighborQueryEngine`
    (deduplicated, block-coalesced CompBin reads), features and seed
    labels from the two column-family stores on the SAME PG-Fuse mount —
    all three byte streams share one memory budget under the
    random-access policy (:func:`repro.core.policy.choose_access_mode`:
    readahead off, clock eviction, churn capped), and nothing in the
    batch is synthesized on the host.
    """
    from repro.core import featstore, paragrapher, policy
    from repro.graph import NeighborSampler
    from repro.launch.data_gnn import ensure_gnn_assets, sampled_store_batch
    from repro.query import NeighborQueryEngine

    d_in = getattr(cfg, "d_in", getattr(cfg, "d_node_in", 16))
    n_classes = getattr(cfg, "n_classes", 7)
    block_size = 1 << 16
    gp, fp, lp = ensure_gnn_assets(tmpdir, d_in, n_classes,
                                   block_size=block_size)
    amode = policy.choose_access_mode("sample")
    budget = 256 * block_size
    g = paragrapher.open_graph(
        gp, use_pgfuse=use_pgfuse, pgfuse_block_size=block_size,
        pgfuse_readahead=amode.readahead, pgfuse_eviction=amode.eviction,
        pgfuse_max_resident_bytes=budget if use_pgfuse else None)
    churn_cap = (int(amode.churn_budget_fraction * budget)
                 if amode.churn_budget_fraction else None)
    feats = featstore.open_featstore(fp, fs=g.fs,
                                     pgfuse_file_budget=churn_cap,
                                     pgfuse_file_readahead=0)
    labels = featstore.open_featstore(lp, fs=g.fs, pgfuse_file_readahead=0)
    # "auto" decode: each layer's frontier batch picks host vs device by
    # its exact edge mass (policy.choose_query_decode) — large sampler
    # fanouts ship ONE H2D of merged packed runs to the Pallas kernel
    engine = NeighborQueryEngine(g, decode="auto")
    sampler = NeighborSampler(engine, fanouts=fanouts, seed=0)
    rng = np.random.default_rng(0)
    n = g.n_vertices
    log.info("sampled mode: %s over %s (|V|=%d); %s", arch_id, gp, n,
             amode.reason)

    def gen():
        step = 0
        while True:
            block = sampler.sample(rng.integers(0, n, batch_seeds))
            yield sampled_store_batch(arch_id, cfg, block, feats, labels)
            step += 1
            if step % 50 == 0:
                st = engine.stats
                log.info("query engine after %d batches: dedup %.2fx, "
                         "%d blocks touched, p50 %.2f ms, %d device-"
                         "decoded (%.1f KiB H2D)",
                         st.batches, st.dedup_ratio, st.blocks_touched,
                         st.p50_s * 1e3, st.device_batches,
                         st.bytes_h2d / 1024)

    return gen()


def _gnn_full_graph_batches(arch_id: str, cfg, tmpdir: str, use_pgfuse: bool,
                            hosts: int):
    """Full-graph mode: storage -> PG-Fuse -> packed CompBin + FeatStore
    rows -> device decode -> :func:`streamed_graph_batch`, on ``hosts``
    simulated processes.  The whole graph becomes ONE device-resident
    batch; every step is a full-batch epoch (the classic Cora/ogbn
    regime).  Neighbor IDs, feature rows, AND the label/mask column
    family all come off storage through the same PG-Fuse mount — the
    batch carries zero synthetic tensors.
    """
    from repro.core import paragrapher, policy
    from repro.data.multihost import (aggregate_stats, all_shards,
                                      simulate_hosts)
    from repro.launch.data_gnn import ensure_gnn_assets, streamed_graph_batch

    block_size = 1 << 16
    d_in = getattr(cfg, "d_in", getattr(cfg, "d_node_in", 16))
    # the converters: real deployments convert their raw feature/label
    # dumps once; benchmark graphs get the deterministic synthesized ones
    path, feat_path, label_path = ensure_gnn_assets(
        tmpdir, d_in, getattr(cfg, "n_classes", 7), block_size=block_size)
    open_kwargs = dict(use_pgfuse=use_pgfuse, pgfuse_block_size=block_size,
                       pgfuse_readahead=2)
    with paragrapher.open_graph(path) as g:
        align = policy.choose_feature_align(block_size, d_in * 4,
                                            g.n_vertices, hosts)
    results = simulate_hosts(path, hosts, open_kwargs=open_kwargs,
                             feature_path=feat_path, label_path=label_path,
                             align=align)
    for r in results:
        st = r.stats
        log.info("host %d/%d: vertices [%d,%d) %d partitions %d edges "
                 "[%s decode] %.1f KiB H2D, %d cache hits, %d storage "
                 "reads, %.1f KiB features (hit rate %.2f)",
                 r.process_index, hosts, *r.host_range, st.partitions,
                 st.edges, st.decode_mode, st.bytes_h2d / 1024,
                 st.cache_hits, st.underlying_reads,
                 st.feature_bytes / 1024, st.feature_hit_rate)
    agg = aggregate_stats(results)
    log.info("streamed %d edges + %d feature rows (%.1f KiB) over %d "
             "host(s): %.1f KiB H2D total, %d host-decoded bytes",
             agg.edges, agg.feature_rows, agg.feature_bytes / 1024, hosts,
             (agg.bytes_h2d + agg.feature_bytes_h2d) / 1024,
             agg.host_decode_bytes)
    if agg.feature_rows != results[0].n_vertices:
        raise RuntimeError(
            f"feature stream incomplete: {agg.feature_rows} rows for "
            f"{results[0].n_vertices} vertices")
    batch = streamed_graph_batch(arch_id, cfg, all_shards(results),
                                 np.random.default_rng(0),
                                 n_classes=getattr(cfg, "n_classes", 7),
                                 n_vertices=results[0].n_vertices)

    def gen():
        while True:
            yield batch

    return gen()


def _din_batches(cfg, batch: int):
    rng = np.random.default_rng(0)
    while True:
        yield {
            "hist_items": jnp.asarray(rng.integers(-1, cfg.n_items, (batch, cfg.seq_len))),
            "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, (batch, cfg.seq_len))),
            "cand_item": jnp.asarray(rng.integers(0, cfg.n_items, batch)),
            "cand_cate": jnp.asarray(rng.integers(0, cfg.n_cates, batch)),
            "labels": jnp.asarray(rng.integers(0, 2, batch).astype(np.float32)),
        }


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def _make_step(arch_id: str, cfg, opt_cfg: AdamWConfig, family: str,
               compress_grads: bool):
    if family == "lm":
        from repro.models import transformer as tf
        loss_fn = lambda p, b: tf.loss_fn(p, b["tokens"], b["labels"], cfg)
        init_fn = lambda key: tf.init_params(cfg, key)
    elif family == "gnn":
        from repro.launch.steps import _GNN_MODULES
        mod = _GNN_MODULES[arch_id]
        loss_fn = lambda p, b: mod.loss_fn(p, b, cfg)
        init_fn = lambda key: mod.init_params(cfg, key)
    else:
        from repro.models.recsys import din as m_din
        loss_fn = lambda p, b: m_din.loss_fn(p, b, cfg)
        init_fn = lambda key: m_din.init_params(cfg, key)

    if compress_grads:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        from jax.sharding import PartitionSpec as P

        def step(state, batch):
            def shard_step(state, batch):
                def loss_local(p):
                    return loss_fn(p, batch)
                l, g = jax.value_and_grad(loss_local)(state["params"])
                g, ef = ef_compress_psum(g, state["ef"], "data",
                                         axis_size=mesh.devices.size)
                l = jax.lax.pmean(l, "data")
                params, opt, met = adamw_update(state["params"], g,
                                                state["opt"], opt_cfg)
                return ({"params": params, "opt": opt, "ef": ef},
                        {**met, "loss": l})

            from repro.distributed.sharding import shard_map
            batch_spec = jax.tree.map(lambda _: P("data"), batch)
            return shard_map(
                shard_step, mesh=mesh,
                in_specs=(P(), batch_spec), out_specs=(P(), P()))(state, batch)

        return init_fn, jax.jit(step)

    def step(state, batch):
        l, g = jax.value_and_grad(lambda p: loss_fn(p, batch))(state["params"])
        params, opt, met = adamw_update(state["params"], g, state["opt"], opt_cfg)
        return {"params": params, "opt": opt}, {**met, "loss": l}

    return init_fn, jax.jit(step)


def main() -> None:
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--use-pgfuse", action="store_true", default=True)
    ap.add_argument("--full-graph", action="store_true",
                    help="GNN archs: train full-batch on the streamed "
                         "partition->device pipeline instead of sampled "
                         "minibatches")
    ap.add_argument("--sampled", action="store_true",
                    help="GNN archs: sampled minibatches drawn through "
                         "the random-access query engine (repro.query), "
                         "features+labels gathered from the column-family "
                         "stores on the shared PG-Fuse mount")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulated processes for --full-graph streaming "
                         "(data/multihost.py)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    args = ap.parse_args()

    os.makedirs(args.workdir, exist_ok=True)
    spec = get_arch(args.arch)
    cfg = spec.make_reduced() if args.reduced else spec.make_config()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps,
                          master_f32=True)

    if spec.family == "lm":
        batches = _lm_batches(cfg, args.batch, args.seq, args.workdir,
                              args.use_pgfuse)
    elif spec.family == "gnn":
        if args.full_graph and args.sampled:
            ap.error("--full-graph and --sampled are mutually exclusive")
        if args.full_graph:
            batches = _gnn_full_graph_batches(args.arch, cfg, args.workdir,
                                              args.use_pgfuse, args.hosts)
        elif args.sampled:
            batches = _gnn_sampled_batches(args.arch, cfg, args.workdir,
                                           args.use_pgfuse)
        else:
            batches = _gnn_batches(args.arch, cfg, args.workdir,
                                   args.use_pgfuse)
    else:
        batches = _din_batches(cfg, args.batch)

    init_fn, step_fn = _make_step(args.arch, cfg, opt_cfg, spec.family,
                                  args.compress_grads)
    params = init_fn(jax.random.key(0))
    state = {"params": params, "opt": adamw_init(params, opt_cfg)}
    if args.compress_grads:
        state["ef"] = ef_state_init(params)

    ckpt_dir = args.ckpt_dir or os.path.join(args.workdir, f"ckpt_{args.arch}")
    trainer = ResilientTrainer(step_fn, state, ckpt_dir=ckpt_dir,
                               ckpt_every=args.ckpt_every)
    monitor = StragglerMonitor(n_hosts=1)
    losses = []

    def on_metrics(step, met):
        monitor.record(0, met["step_time_s"])
        losses.append(float(met["loss"]))
        if step % 10 == 0 or step == args.steps:
            log.info("step %d loss %.4f grad_norm %.3f lr %.2e (%.0f ms)",
                     step, float(met["loss"]), float(met["grad_norm"]),
                     float(met["lr"]), met["step_time_s"] * 1e3)

    trainer.run(batches, n_steps=args.steps, on_metrics=on_metrics,
                inject_failure_at=args.inject_failure_at)
    log.info("done: first-10 mean loss %.4f -> last-10 mean loss %.4f",
             float(np.mean(losses[:10])), float(np.mean(losses[-10:])))


if __name__ == "__main__":
    main()
