"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods x 256 chips; ``.lower().compile()``
must succeed for every cell, and the compiled artifact yields the roofline
terms (launch/hlo_analysis.py) recorded in EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh multi
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json

``--all`` forks one subprocess per cell (fresh XLA state; a crashing cell
cannot take down the sweep) and merges results incrementally into --out.
"""

# The VERY FIRST two lines — before ANY other import — jax locks the device
# count on first init (system-prompt contract for this dry-run):
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool,
             variant: str = "baseline") -> dict:
    import jax

    from repro.configs import get_arch
    from repro.launch.hlo_analysis import parse_collectives, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.launch.variants import apply_variant

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_devices),
        "family": get_arch(arch).family,
    }
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, unroll=not multi_pod,
                      **apply_variant(arch, shape, variant))
    if cell.skip_reason:
        rec.update(status="SKIP", skip_reason=cell.skip_reason)
        return rec
    rec["kind"] = cell.kind
    with mesh:
        jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
        lowered = jf.lower(*cell.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    rl = roofline(cost, coll, n_devices, cell.model_flops)
    rec.update(
        status="OK",
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        memory={
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_est_bytes": int(mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
        },
        cost={"flops": float(cost.get("flops", 0.0)),
              "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
              "transcendentals": float(cost.get("transcendentals", 0.0))},
        collectives={"ops": coll.ops,
                     "logical_bytes": coll.logical_bytes,
                     "wire_bytes": float(coll.wire_bytes)},
        roofline=rl.as_dict(),
    )
    return rec


def _merge_out(out_path: str, rec: dict) -> None:
    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}|{rec.get('variant','baseline')}"
    data[key] = rec
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, out_path)


def _run_all(meshes: list[str], out_path: str, variant: str,
             only_missing: bool, timeout: int, jobs: int = 1) -> int:
    import threading

    from repro.configs import all_cells
    existing = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            existing = json.load(f)
    todo = []
    for mesh_name in meshes:
        for arch, shape in all_cells():
            key = f"{arch}|{shape}|{'2x16x16' if mesh_name=='multi' else '16x16'}|{variant}"
            if only_missing and existing.get(key, {}).get("status") in ("OK", "SKIP"):
                continue
            todo.append((key, arch, shape, mesh_name))

    lock = threading.Lock()
    failures = [0]

    def worker():
        while True:
            with lock:
                if not todo:
                    return
                key, arch, shape, mesh_name = todo.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_name,
                   "--variant", variant, "--out", out_path]
            print(f"[dryrun] {key} ...", flush=True)
            t0 = time.time()
            mesh_tag = "2x16x16" if mesh_name == "multi" else "16x16"
            try:
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout)
            except subprocess.TimeoutExpired:
                print(f"  {key} TIMEOUT after {timeout}s", flush=True)
                with lock:
                    _merge_out(out_path, {"arch": arch, "shape": shape,
                                          "variant": variant, "mesh": mesh_tag,
                                          "status": "TIMEOUT"})
                    failures[0] += 1
                continue
            dt = time.time() - t0
            if r.returncode != 0:
                tail = (r.stderr or r.stdout).strip().splitlines()[-12:]
                print(f"  {key} FAIL ({dt:.0f}s):\n    "
                      + "\n    ".join(tail), flush=True)
                with lock:
                    # single-cell invocations merge their own record (incl.
                    # python-level errors); only fill in hard crashes
                    data = {}
                    if os.path.exists(out_path):
                        with open(out_path) as f:
                            data = json.load(f)
                    if data.get(key, {}).get("status") not in ("FAIL",):
                        _merge_out(out_path, {"arch": arch, "shape": shape,
                                              "variant": variant,
                                              "mesh": mesh_tag,
                                              "status": "FAIL",
                                              "error": "\n".join(tail)})
                    failures[0] += 1
            else:
                print(f"  {key} ok ({dt:.0f}s)", flush=True)

    threads = [threading.Thread(target=worker) for _ in range(max(1, jobs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return failures[0]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--jobs", type=int, default=1)
    args = ap.parse_args()

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    if args.all:
        sys.exit(1 if _run_all(meshes, args.out, args.variant,
                               args.only_missing, args.timeout,
                               args.jobs) else 0)

    assert args.arch and args.shape, "--arch/--shape required without --all"
    for mesh_name in meshes:
        rec = None
        try:
            rec = run_cell(args.arch, args.shape, mesh_name == "multi",
                           args.variant)
        except Exception:
            traceback.print_exc()
            rec = {"arch": args.arch, "shape": args.shape,
                   "variant": args.variant,
                   "mesh": "2x16x16" if mesh_name == "multi" else "16x16",
                   "status": "FAIL", "error": traceback.format_exc()[-2000:]}
        _merge_out(args.out, rec)
        status = rec.get("status")
        print(json.dumps({k: v for k, v in rec.items()
                          if k in ("arch", "shape", "mesh", "status",
                                   "lower_s", "compile_s", "skip_reason")}))
        if status == "FAIL":
            sys.exit(1)


if __name__ == "__main__":
    main()
