"""CLI for the offline graph compiler (reorder + recompress).

    PYTHONPATH=src python -m repro.launch.compile_graph \
        --in graph.cbin --out graph_bfs.lgsr --codec logcsr

Reads any registered codec, applies the locality permutation
:func:`repro.core.policy.choose_reorder` selects (``--strategy``
overrides), re-encodes through the chosen codec and writes the inverse
permutation sidecar next to the output (``--sidecar`` overrides).  The
compile self-verifies before returning: sampled vertices must answer
byte-identically through the inverse permutation, or the output files
are removed and the run fails.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core.codec import registered_codecs
from repro.core.policy import REORDER_STRATEGIES
from repro.graph.reorder import compile_graph


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Reorder + re-encode an on-disk graph")
    ap.add_argument("--in", dest="in_path", required=True,
                    help="input graph (any registered codec)")
    ap.add_argument("--out", dest="out_path", required=True,
                    help="compiled graph output path")
    ap.add_argument("--codec", default="compbin",
                    choices=sorted(registered_codecs()),
                    help="output codec (default: compbin)")
    ap.add_argument("--strategy", default=None,
                    choices=list(REORDER_STRATEGIES),
                    help="vertex ordering (default: policy.choose_reorder)")
    ap.add_argument("--sidecar", default=None,
                    help="inverse-permutation sidecar path "
                         "(default: <out>.perm)")
    ap.add_argument("--verify-samples", type=int, default=64,
                    help="vertices sampled for the byte-identity check")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    report = compile_graph(
        args.in_path, args.out_path, codec=args.codec,
        strategy=args.strategy, sidecar=args.sidecar,
        verify_samples=args.verify_samples, seed=args.seed)
    print(json.dumps(report.as_dict(), indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
