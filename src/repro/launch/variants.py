"""Named configuration variants for the §Perf hillclimb.

``apply_variant(arch, shape, name)`` returns the kwargs for
``build_cell`` realizing that variant; "baseline" is the paper-faithful
configuration.  Variants are registered here so every hillclimb iteration
is reproducible from the CLI:

    python -m repro.launch.dryrun --arch dbrx-132b --shape decode_32k \
        --variant <name> --mesh single
"""

from __future__ import annotations

from typing import Any


def apply_variant(arch: str, shape: str, name: str) -> dict[str, Any]:
    if name == "baseline":
        return {}
    if name not in VARIANTS:
        raise KeyError(f"unknown variant {name!r}; known: {list(VARIANTS)}")
    return VARIANTS[name](arch, shape)


def _dense_attn(arch: str, shape: str) -> dict:
    return {"cfg_overrides": {"attn_impl": "dense"}}


def _chunk(n: int):
    def f(arch: str, shape: str) -> dict:
        return {"cfg_overrides": {"attn_chunk": n}}
    return f


def _no_remat(arch: str, shape: str) -> dict:
    return {"cfg_overrides": {"remat": False}}


def _moe_gather(arch: str, shape: str) -> dict:
    return {"cfg_overrides": {"moe_dispatch": "gather"}}


def _ce_chunk(n: int):
    def f(arch: str, shape: str) -> dict:
        return {"cfg_overrides": {"ce_chunk": n}}
    return f


def _edges_compbin(arch: str, shape: str) -> dict:
    return {"edges_packed": True}


def _combo_lm_best(arch: str, shape: str) -> dict:
    # best-of combination for LM train cells
    over = {"ce_chunk": 512, "attn_chunk": 1024}
    return {"cfg_overrides": over}


def _combo_moe_best(arch: str, shape: str) -> dict:
    return {"cfg_overrides": {"moe_dispatch": "gather", "ce_chunk": 512}}


def _moe_gather_cf(cf: float):
    def f(arch: str, shape: str) -> dict:
        return {"cfg_overrides": {"moe_dispatch": "gather",
                                  "capacity_factor": cf}}
    return f


VARIANTS = {
    "dense_attn": _dense_attn,
    "chunk_1024": _chunk(1024),
    "chunk_2048": _chunk(2048),
    "chunk_4096": _chunk(4096),
    "chunk_8192": _chunk(8192),
    "no_remat": _no_remat,
    "moe_gather": _moe_gather,
    "moe_gather_cf1": _moe_gather_cf(1.0),
    "moe_gather_cf2": _moe_gather_cf(2.0),
    "ce_chunk_512": _ce_chunk(512),
    "ce_chunk_1024": _ce_chunk(1024),
    "edges_compbin": _edges_compbin,
    "combo_lm_best": _combo_lm_best,
    "combo_moe_best": _combo_moe_best,
    "attn_p_bf16": lambda a, s: {"cfg_overrides": {"attn_p_bf16": True}},
    "gcn_transform_first": lambda a, s: {"gnn_cfg_overrides":
                                         {"transform_first": True}},
}
