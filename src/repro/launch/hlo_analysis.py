"""HLO post-compile analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` gives per-device HLO FLOPs and bytes, but no
collective traffic — we parse the optimized HLO text and sum the operand
bytes of every collective op, modeling on-wire bytes per op kind (ring
algorithms), with the group size taken from ``replica_groups``:

    all-reduce          2 (n-1)/n x bytes
    all-gather          (n-1)/n x result_bytes
    reduce-scatter      (n-1)   x result_bytes   (= (n-1)/n x operand)
    all-to-all          (n-1)/n x bytes
    collective-permute  1       x bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|[su]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    ops: dict                    # kind -> count
    logical_bytes: dict          # kind -> summed operand/result bytes
    wire_bytes: float            # ring-model on-wire bytes (per device)

    def total_logical(self) -> float:
        return float(sum(self.logical_bytes.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    ops: dict = {}
    logical: dict = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        # Optimized HLO prints operands as bare %refs (no inline types);
        # the RESULT type always precedes the op name — model wire bytes
        # from the per-device result size.
        result_bytes = _shape_bytes(m.group(1))
        n = max(2, _group_size(line))
        if kind == "all-reduce":
            w = 2 * (n - 1) / n * result_bytes      # result == operand
        elif kind == "all-gather":
            w = (n - 1) / n * result_bytes          # result = gathered
        elif kind == "reduce-scatter":
            w = (n - 1) * result_bytes              # operand = n x result
        elif kind == "all-to-all":
            w = (n - 1) / n * result_bytes
        else:  # collective-permute
            w = float(result_bytes)
        ops[kind] = ops.get(kind, 0) + 1
        logical[kind] = logical.get(kind, 0) + result_bytes
        wire += w
    return CollectiveStats(ops=ops, logical_bytes=logical, wire_bytes=wire)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_wire_bytes: float
    n_devices: int
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None  # MODEL_FLOPS / (HLO_FLOPs x chips)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(cost, coll: CollectiveStats, n_devices: int,
             model_flops: Optional[float] = None) -> Roofline:
    """cost: compiled.cost_analysis() (per-device HLO module).

    jax <= 0.4.x returns a one-element list of dicts; newer jax returns
    the dict directly — accept both.
    """
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    mem = float(cost.get("bytes accessed", 0.0))
    t_c = flops / PEAK_FLOPS
    t_m = mem / HBM_BW
    t_x = coll.wire_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops * n_devices, 1.0)
    return Roofline(flops_per_device=flops, bytes_per_device=mem,
                    coll_wire_bytes=coll.wire_bytes, n_devices=n_devices,
                    t_compute=t_c, t_memory=t_m, t_collective=t_x,
                    dominant=dom, model_flops=model_flops,
                    useful_ratio=useful)
