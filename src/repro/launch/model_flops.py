"""Analytic MODEL_FLOPS per (arch, shape) — the "useful work" numerator of
the roofline's utilization ratio.

LM: 6*N*D train (N = params, D = tokens; MoE: N_active), 2*N*D inference,
plus the KV-cache attention term for decode.  GNN/recsys: per-op counts
(documented inline) — matmul-dominated terms only, gathers/scatters count
as bytes not FLOPs.
"""

from __future__ import annotations

from repro.configs import get_arch
from repro.configs.shapes import (GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES,
                                  gnn_input_specs)


def lm_model_flops(cfg, shape) -> float:
    n_act = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        core = 6.0 * n_act * B * S
        # causal attention: 2 matmuls x 2 ops x S^2/2 x fwd+bwd(3x)
        attn = 3.0 * 2.0 * 2.0 * B * cfg.n_layers * cfg.n_heads * cfg.d_head * S * S / 2
        return core + attn
    if shape.kind == "prefill":
        core = 2.0 * n_act * B * S
        attn = 2.0 * 2.0 * B * cfg.n_layers * cfg.n_heads * cfg.d_head * S * S / 2
        return core + attn
    # decode: one token, full KV read
    core = 2.0 * n_act * B
    attn = 2.0 * 2.0 * B * cfg.n_layers * cfg.n_heads * cfg.d_head * shape.seq_len
    return core + attn


def gnn_model_flops(arch_id: str, cfg, shape) -> float:
    N, E, F = shape.n_nodes, shape.n_edges, shape.d_feat
    train_mult = 3.0  # fwd + bwd(2x)
    if arch_id == "gcn-cora":
        d = cfg.d_hidden
        fwd = 2.0 * N * (F * d + d * cfg.n_classes) + 2.0 * E * (F + d)
    elif arch_id == "pna":
        d = cfg.d_hidden
        per_layer = 2.0 * E * (2 * d) * d + 2.0 * N * (13 * d) * d
        fwd = 2.0 * N * F * d + cfg.n_layers * per_layer
    elif arch_id == "meshgraphnet":
        d = cfg.d_hidden
        per_layer = 2.0 * E * (3 * d) * d + 2.0 * E * d * d \
            + 2.0 * N * (2 * d) * d + 2.0 * N * d * d
        fwd = 2.0 * (N * cfg.d_node_in + E * cfg.d_edge_in) * d \
            + cfg.n_layers * per_layer
    elif arch_id == "dimenet":
        d = cfg.d_hidden
        T = int(shape.triplet_factor * E)
        nsr = cfg.n_spherical * cfg.n_radial
        per_block = (2.0 * T * (d * cfg.n_bilinear + nsr * cfg.n_bilinear)
                     + 2.0 * E * (cfg.n_bilinear * d + 2 * d * d))
        fwd = 2.0 * E * (2 * cfg.d_in + cfg.n_radial) * d + cfg.n_blocks * per_block
    else:
        raise KeyError(arch_id)
    return train_mult * fwd


def din_model_flops(cfg, shape) -> float:
    d = cfg.d_item
    S = cfg.seq_len
    a1, a2 = cfg.attn_mlp
    m1, m2 = cfg.mlp
    per_cand = (2.0 * S * (4 * d * a1 + a1 * a2 + a2)
                + 2.0 * (3 * d * m1 + m1 * m2 + m2))
    if shape.kind == "train":
        return 3.0 * shape.batch * per_cand
    if shape.kind == "retrieval":
        return float(shape.n_candidates) * per_cand
    return float(shape.batch) * per_cand


def model_flops(arch_id: str, shape_id: str) -> float:
    spec = get_arch(arch_id)
    cfg = _full_cfg(arch_id)
    if spec.family == "lm":
        return lm_model_flops(cfg, LM_SHAPES[shape_id])
    if spec.family == "gnn":
        return gnn_model_flops(arch_id, cfg, GNN_SHAPES[shape_id])
    return din_model_flops(cfg, RECSYS_SHAPES[shape_id])


def _full_cfg(arch_id: str):
    spec = get_arch(arch_id)
    return spec.make_config()
