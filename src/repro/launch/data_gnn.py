"""Convert sampled blocks / generated graphs into model batch dicts."""

from __future__ import annotations

import os

import numpy as np

from repro.core.csr import CSR
from repro.graph.sampler import SampledBlock


def ensure_gnn_assets(workdir: str, d_in: int, n_classes: int, *,
                      scale: int = 10, edge_factor: int = 8, seed: int = 1,
                      block_size: int = 1 << 16
                      ) -> tuple[str, str, str]:
    """Idempotently materialize the demo GNN storage triplet in
    ``workdir``: CompBin topology + feature store + label/mask column
    family (all block-aligned to ``block_size``).  Returns
    (graph_path, feature_path, label_path) — the same files whether the
    caller streams them sequentially (--full-graph), samples minibatches
    through the query engine (--sampled), or serves inference requests.
    """
    from repro.core import paragrapher
    from repro.graph import (featstore_for_graph, labelstore_for_graph, rmat,
                             synthesize_node_features,
                             synthesize_separable_labels)

    os.makedirs(workdir, exist_ok=True)
    gp = os.path.join(workdir, f"graph_s{scale}e{edge_factor}.cbin")
    if not os.path.exists(gp):
        paragrapher.save_graph(gp, rmat(scale, edge_factor, seed=seed),
                               format="compbin")
    fp = os.path.join(workdir, f"graph_s{scale}e{edge_factor}_d{d_in}.fst")
    if not os.path.exists(fp):
        featstore_for_graph(gp, fp, d_in, seed=0, data_align=block_size)
    lp = os.path.join(workdir,
                      f"graph_s{scale}e{edge_factor}_d{d_in}c{n_classes}.lbl")
    if not os.path.exists(lp):
        # labels derived from the stored features (fixed projection), so
        # training on the triplet has signal to fit — loss decreases
        with paragrapher.open_graph(gp) as g:
            n = g.n_vertices
        x = synthesize_node_features(n, d_in, seed=0)
        labelstore_for_graph(gp, lp, n_classes, seed=0,
                             labels=synthesize_separable_labels(x, n_classes),
                             data_align=block_size)
    return gp, fp, lp


def block_to_edges(block: SampledBlock) -> tuple[np.ndarray, np.ndarray, int]:
    """Padded tree block -> (edge_src, edge_dst) local indices + n_nodes.

    Layer l slot i's children occupy slots [i*f, (i+1)*f) of layer l+1;
    edges point child -> parent (message flows to the seed side).
    """
    offsets = np.cumsum([0] + [len(x) for x in block.layer_nodes])
    srcs, dsts = [], []
    for l, f in enumerate(block.fanouts):
        n_par = len(block.layer_nodes[l])
        child_base = offsets[l + 1]
        par_base = offsets[l]
        child_idx = child_base + np.arange(n_par * f)
        par_idx = par_base + np.repeat(np.arange(n_par), f)
        valid = block.layer_valid[l + 1]
        srcs.append(np.where(valid, child_idx, -1))
        dsts.append(np.where(valid, par_idx, -1))
    return (np.concatenate(srcs), np.concatenate(dsts), int(offsets[-1]))


def block_features(block: SampledBlock, d_feat: int, rng) -> np.ndarray:
    """Feature matrix for all block nodes (hashed-random stand-in: real
    deployments gather rows from the feature store through PG-Fuse)."""
    nodes = np.concatenate(block.layer_nodes)
    feats = rng.standard_normal((len(nodes), d_feat)).astype(np.float32)
    return np.where((nodes >= 0)[:, None], feats, 0)


def block_to_batch(arch_id: str, cfg, block: SampledBlock, rng) -> dict:
    import jax.numpy as jnp

    src, dst, n = block_to_edges(block)
    d_in = getattr(cfg, "d_in", getattr(cfg, "d_node_in", 16))
    x = block_features(block, d_in, rng)
    batch = {
        "x": jnp.asarray(x),
        "edge_src": jnp.asarray(src.astype(np.int32)),
        "edge_dst": jnp.asarray(dst.astype(np.int32)),
    }
    n_seeds = len(block.seeds)
    if arch_id in ("gcn-cora", "pna"):
        n_classes = cfg.n_classes
        labels = np.full(n, -1, np.int64)
        labels[:n_seeds] = rng.integers(0, n_classes, n_seeds)
        mask = np.zeros(n, bool)
        mask[:n_seeds] = True
        batch["labels"] = jnp.asarray(labels)
        batch["label_mask"] = jnp.asarray(mask)
    elif arch_id == "meshgraphnet":
        batch["edge_attr"] = jnp.asarray(
            rng.standard_normal((len(src), cfg.d_edge_in)).astype(np.float32))
        batch["targets"] = jnp.asarray(
            rng.standard_normal((n, cfg.d_out)).astype(np.float32))
        batch["node_mask"] = jnp.asarray(np.arange(n) < n_seeds)
    elif arch_id == "dimenet":
        batch["pos"] = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
        E = len(src)
        T = 2 * E
        batch["triplet_kj"] = jnp.asarray(rng.integers(0, E, T).astype(np.int32))
        batch["triplet_ji"] = jnp.asarray(rng.integers(0, E, T).astype(np.int32))
        batch["graph_id"] = jnp.asarray(np.zeros(n, np.int32))
        batch["targets"] = jnp.asarray(rng.standard_normal((1, 1)).astype(np.float32))
        batch["n_graphs"] = 1
    return batch


def device_batch(np_batch: dict) -> dict:
    """Ship a whole numpy batch dict to the accelerator with ONE
    ``jax.device_put`` call.

    The serving path assembles every tensor of a request batch on the
    host first (feature rows, edge index, labels/masks) and transfers
    them together — one H2D dispatch per request batch instead of one
    implicit transfer per ``jnp.asarray``, which is where per-request
    latency went on the PR-4 path."""
    import jax

    return jax.device_put(np_batch)


def sampled_store_batch(arch_id: str, cfg, block: SampledBlock, feats,
                        labels=None) -> dict:
    """Minibatch dict from a sampled block with REAL per-node tensors:
    feature rows gathered from the feature store and (when a label store
    is given) seed labels/masks from the label column family — the
    sampled-training sibling of :func:`streamed_graph_batch`, zero
    synthetic tensors on the gcn/pna path.

    ``feats``/``labels`` are :class:`repro.core.featstore.FeatureStoreHandle`
    objects, typically mounted on the SAME PG-Fuse instance as the graph
    the block was sampled from (one memory budget for topology + features
    + labels).  Row gathers go through
    :func:`repro.query.engine.gather_rows` (dedup + run-coalesced reads),
    and the assembled batch crosses to the device as ONE transfer
    (:func:`device_batch`).
    """
    from repro.query.engine import gather_rows

    src, dst, n = block_to_edges(block)
    nodes = np.concatenate(block.layer_nodes)
    valid = np.concatenate(block.layer_valid)
    x = gather_rows(feats, np.where(valid, nodes, -1))
    batch = {
        "x": np.ascontiguousarray(x, dtype=np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
    }
    if arch_id in ("gcn-cora", "pna"):
        n_seeds = len(block.seeds)
        lab = np.full(n, -1, np.int64)
        mask = np.zeros(n, bool)
        if labels is not None:
            fam = gather_rows(labels, block.seeds)
            lab[:n_seeds] = fam[:, 0].astype(np.int64)
            # only seeds the store marks as training rows contribute loss
            mask[:n_seeds] = fam[:, 1].astype(bool)
        batch["labels"] = lab
        batch["label_mask"] = mask
    return device_batch(batch)


def shards_to_edge_index(shards) -> tuple:
    """Streamed device shards -> (edge_src, edge_dst) ON DEVICE.

    The whole point of the streaming loader: the neighbor IDs never exist
    decoded on the host, so the edge index is derived where it is consumed.
    Row IDs are expanded from each shard's offsets with a static
    total_repeat_length (the shard's edge count), keeping shapes jit-able.
    """
    import jax.numpy as jnp

    srcs, dsts = [], []
    for s in sorted(shards, key=lambda sh: sh.v0):
        deg = jnp.diff(s.offsets)
        srcs.append(jnp.repeat(
            jnp.arange(s.v0, s.v1, dtype=jnp.int32), deg,
            total_repeat_length=s.n_edges))
        dsts.append(s.neighbors.astype(jnp.int32))
    if not srcs:
        z = jnp.zeros(0, jnp.int32)
        return z, z
    return jnp.concatenate(srcs), jnp.concatenate(dsts)


def shards_to_features(shards) -> "jax.Array | None":
    """Streamed per-shard feature rows -> one (n, d) device matrix.

    Returns None when the shards carry no features (no store attached).
    A MIX of featured and feature-less shards is an error: it means some
    host streamed the feature store and some did not, and training would
    silently run on garbage rows for the missing range.
    """
    import jax.numpy as jnp

    shards = sorted(shards, key=lambda s: s.v0)
    have = [s.x is not None for s in shards]
    if not any(have):
        return None
    if not all(have):
        missing = [(s.v0, s.v1) for s, h in zip(shards, have) if not h]
        raise ValueError(
            f"shards {missing} carry no feature rows but others do; every "
            f"host must stream the same feature store")
    return jnp.concatenate([s.x for s in shards])


def shards_to_labels(shards) -> "tuple | None":
    """Streamed label-family rows -> (labels int32[n], mask bool[n]) on
    device, or None when no label store was attached.  Mixed
    labeled/unlabeled shards are an error for the same reason mixed
    feature shards are (see :func:`shards_to_features`)."""
    import jax.numpy as jnp

    shards = sorted(shards, key=lambda s: s.v0)
    have = [s.y is not None for s in shards]
    if not any(have):
        return None
    if not all(have):
        missing = [(s.v0, s.v1) for s, h in zip(shards, have) if not h]
        raise ValueError(
            f"shards {missing} carry no label rows but others do; every "
            f"host must stream the same label store")
    y = jnp.concatenate([s.y for s in shards])
    return y[:, 0].astype(jnp.int32), y[:, 1].astype(bool)


def streamed_graph_batch(arch_id: str, cfg, shards, rng, *,
                         n_classes: int = 7,
                         n_vertices: int | None = None) -> dict:
    """Full-graph training dict straight from streamed device shards
    (the device-resident sibling of :func:`full_graph_batch`).

    ``shards`` may come from one stream or from every host of a
    multi-host load (``data/multihost.py::all_shards``); full-graph
    training needs the WHOLE vertex range, so a gap in coverage (a host's
    shards missing) is an error, not a silently smaller graph.  Pass
    ``n_vertices`` (the graph's true vertex count, e.g.
    ``HostResult.n_vertices``) to also reject a missing TAIL — without it
    only interior gaps are detectable.

    When the stream carried a feature store (``feature_path=``), ``x``
    is the shards' real feature rows — storage -> PG-Fuse -> device with
    zero host synthesis; the hashed-random stand-in is used only for
    feature-less streams.  When it also carried the label/mask column
    family (``label_path=``), ``labels``/``label_mask`` come off storage
    too and the batch holds ZERO synthetic tensors.
    """
    import jax.numpy as jnp

    shards = sorted(shards, key=lambda s: s.v0)
    expect = 0
    for s in shards:
        if s.v0 != expect:
            raise ValueError(
                f"streamed shards do not cover the graph: gap/overlap at "
                f"vertex {expect} (next shard starts at {s.v0}); full-graph "
                f"training needs every host's shards")
        expect = s.v1
    if n_vertices is not None and expect != n_vertices:
        raise ValueError(
            f"streamed shards cover only [0, {expect}) of {n_vertices} "
            f"vertices (trailing host missing); full-graph training needs "
            f"every host's shards")
    src, dst = shards_to_edge_index(shards)
    n = expect  # the coverage loop proved the shards tile [0, expect)
    d_in = getattr(cfg, "d_in", getattr(cfg, "d_node_in", 16))
    x = shards_to_features(shards)
    if x is not None and int(x.shape[1]) != d_in:
        raise ValueError(
            f"feature store rows have d={int(x.shape[1])} but the model "
            f"expects d_in={d_in}")
    if x is None:
        x = jnp.asarray(rng.standard_normal((n, d_in)).astype(np.float32))
    batch = {
        "x": x.astype(jnp.float32),
        "edge_src": src,
        "edge_dst": dst,
    }
    if arch_id in ("gcn-cora", "pna"):
        lab = shards_to_labels(shards)
        if lab is not None:
            if int(jnp.max(lab[0])) >= n_classes:
                raise ValueError(
                    f"label store holds class {int(jnp.max(lab[0]))} but "
                    f"the model expects n_classes={n_classes}")
            batch["labels"], batch["label_mask"] = lab
        else:
            batch["labels"] = jnp.asarray(rng.integers(0, n_classes, n))
            batch["label_mask"] = jnp.asarray(rng.random(n) < 0.3)
    return batch


def full_graph_batch(arch_id: str, cfg, csr: CSR, rng, *,
                     n_classes: int = 7) -> dict:
    """Full-batch training dict from an in-memory CSR."""
    import jax.numpy as jnp

    src, dst = csr.edge_index()
    n = csr.n_vertices
    d_in = getattr(cfg, "d_in", getattr(cfg, "d_node_in", 16))
    batch = {
        "x": jnp.asarray(rng.standard_normal((n, d_in)).astype(np.float32)),
        "edge_src": jnp.asarray(src.astype(np.int32)),
        "edge_dst": jnp.asarray(dst.astype(np.int32)),
    }
    if arch_id in ("gcn-cora", "pna"):
        batch["labels"] = jnp.asarray(rng.integers(0, n_classes, n))
        batch["label_mask"] = jnp.asarray(rng.random(n) < 0.3)
    elif arch_id == "meshgraphnet":
        batch["edge_attr"] = jnp.asarray(
            rng.standard_normal((len(src), cfg.d_edge_in)).astype(np.float32))
        batch["targets"] = jnp.asarray(
            rng.standard_normal((n, cfg.d_out)).astype(np.float32))
    elif arch_id == "dimenet":
        E = len(src)
        batch["pos"] = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
        batch["triplet_kj"] = jnp.asarray(rng.integers(0, E, 2 * E).astype(np.int32))
        batch["triplet_ji"] = jnp.asarray(rng.integers(0, E, 2 * E).astype(np.int32))
        batch["graph_id"] = jnp.asarray(np.zeros(n, np.int32))
        batch["targets"] = jnp.asarray(rng.standard_normal((1, 1)).astype(np.float32))
        batch["n_graphs"] = 1
    return batch
