"""Cell builder: (arch x shape x mesh) -> jittable step + shardings + specs.

This is the single source of truth used by the dry-run, the trainers and
the benchmarks, so what we lower in the 512-device dry-run is exactly what
``train.py``/``serve.py`` execute.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.shapes import (GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES,
                                  din_input_specs, gnn_input_specs,
                                  lm_input_specs)
from repro.distributed import sharding as shard_rules
from repro.launch import model_flops as mf
from repro.models import transformer as tf
from repro.models.gnn import dimenet as m_dimenet
from repro.models.gnn import gcn as m_gcn
from repro.models.gnn import meshgraphnet as m_mgn
from repro.models.gnn import pna as m_pna
from repro.models.recsys import din as m_din
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    kind: str                      # train | prefill | decode | serve | retrieval
    fn: Optional[Callable]         # fn(*args)
    args: Optional[tuple]          # ShapeDtypeStruct pytrees
    in_shardings: Optional[tuple]
    out_shardings: Any
    model_flops: float
    skip_reason: Optional[str] = None
    donate: tuple = ()


def _named(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _replicated_specs(tree_like: Any) -> Any:
    return jax.tree.map(lambda x: P(*([None] * len(x.shape))), tree_like)


def _abstract(fn: Callable, *args) -> Any:
    return jax.eval_shape(fn, *args)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch_id: str, shape_id: str, mesh: Mesh, *,
             opt_cfg: Optional[AdamWConfig] = None,
             cfg_overrides: Optional[dict] = None,
             unroll: bool = True) -> Cell:
    spec = get_arch(arch_id)
    shape = LM_SHAPES[shape_id]
    cfg = spec.make_config()
    # Dry-run defaults: unrolled execution for exact HLO cost accounting
    # (XLA counts while-loop bodies once — see TransformerConfig docstring)
    # + per-shape attention chunk sizes keeping one tile ~VMEM-friendly.
    # The multi-pod compile-proof pass uses scan (fast compile; the
    # roofline table is single-pod only).
    defaults: dict = {"unroll_layers": unroll, "attn_unroll": unroll}
    if shape.kind == "train":
        defaults["attn_chunk"] = 2048
    elif shape.kind == "prefill":
        defaults["attn_chunk"] = 8192
    m_size = shard_rules.axis_size(mesh, "model")
    defaults["attn_head_axis"] = "model"
    defaults["batch_axes"] = tuple(shard_rules.batch_axes(mesh))
    if cfg.n_kv_heads % m_size != 0:
        defaults["attn_kv_expand"] = True
    overrides = {**defaults, **(cfg_overrides or {})}
    if cfg.moe and "moe_ep_axis" not in overrides:
        overrides["moe_ep_axis"] = "model"
    cfg = dataclasses.replace(cfg, **overrides)
    if shape.skip_reason:
        return Cell(arch_id, shape_id, shape.kind, None, None, None, None,
                    0.0, skip_reason=shape.skip_reason)

    flops = mf.lm_model_flops(cfg, shape)
    params_shape = _abstract(lambda k: tf.init_params(cfg, k), jax.random.key(0))
    p_specs = shard_rules.lm_param_specs(cfg, mesh)
    batch_spec = shard_rules.lm_batch_spec(mesh)
    inputs = lm_input_specs(shape, cfg)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        opt_shape = _abstract(lambda p: adamw_init(p, opt_cfg), params_shape)
        o_specs = shard_rules.zero_opt_specs(params_shape, p_specs, mesh)

        def train_step(state, batch):
            def loss(p):
                return tf.loss_fn(p, batch["tokens"], batch["labels"], cfg)
            l, g = jax.value_and_grad(loss)(state["params"])
            params, opt, met = adamw_update(state["params"], g, state["opt"],
                                            opt_cfg)
            return ({"params": params, "opt": opt}, {**met, "loss": l})

        state_shape = {"params": params_shape, "opt": opt_shape}
        state_specs = {"params": p_specs, "opt": o_specs}
        batch_specs = {k: batch_spec for k in inputs}
        met_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
        return Cell(arch_id, shape_id, "train", train_step,
                    (state_shape, inputs),
                    (_named(mesh, state_specs), _named(mesh, batch_specs)),
                    (_named(mesh, state_specs), _named(mesh, met_specs)),
                    flops, donate=(0,))

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            logits, cache = tf.prefill(params, batch["tokens"], cfg)
            return logits, cache["k"], cache["v"]

        cache_specs = shard_rules.lm_cache_specs(cfg, mesh, shape.global_batch)
        out_specs = (P(shard_rules.batch_axes(mesh), None),
                     cache_specs["k"], cache_specs["v"])
        in_specs = ({k: batch_spec for k in inputs})
        return Cell(arch_id, shape_id, "prefill", prefill_fn,
                    (params_shape, inputs),
                    (_named(mesh, p_specs), _named(mesh, in_specs)),
                    _named(mesh, out_specs), flops)

    # decode
    def decode_fn(params, batch):
        cache = {"k": batch["cache_k"], "v": batch["cache_v"],
                 "len": batch["cache_len"]}
        logits, cache = tf.decode_step(params, batch["tokens"], cache, cfg)
        return logits, cache["k"], cache["v"]

    cache_specs = shard_rules.lm_cache_specs(cfg, mesh, shape.global_batch)
    in_batch_specs = {
        "tokens": batch_spec,
        "cache_k": cache_specs["k"], "cache_v": cache_specs["v"],
        "cache_len": P(),
    }
    out_specs = (P(shard_rules.batch_axes(mesh), None),
                 cache_specs["k"], cache_specs["v"])
    return Cell(arch_id, shape_id, "decode", decode_fn,
                (params_shape, inputs),
                (_named(mesh, p_specs), _named(mesh, in_batch_specs)),
                _named(mesh, out_specs), flops, donate=(1,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

_GNN_MODULES = {
    "gcn-cora": m_gcn, "pna": m_pna, "dimenet": m_dimenet,
    "meshgraphnet": m_mgn,
}


def _gnn_config(arch_id: str, shape) -> Any:
    spec = get_arch(arch_id)
    if arch_id == "gcn-cora":
        return spec.make_config(d_in=shape.d_feat, n_classes=shape.n_classes)
    if arch_id == "pna":
        return spec.make_config(d_in=shape.d_feat, n_classes=shape.n_classes)
    if arch_id == "dimenet":
        return spec.make_config(d_in=shape.d_feat)
    if arch_id == "meshgraphnet":
        return spec.make_config(d_node_in=shape.d_feat)
    raise KeyError(arch_id)


def _gnn_cell(arch_id: str, shape_id: str, mesh: Mesh, *,
              opt_cfg: Optional[AdamWConfig] = None,
              edges_packed: bool = False,
              gnn_cfg_overrides: Optional[dict] = None) -> Cell:
    shape = GNN_SHAPES[shape_id]
    mod = _GNN_MODULES[arch_id]
    cfg = _gnn_config(arch_id, shape)
    if gnn_cfg_overrides:
        cfg = dataclasses.replace(cfg, **gnn_cfg_overrides)
    inputs = gnn_input_specs(shape, arch_id)
    cb_b = 0
    if edges_packed:
        # §Perf variant: the edge index arrives CompBin-packed (paper
        # eq. (1): b = ceil(log2 |V|/8) bytes/ID) and is decoded on device
        # right before the gather — (4-b)/4 less HBM traffic for the
        # hottest input of the SpMM regime.
        from repro.core.compbin import bytes_per_vertex
        cb_b = bytes_per_vertex(shape.n_nodes)
        E = inputs["edge_src"].shape[0]
        packed = jax.ShapeDtypeStruct((E * cb_b,), jnp.uint8)
        inputs = dict(inputs, edge_src=packed, edge_dst=packed)
    flops = mf.gnn_model_flops(arch_id, cfg, shape)

    params_shape = _abstract(lambda k: mod.init_params(cfg, k), jax.random.key(0))
    p_specs = _replicated_specs(params_shape)
    b_specs = shard_rules.gnn_specs(mesh, inputs)
    # static scalar entries (n_graphs) are not arrays — keep them python-side
    static = {k: v for k, v in inputs.items() if not hasattr(v, "shape")}

    opt_cfg = opt_cfg or AdamWConfig()
    opt_shape = _abstract(lambda p: adamw_init(p, opt_cfg), params_shape)
    o_specs = shard_rules.zero_opt_specs(params_shape, p_specs, mesh)

    loss_with_static = functools.partial(_gnn_loss, mod=mod, cfg=cfg,
                                         static=dict(static, n_graphs=shape.n_graphs),
                                         cb_b=cb_b)

    def train_step(state, batch):
        l, g = jax.value_and_grad(loss_with_static)(state["params"], batch)
        params, opt, met = adamw_update(state["params"], g, state["opt"], opt_cfg)
        return ({"params": params, "opt": opt}, {**met, "loss": l})

    arr_inputs = {k: v for k, v in inputs.items() if hasattr(v, "shape")}
    state_shape = {"params": params_shape, "opt": opt_shape}
    state_specs = {"params": p_specs, "opt": o_specs}
    met_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
    return Cell(arch_id, shape_id, "train", train_step,
                (state_shape, arr_inputs),
                (_named(mesh, state_specs), _named(mesh, b_specs)),
                (_named(mesh, state_specs), _named(mesh, met_specs)),
                flops, donate=(0,))


def _gnn_loss(params, batch, *, mod, cfg, static, cb_b=0):
    full = dict(batch)
    if cb_b:
        # decode the packed edge index (eq. 1: shifts+adds) on device;
        # padding slots decode to id (2^8b - 1) -> mapped back to -1
        from repro.kernels.compbin_decode.ref import compbin_decode_ref
        for key in ("edge_src", "edge_dst"):
            ids = compbin_decode_ref(full[key], cb_b)
            full[key] = jnp.where(ids == (1 << (8 * cb_b)) - 1, -1, ids)
    full.update(static)
    return mod.loss_fn(params, full, cfg)


# ---------------------------------------------------------------------------
# Recsys (DIN) cells
# ---------------------------------------------------------------------------

def _din_cell(arch_id: str, shape_id: str, mesh: Mesh, *,
              opt_cfg: Optional[AdamWConfig] = None) -> Cell:
    spec = get_arch(arch_id)
    shape = RECSYS_SHAPES[shape_id]
    cfg = spec.make_config()
    inputs = din_input_specs(shape, cfg)
    flops = mf.din_model_flops(cfg, shape)
    params_shape = _abstract(lambda k: m_din.init_params(cfg, k), jax.random.key(0))
    p_specs = shard_rules.din_specs(params_shape, mesh)
    b_specs = shard_rules.din_batch_specs(mesh, inputs)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        opt_shape = _abstract(lambda p: adamw_init(p, opt_cfg), params_shape)
        o_specs = shard_rules.zero_opt_specs(params_shape, p_specs, mesh)

        def train_step(state, batch):
            l, g = jax.value_and_grad(
                lambda p: m_din.loss_fn(p, batch, cfg))(state["params"])
            params, opt, met = adamw_update(state["params"], g, state["opt"],
                                            opt_cfg)
            return ({"params": params, "opt": opt}, {**met, "loss": l})

        state_shape = {"params": params_shape, "opt": opt_shape}
        state_specs = {"params": p_specs, "opt": o_specs}
        met_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
        return Cell(arch_id, shape_id, "train", train_step,
                    (state_shape, inputs),
                    (_named(mesh, state_specs), _named(mesh, b_specs)),
                    (_named(mesh, state_specs), _named(mesh, met_specs)),
                    flops, donate=(0,))

    if shape.kind == "retrieval":
        def retrieve(params, batch):
            return m_din.score_candidates(params, batch, cfg)

        out_spec = P(tuple(mesh.axis_names))
        return Cell(arch_id, shape_id, "retrieval", retrieve,
                    (params_shape, inputs),
                    (_named(mesh, p_specs), _named(mesh, b_specs)),
                    NamedSharding(mesh, out_spec), flops)

    def serve(params, batch):
        return m_din.forward(params, batch, cfg)

    out_spec = P(shard_rules.batch_axes(mesh))
    return Cell(arch_id, shape_id, "serve", serve,
                (params_shape, inputs),
                (_named(mesh, p_specs), _named(mesh, b_specs)),
                NamedSharding(mesh, out_spec), flops)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_id: str, mesh: Mesh, **kw) -> Cell:
    family = get_arch(arch_id).family
    if family == "lm":
        return _lm_cell(arch_id, shape_id, mesh, **kw)
    kw.pop("unroll", None)  # GNN/recsys models have no scan anywhere
    if family == "gnn":
        return _gnn_cell(arch_id, shape_id, mesh, **kw)
    return _din_cell(arch_id, shape_id, mesh, **kw)
