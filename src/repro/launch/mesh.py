"""Production mesh definition.

A function (not a module-level constant) so importing never touches jax
device state: the dry-run sets XLA_FLAGS *before* the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, *, model: int = 1):
    """Small CPU mesh for tests/examples (data x model over local devices)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
