from repro.query.engine import (DECODE_MODES,  # noqa: F401
                                NeighborQueryEngine, QueryFuture, QueryStats,
                                gather_rows)
from repro.query.window import CLOSE_REASONS, AdaptiveWindow  # noqa: F401
