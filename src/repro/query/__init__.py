from repro.query.engine import (DECODE_MODES,  # noqa: F401
                                NeighborQueryEngine, QueryFuture, QueryStats,
                                gather_rows, merge_query_stats)
from repro.query.loadgen import (LoadGenerator, LoadReport,  # noqa: F401
                                 default_cost_fn)
from repro.query.sharded import (RouterStats, ShardReplica,  # noqa: F401
                                 ShardedQueryService)
from repro.query.traversal import (TRAVERSAL_KINDS,  # noqa: F401
                                   AdmissionGate, TraversalError,
                                   TraversalRequest, TraversalResult,
                                   TraversalService, TraversalShed,
                                   TraversalStats)
from repro.query.window import CLOSE_REASONS, AdaptiveWindow  # noqa: F401
