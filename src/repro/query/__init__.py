from repro.query.engine import (NeighborQueryEngine,  # noqa: F401
                                QueryFuture, QueryStats, gather_rows)
