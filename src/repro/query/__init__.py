"""Random-access serving stack over CompBin + PG-Fuse.

One package, every serving layer: the batched
:class:`NeighborQueryEngine` (dedup -> coalesced gathers -> host/device
eq. (1) decode), the HBM-resident :class:`HotSetCache` tier above it
(decoded hub runs, degree-aware admission, trace-driven prefetch), the
:class:`TraversalService` (k-hop/BFS/path, one engine batch per
frontier, admission-gated), the scatter-gather
:class:`ShardedQueryService` (per-shard engines + mounts, replicated
routing), and the deterministic virtual-clock :class:`LoadGenerator`.
The end-to-end picture — including the three-tier cache hierarchy
(storage blocks / host-RAM PG-Fuse / HBM hot set) — lives in
``docs/architecture.md``.
"""

from repro.query.engine import (DECODE_MODES,  # noqa: F401
                                NeighborQueryEngine, QueryFuture, QueryStats,
                                gather_rows, merge_query_stats)
from repro.query.hotset import (BYTES_PER_EDGE, HotSetCache,  # noqa: F401
                                HotSetStats, merge_hotset_stats)
from repro.query.loadgen import (LoadGenerator, LoadReport,  # noqa: F401
                                 default_cost_fn)
from repro.query.sharded import (RouterStats, ShardReplica,  # noqa: F401
                                 ShardedQueryService)
from repro.query.traversal import (TRAVERSAL_KINDS,  # noqa: F401
                                   AdmissionGate, TraversalError,
                                   TraversalRequest, TraversalResult,
                                   TraversalService, TraversalShed,
                                   TraversalStats)
from repro.query.window import (CLOSE_REASONS,  # noqa: F401
                                AdaptiveWindow, close_reason_counts)
