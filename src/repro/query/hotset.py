"""HBM-resident hot-set cache of DECODED neighbor runs (cache tier 3).

The cache hierarchy below this module ends at host RAM: PG-Fuse keeps
*packed* CompBin bytes resident, so every query — even the thousandth
touch of the same hub vertex — still pays the eq. (1) decode (and, on
the device path, the H2D transfer) per touch.  The zipf traces the
serving benchmarks replay concentrate almost all traffic on a few hub
vertices ("Making Caches Work for Graph Analytics", PAPERS.md:
frequency-clustered hot sets), so the right third tier is obvious: keep
the *decoded* adjacency runs of exactly those hubs resident on the
accelerator, and stop paying decode for them at all.

:class:`HotSetCache` is that tier.  The
:class:`~repro.query.NeighborQueryEngine` consults it FIRST — before the
offsets-run gather — so a hot hit touches neither storage nor the
PG-Fuse block cache, and fills it from whatever each micro-batch decoded
anyway (fills are free: the decode already happened for the caller).

Three mechanisms, all deterministic and injectable-clock friendly:

* **degree-aware admission** (:func:`repro.core.policy.
  choose_hotset_admission`): an entry costs ``8 * degree`` bytes of the
  byte budget, so admission is by degree — the cold tail
  (``degree < min_degree``) BYPASSES the tier entirely (storing a
  3-neighbor run can only evict something hotter), and true hubs
  (``degree >= pin_degree``) are PINNED: the eviction sweep never takes
  them (up to ``pin_fraction`` of the budget), because a hub's
  re-reference is a certainty, not a bet.  Slim Graph (PAPERS.md)
  motivates the same asymmetry: spend the scarce tier on the vertices
  that dominate traffic, let the tail fall through to the cheaper
  tiers;
* **budgeted clock eviction**: the budget is bytes
  (``max_resident_bytes``), mirroring PG-Fuse's
  :class:`~repro.core.pgfuse.EngineShare` arithmetic one tier down;
  over budget, a second-chance sweep walks unpinned entries in
  insertion ring order, clearing reference bits (set on every hit)
  before evicting — a re-touched entry survives one full round of
  churn, exactly PG-Fuse's ``eviction="clock"`` semantics lifted to
  decoded runs;
* **trace-driven prefetch**: the cache observes every batch's unique
  vertex ids (the same per-batch fold that updates
  :class:`~repro.query.QueryStats`) in a bounded frequency window;
  vertices seen ``prefetch_min_hits``+ times that are not yet resident
  become prefetch candidates, and the engine fetches+decodes up to
  ``prefetch_batch`` of them AFTER answering each request batch — the
  fill cost lands outside any request's latency, and the next touch of
  a predicted hub is a hit.

Placement: ``place="device"`` keeps each admitted run as a JAX device
array (int32 — ids below ``2^31`` fit the same lanes the Pallas decode
kernel uses; :func:`~repro.core.policy.choose_hotset_admission` degrades
to host placement beyond that, mirroring
:func:`~repro.core.policy.choose_query_decode`'s constraint), converted
back to an independent int64 host array on every hit so hot answers are
byte-identical to the host/device/CSR decode paths — the differential
fuzzers assert exactly this.  ``place="host"`` keeps plain numpy arrays
(the fallback for huge graphs and for jax-free tests).

:class:`HotSetStats` accounts the tier (hits/misses/admissions/
bypasses/evictions/prefetch fills, resident bytes) and merges
associatively like :class:`~repro.query.QueryStats` — the sharded
service folds per-shard hot sets into fleet totals the same way.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.core import policy as _policy

#: byte cost charged to the budget per cached neighbor id (decoded
#: runs are int64 on host; the device copy is int32, but budgeting the
#: wider of the two keeps the budget an upper bound on either placement)
BYTES_PER_EDGE = 8

#: bounded frequency window for trace-driven prefetch: observations
#: older than this many distinct vertices decay away, so the predictor
#: tracks the RECENT hot head, not all-time popularity
HISTORY_WINDOW = 4096


@dataclasses.dataclass
class HotSetStats:
    """Per-cache accounting, shaped like the engine's ``QueryStats``
    (own lock, atomic :meth:`reset`, associative :meth:`merge`).

    Conservation invariants (asserted by ``tests/test_hotset.py``):

    * ``lookups == hits + misses`` (every consulted vertex is one or
      the other);
    * ``fills == admitted + bypassed + rejected`` (every decoded run
      offered to the tier is accounted exactly once).
    """

    lookups: int = 0          # unique vertices consulted (post-dedup)
    hits: int = 0             # answered from the resident tier
    misses: int = 0           # fell through to the storage gather
    fills: int = 0            # decoded runs offered to the tier
    admitted: int = 0         # fills stored (degree >= min_degree, fit)
    bypassed: int = 0         # fills below min_degree (cold tail)
    rejected: int = 0         # admissible fills the budget refused
    evicted: int = 0          # entries the clock sweep revoked
    pinned: int = 0           # CURRENT pinned entries (degree-pinned)
    prefetch_fills: int = 0   # admitted entries that arrived via prefetch
    prefetch_hits: int = 0    # prefetched entries later answered a lookup
                              # (counted once: the first hit clears the
                              # prefetched mark)
    prefetch_evicted: int = 0  # prefetched entries revoked before any hit
    hit_edges: int = 0        # neighbor ids served from the tier
    resident_bytes: int = 0   # CURRENT budget charge
    resident_entries: int = 0  # CURRENT resident vertices

    def __post_init__(self) -> None:
        # attribute, not a field: asdict()/replace() never touch it
        self._lock = threading.Lock()

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    @property
    def conserved(self) -> bool:
        return (self.lookups == self.hits + self.misses
                and self.fills
                == self.admitted + self.bypassed + self.rejected)

    @property
    def prefetch_hit_rate(self) -> float:
        """Fraction of prefetched entries that went on to answer a
        lookup — prefetch effectiveness (gated in the bench lane)."""
        return (self.prefetch_hits / self.prefetch_fills
                if self.prefetch_fills else 0.0)

    def as_dict(self) -> dict:
        with self._lock:
            d = dataclasses.asdict(self)
        d["hit_rate"] = (d["hits"] / d["lookups"] if d["lookups"] else 0.0)
        d["prefetch_hit_rate"] = (d["prefetch_hits"] / d["prefetch_fills"]
                                  if d["prefetch_fills"] else 0.0)
        return d

    def _snapshot(self) -> "HotSetStats":
        with self._lock:
            return dataclasses.replace(self)

    def merge(self, other: "HotSetStats") -> "HotSetStats":
        """Associative cross-cache aggregation (returns a NEW instance)
        — the hot-set sibling of :meth:`repro.query.QueryStats.merge`,
        for folding per-shard hot sets into fleet totals: every field
        (flow counters and resident gauges alike) sums, so per-shard
        sums equal service totals by construction and both conservation
        invariants survive (each is a sum of terms that satisfy them).
        """
        a, b = self._snapshot(), other._snapshot()
        out = HotSetStats()
        for f in dataclasses.fields(out):
            setattr(out, f.name, getattr(a, f.name) + getattr(b, f.name))
        return out

    def reset(self) -> "HotSetStats":
        """Zero the FLOW counters atomically; returns the pre-reset
        snapshot.  Resident gauges (``resident_bytes`` /
        ``resident_entries`` / ``pinned``) describe what is still
        cached, so they survive the cut — the epoch boundary changes
        what has been counted, not what is resident."""
        with self._lock:
            snap = dataclasses.replace(self)
            keep = ("resident_bytes", "resident_entries", "pinned")
            for f in dataclasses.fields(self):
                if f.name not in keep:
                    setattr(self, f.name, 0)
        return snap


def merge_hotset_stats(stats) -> HotSetStats:
    """Fold any number of caches' :class:`HotSetStats` into one
    aggregate (associative; mirrors
    :func:`repro.query.engine.merge_query_stats`)."""
    out = HotSetStats()
    for s in stats:
        out = out.merge(s)
    return out


@dataclasses.dataclass
class _Entry:
    """One resident decoded run."""

    store: object        # int32 device array or int64 numpy array
    degree: int
    nbytes: int          # budget charge (BYTES_PER_EDGE * degree)
    pinned: bool
    ref: bool = True     # second-chance bit, set on every hit
    prefetched: bool = False  # arrived via prefetch, no lookup hit yet
                              # (outcome lands in prefetch_hits or
                              # prefetch_evicted, exactly once)


class HotSetCache:
    """Device-resident cache of decoded neighbor runs for hub vertices.

    Built from a :class:`repro.core.policy.HotSetPlan` (or the
    equivalent keyword arguments)::

        plan = policy.choose_hotset_admission(
            n_vertices, n_edges, budget_bytes=1 << 22)
        hot = HotSetCache(plan=plan)
        engine = NeighborQueryEngine(graph, hotset=hot)

    Thread-safe: the engine's per-batch ``lookup`` / ``fill`` /
    ``observe`` calls and any concurrent ``stats`` reads serialize on
    one internal lock.  All decisions (admission, eviction order,
    prefetch candidates) are deterministic functions of the call
    sequence — no wall clock, no randomness — so virtual-clock tests
    replay them exactly.
    """

    def __init__(self, *, plan: Optional["_policy.HotSetPlan"] = None,
                 budget_bytes: Optional[int] = None,
                 min_degree: Optional[int] = None,
                 pin_degree: Optional[int] = None,
                 pin_fraction: Optional[float] = None,
                 place: Optional[str] = None,
                 prefetch_min_hits: Optional[int] = None,
                 prefetch_batch: Optional[int] = None):
        if plan is None:
            if budget_bytes is None:
                raise ValueError("HotSetCache needs plan= or budget_bytes=")
            plan = _policy.HotSetPlan(
                budget_bytes=int(budget_bytes),
                min_degree=2 if min_degree is None else int(min_degree),
                pin_degree=(1 << 62) if pin_degree is None
                else int(pin_degree),
                pin_fraction=0.5 if pin_fraction is None else pin_fraction,
                place=place or "host",
                prefetch_min_hits=(3 if prefetch_min_hits is None
                                   else int(prefetch_min_hits)),
                prefetch_batch=(8 if prefetch_batch is None
                                else int(prefetch_batch)),
                reason="explicit kwargs")
        else:
            # explicit kwargs override plan fields
            override = dict(budget_bytes=budget_bytes, min_degree=min_degree,
                            pin_degree=pin_degree, pin_fraction=pin_fraction,
                            place=place, prefetch_min_hits=prefetch_min_hits,
                            prefetch_batch=prefetch_batch)
            fields = {k: v for k, v in override.items() if v is not None}
            if fields:
                plan = dataclasses.replace(plan, **fields)
        if plan.budget_bytes < 1:
            raise ValueError(f"budget_bytes must be >= 1, "
                             f"got {plan.budget_bytes}")
        if plan.place not in ("device", "host"):
            raise ValueError(f"place must be 'device' or 'host', "
                             f"got {plan.place!r}")
        if not 0.0 <= plan.pin_fraction <= 1.0:
            raise ValueError(f"pin_fraction must be in [0, 1], "
                             f"got {plan.pin_fraction}")
        self.plan = plan
        self.stats = HotSetStats()
        self._lock = threading.Lock()
        self._entries: Dict[int, _Entry] = {}   # insertion order = ring
        self._resident_bytes = 0
        self._pinned_bytes = 0
        self._hand = 0                           # clock hand: ring index
        # trace history for prefetch: bounded per-vertex hit counts over
        # the last HISTORY_WINDOW observations (FIFO decay)
        self._freq: Dict[int, int] = {}
        self._history: List[int] = []
        # candidates already handed out: a prefetched vertex whose run
        # turned out to be cold tail (bypassed) must not be re-fetched
        # every batch; an ADMITTED fill clears the mark, so a later
        # eviction leaves the vertex predictable again
        self._attempted: set = set()

    # -- properties --------------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident_bytes

    @property
    def resident_vertices(self) -> np.ndarray:
        """Sorted ids of currently resident vertices (tests/benches)."""
        with self._lock:
            return np.sort(np.fromiter(self._entries, np.int64,
                                       len(self._entries)))

    def is_pinned(self, v: int) -> bool:
        with self._lock:
            e = self._entries.get(int(v))
            return e is not None and e.pinned

    # -- placement ---------------------------------------------------------
    def _place(self, decoded: np.ndarray):
        """Ship one decoded run to its resident representation."""
        if self.plan.place == "device":
            import jax
            # ids fit int32 by the plan's lane constraint; the device
            # copy is the HBM-resident truth, re-widened on every hit
            return jax.device_put(decoded.astype(np.int32))
        return decoded.astype(np.int64, copy=True)

    @staticmethod
    def _fetch(entry: _Entry) -> np.ndarray:
        """An independent int64 host array from the resident store —
        byte-identical to what the decode paths hand out."""
        return np.asarray(entry.store).astype(np.int64)

    # -- the tier API the engine drives ------------------------------------
    def lookup(self, uniq: np.ndarray) -> Dict[int, np.ndarray]:
        """Resident decoded runs for the (unique) ids in ``uniq``.

        Returns ``{vertex_id: int64 ndarray}`` for every hit; ids absent
        from the dict fell through to the storage tier.  Hits set the
        entry's reference bit (second chance) and fold into the
        frequency history alongside misses, so the prefetch predictor
        sees the full trace.
        """
        out: Dict[int, np.ndarray] = {}
        with self._lock:
            st = self.stats
            for v in uniq:
                v = int(v)
                e = self._entries.get(v)
                with st._lock:
                    st.lookups += 1
                    if e is None:
                        st.misses += 1
                        continue
                    st.hits += 1
                    st.hit_edges += e.degree
                    if e.prefetched:
                        # the prefetch paid off; count the outcome once
                        st.prefetch_hits += 1
                e.prefetched = False
                e.ref = True
                out[v] = self._fetch(e)
        return out

    def observe(self, uniq: np.ndarray) -> None:
        """Fold one batch's unique ids into the bounded frequency
        window (the prefetch predictor's input)."""
        with self._lock:
            for v in uniq:
                v = int(v)
                self._freq[v] = self._freq.get(v, 0) + 1
                self._history.append(v)
            while len(self._history) > HISTORY_WINDOW:
                old = self._history.pop(0)
                n = self._freq.get(old, 0) - 1
                if n <= 0:
                    self._freq.pop(old, None)
                else:
                    self._freq[old] = n

    def fill(self, v: int, decoded: np.ndarray, *,
             prefetch: bool = False) -> bool:
        """Offer one decoded run to the tier; returns True if admitted.

        Admission is degree-aware: ``degree < min_degree`` bypasses
        (the cold tail never competes for the budget), ``degree >=
        pin_degree`` pins (up to ``pin_fraction`` of the budget —
        beyond that a hub is admitted unpinned).  Admitting over budget
        triggers the clock sweep; an admissible run the sweep cannot
        make room for (everything else pinned or fresher) is rejected.
        """
        v = int(v)
        degree = int(decoded.size)
        nbytes = BYTES_PER_EDGE * degree
        st = self.stats
        with self._lock:
            with st._lock:
                st.fills += 1
            if v in self._entries:
                # already resident (a racing fill); refresh the ref bit
                self._entries[v].ref = True
                with st._lock:
                    st.admitted += 1
                return True
            if degree < self.plan.min_degree:
                with st._lock:
                    st.bypassed += 1
                return False
            if nbytes > self.plan.budget_bytes:
                with st._lock:
                    st.rejected += 1
                return False
            pinned = (degree >= self.plan.pin_degree
                      and self._pinned_bytes + nbytes
                      <= self.plan.pin_fraction * self.plan.budget_bytes)
            if not self._make_room(nbytes):
                with st._lock:
                    st.rejected += 1
                return False
            self._entries[v] = _Entry(self._place(decoded), degree,
                                      nbytes, pinned, prefetched=prefetch)
            self._resident_bytes += nbytes
            self._attempted.discard(v)
            if pinned:
                self._pinned_bytes += nbytes
            with st._lock:
                st.admitted += 1
                if prefetch:
                    st.prefetch_fills += 1
                st.resident_bytes = self._resident_bytes
                st.resident_entries = len(self._entries)
                st.pinned += pinned
        return True

    def _make_room(self, nbytes: int) -> bool:
        """Clock sweep until ``nbytes`` fits (caller holds the lock).

        Second chance over UNPINNED entries in insertion ring order,
        resuming at the saved hand: the first pass over a referenced
        entry clears its bit, the second evicts.  Returns False when no
        unpinned entry remains to take and the budget still does not
        fit — pinned hubs are never the victims.
        """
        if self._resident_bytes + nbytes <= self.plan.budget_bytes:
            return True
        st = self.stats
        # two full rounds bound the sweep: round one may only clear bits
        max_steps = 2 * len(self._entries) + 2
        steps = 0
        while (self._resident_bytes + nbytes > self.plan.budget_bytes
               and steps < max_steps):
            ring = [u for u, e in self._entries.items() if not e.pinned]
            if not ring:
                return False
            victim = None
            for _ in range(2 * len(ring)):
                u = ring[self._hand % len(ring)]
                self._hand += 1
                steps += 1
                e = self._entries[u]
                if e.ref:
                    e.ref = False     # second chance
                    continue
                victim = u
                break
            if victim is None:
                return False
            e = self._entries.pop(victim)
            self._resident_bytes -= e.nbytes
            with st._lock:
                st.evicted += 1
                if e.prefetched:
                    # revoked before any lookup hit: the prefetch was
                    # wasted budget (the other prefetch outcome)
                    st.prefetch_evicted += 1
                st.resident_bytes = self._resident_bytes
                st.resident_entries = len(self._entries)
        return self._resident_bytes + nbytes <= self.plan.budget_bytes

    # -- trace-driven prefetch ---------------------------------------------
    def prefetch_candidates(self) -> np.ndarray:
        """Up to ``prefetch_batch`` predicted-hot vertex ids to fetch
        next: seen at least ``prefetch_min_hits`` times in the recent
        window, not resident, hottest (then smallest id) first.  The
        engine decodes them through its normal gather core after each
        request batch and offers the runs back via
        ``fill(..., prefetch=True)``.
        """
        with self._lock:
            cand = [(-n, v) for v, n in self._freq.items()
                    if n >= self.plan.prefetch_min_hits
                    and v not in self._entries
                    and v not in self._attempted]
            cand.sort()
            take = [v for _, v in cand[:self.plan.prefetch_batch]]
            self._attempted.update(take)
        return np.asarray(take, dtype=np.int64)

    def clear(self) -> None:
        """Drop every entry (budget returns to zero; stats keep their
        flow history, gauges zero)."""
        with self._lock:
            self._entries.clear()
            self._resident_bytes = 0
            self._pinned_bytes = 0
            self._hand = 0
            self._attempted.clear()
            with self.stats._lock:
                self.stats.resident_bytes = 0
                self.stats.resident_entries = 0
                self.stats.pinned = 0
