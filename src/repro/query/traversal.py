"""Multi-hop traversal service over the neighbor-query engine.

The engine answers *one* frontier; real graph serving (swh-graph's
visit API, the BFS/PageRank evaluations ParaGrapher itself is measured
with) asks *traversals*: k-hop neighborhoods, bounded BFS visits and
shortest paths.  :class:`TraversalService` is that layer, built so the
engine's whole machinery keeps paying at every hop:

* each frontier expands as **ONE batched engine call**
  (:meth:`~repro.query.NeighborQueryEngine.neighbors_batch_ragged`) —
  dedup, merged range reads, span prefetch and the per-batch
  host/device decode placement (``decode="auto"`` routes large
  frontiers to the Pallas kernel) all apply to the frontier as a unit,
  never per vertex; when the engine carries the HBM-resident hot-set
  tier (:class:`~repro.query.hotset.HotSetCache`), the frontier's hub
  vertices are answered from resident decoded runs and **skip the
  storage gather entirely** — only the frontier's cold remainder
  reaches PG-Fuse, and answers stay byte-identical either way;
* every request carries budgets — ``max_edges`` (scanned edge budget)
  and ``max_vertices`` (visit bound) — with semantics pinned precisely
  enough that a pure in-memory CSR reference reproduces the results
  bit for bit (the differential property suite asserts it);
* an **admission gate** sized by
  :func:`repro.core.policy.choose_admission` sheds excess load
  *immediately* (fast-fail :class:`TraversalShed`), so overload shows
  up as an explicit shed rate while every admitted request keeps its
  latency SLO — the deterministic closed-loop load generator
  (:mod:`repro.query.loadgen`) pins both properties on a virtual
  clock;
* per-request accounting folds into :class:`TraversalStats`, shaped
  like the engine's :class:`~repro.query.QueryStats` (injectable-clock
  latency window, atomic :meth:`~TraversalStats.reset`, conservation
  invariants: ``admitted + shed == submitted`` and
  ``completed + failed + inflight == admitted``).

Traversal semantics (shared verbatim by the in-memory reference)
----------------------------------------------------------------

Seeds are validated against ``[0, n_vertices)`` (a bad seed is a clean
per-request :class:`TraversalError`), then deduplicated and sorted —
depth 0 of the visit.  Each hop expands the current frontier in one
engine batch; newly discovered vertices (ascending id) join the visit
at depth ``hop``.  Checked *before* each expansion, in order:

1. ``found`` (path requests) — the target entered the visit;
2. empty frontier — natural exhaustion;
3. ``hop == k`` — depth bound reached (``k=0`` visits only the seeds);
4. ``edges_scanned > max_edges`` — the PREVIOUS hop crossed the edge
   budget: its results are kept, the traversal stops ``truncated``;
5. ``len(visited) >= max_vertices`` — visit bound reached,
   ``truncated``.

``max_vertices`` also trims within a hop: newly discovered vertices
are kept in ascending order up to the remaining capacity (dropping any
marks the result ``truncated``).  Shortest-path parents are defined
order-independently: the parent of a newly discovered vertex is the
**smallest-id frontier vertex adjacent to it** (equal to the first
occurrence in the frontier-major expansion, since frontiers are
sorted), so host decode, device decode and the reference agree on the
exact path, not just its length.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.core import policy as _policy
from repro.obs.metrics import LatencyHistogram
from repro.obs.trace import NULL_TRACER

#: default per-request scanned-edge budget (generous: bounded work per
#: request is the contract, not a tight cap)
DEFAULT_EDGE_BUDGET = 1 << 20

TRAVERSAL_KINDS = ("khop", "bfs", "path")


class TraversalError(ValueError):
    """Per-request rejection (bad seeds/arguments) — never engine state."""


class TraversalShed(RuntimeError):
    """Request refused by the admission gate (overload fast-fail)."""


@dataclasses.dataclass
class TraversalRequest:
    """One traversal request.

    ``kind`` is ``"khop"`` (neighborhood to depth ``k``), ``"bfs"``
    (visit bounded by ``max_vertices``/``max_edges``; ``k`` optionally
    bounds depth) or ``"path"`` (BFS shortest path seeds -> ``target``).
    """

    kind: str
    seeds: np.ndarray
    k: Optional[int] = None
    target: Optional[int] = None
    max_edges: int = DEFAULT_EDGE_BUDGET
    max_vertices: Optional[int] = None

    def __post_init__(self) -> None:
        if self.kind not in TRAVERSAL_KINDS:
            raise TraversalError(
                f"kind must be one of {TRAVERSAL_KINDS}, got {self.kind!r}")
        self.seeds = np.asarray(self.seeds, dtype=np.int64).ravel()
        if self.max_edges < 0:
            raise TraversalError(f"max_edges must be >= 0, "
                                 f"got {self.max_edges}")
        if self.k is not None and self.k < 0:
            raise TraversalError(f"k must be >= 0, got {self.k}")
        if self.max_vertices is not None and self.max_vertices < 1:
            raise TraversalError(f"max_vertices must be >= 1, "
                                 f"got {self.max_vertices}")
        if self.kind == "path":
            if self.target is None:
                raise TraversalError("path requests need target=")
            if self.seeds.size != 1:
                raise TraversalError("path requests take exactly one seed")
        if self.kind == "khop" and self.k is None:
            raise TraversalError("khop requests need k=")


@dataclasses.dataclass
class TraversalResult:
    """One traversal's answer + its per-request accounting."""

    kind: str
    vertices: np.ndarray        # visit in BFS order (hop-major, ascending
                                # id within each hop); int64
    depths: np.ndarray          # hop each vertex was discovered at; int64
    found: bool                 # path requests: target reached
    path: Optional[np.ndarray]  # path requests: seed..target inclusive
    truncated: bool             # a budget stopped the traversal early
    hops: int                   # frontier expansions executed
    edges_scanned: int          # neighbor slots read across all hops
    latency_s: float = 0.0      # service-clock request latency

    @property
    def n_visited(self) -> int:
        return int(self.vertices.size)


@dataclasses.dataclass
class TraversalStats:
    """Service accounting, shaped like the engine's ``QueryStats``
    (bounded latency histogram over the injectable clock, atomic
    :meth:`reset` returning the pre-reset snapshot).

    Conservation invariants — asserted by the load/soak suite, held
    under concurrent submission because every mutation happens under
    one lock:

    * ``submitted == admitted + shed``  (the gate loses nothing);
    * ``admitted == completed + failed + inflight``.
    """

    submitted: int = 0        # requests offered to the gate
    admitted: int = 0         # requests past the gate
    shed: int = 0             # requests refused by the gate
    completed: int = 0        # admitted requests answered
    failed: int = 0           # admitted requests erroring (storage etc.)
    inflight: int = 0         # admitted, not yet completed/failed
    requests_by_kind: dict = dataclasses.field(default_factory=dict)
    frontier_batches: int = 0  # engine calls (== hops across requests)
    edges_scanned: int = 0
    vertices_visited: int = 0
    truncated: int = 0         # completed requests a budget cut short
    latencies: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    def __post_init__(self) -> None:
        # the lock is deliberately an attribute, not a field: asdict()
        # and replace() must never try to serialize or copy it
        self._lock = threading.Lock()

    @property
    def conserved(self) -> bool:
        return (self.submitted == self.admitted + self.shed
                and self.admitted
                == self.completed + self.failed + self.inflight)

    @property
    def shed_rate(self) -> float:
        return self.shed / self.submitted if self.submitted else 0.0

    def latency_quantile(self, q: float) -> float:
        with self._lock:
            return self.latencies.quantile(q)

    @property
    def p50_s(self) -> float:
        return self.latency_quantile(0.50)

    @property
    def p99_s(self) -> float:
        return self.latency_quantile(0.99)

    def as_dict(self) -> dict:
        with self._lock:
            d = {f.name: getattr(self, f.name)
                 for f in dataclasses.fields(self)}
            hist = d.pop("latencies")
            d["requests_by_kind"] = dict(d["requests_by_kind"])
            d["n_latencies"] = hist.n
            d["p50_s"] = hist.quantile(0.50)
            d["p99_s"] = hist.quantile(0.99)
        d["shed_rate"] = (d["shed"] / d["submitted"]
                          if d["submitted"] else 0.0)
        return d

    def _snapshot(self) -> "TraversalStats":
        """A consistent copy taken under the stats lock."""
        with self._lock:
            return dataclasses.replace(
                self, latencies=self.latencies.copy(),
                requests_by_kind=dict(self.requests_by_kind))

    def merge(self, other: "TraversalStats") -> "TraversalStats":
        """Associative cross-service aggregation (returns a NEW
        instance) — the traversal-side sibling of
        :meth:`repro.query.QueryStats.merge`, for folding several
        services' (or shards') accounting into fleet totals: counters
        sum, ``requests_by_kind`` sums key-wise, latency histograms
        merge bucket-wise.  Each operand is snapshotted under its
        own lock, so merging races cleanly with concurrent
        admit/complete folds and with :meth:`reset`; both conservation
        invariants (``submitted == admitted + shed``,
        ``admitted == completed + failed + inflight``) survive the
        merge because every term is a sum of terms that satisfy them.
        """
        a, b = self._snapshot(), other._snapshot()
        out = TraversalStats()
        for f in dataclasses.fields(out):
            if f.name in ("latencies", "requests_by_kind"):
                continue
            setattr(out, f.name, getattr(a, f.name) + getattr(b, f.name))
        for src in (a.requests_by_kind, b.requests_by_kind):
            for k, v in src.items():
                out.requests_by_kind[k] = out.requests_by_kind.get(k, 0) + v
        out.latencies = a.latencies.merge(b.latencies)
        return out

    def reset(self) -> "TraversalStats":
        """Zero in place ATOMICALLY; returns the pre-reset snapshot.

        In-flight requests survive a reset: ``inflight`` carries over
        (their eventual completion must still balance), everything else
        zeroes — the snapshot absorbs the finished history, the live
        object keeps only what is still outstanding, and conservation
        holds on BOTH sides of the cut.
        """
        with self._lock:
            snap = dataclasses.replace(
                self, latencies=self.latencies.copy(),
                requests_by_kind=dict(self.requests_by_kind))
            live = self.inflight
            for f in dataclasses.fields(self):
                cur = getattr(self, f.name)
                setattr(self, f.name,
                        LatencyHistogram()
                        if isinstance(cur, LatencyHistogram)
                        else [] if isinstance(cur, list)
                        else {} if isinstance(cur, dict) else 0)
            # the outstanding requests were admitted in THIS epoch now:
            # count them as submitted+admitted so the live invariant
            # (admitted == completed + failed + inflight) keeps holding
            self.inflight = live
            self.admitted = live
            self.submitted = live
            snap.inflight -= live
            snap.admitted -= live
            snap.submitted -= live
        return snap


class AdmissionGate:
    """Token gate over an :class:`repro.core.policy.AdmissionPlan`.

    Thread-safe; ``try_admit`` takes both tokens (request slot + edge
    budget) or neither.  A ``plan=None`` gate admits everything.
    """

    def __init__(self, plan: Optional["_policy.AdmissionPlan"]):
        self.plan = plan
        self._lock = threading.Lock()
        self.inflight = 0
        self.edges_inflight = 0

    def try_admit(self, edge_budget: int) -> bool:
        with self._lock:
            if self.plan is not None:
                if (self.inflight + 1 > self.plan.max_inflight
                        or self.edges_inflight + edge_budget
                        > self.plan.max_edges_inflight):
                    return False
            self.inflight += 1
            self.edges_inflight += edge_budget
            return True

    def release(self, edge_budget: int) -> None:
        with self._lock:
            self.inflight -= 1
            self.edges_inflight -= edge_budget
            assert self.inflight >= 0 and self.edges_inflight >= 0


class TraversalService:
    """Traversal API over a pluggable frontier-expansion backend.

    ``engine`` is anything exposing the engine's query surface —
    ``neighbors_batch_ragged(vertices) -> (offsets, ids)``,
    ``n_vertices``, ``stats`` (a :class:`~repro.query.QueryStats`) and
    ``_clock``: a single :class:`~repro.query.NeighborQueryEngine`, or
    a :class:`~repro.query.sharded.ShardedQueryService` that
    scatter-gathers each frontier across per-shard engines (at most one
    engine batch per shard per hop, results merged back into the same
    pinned order, so every traversal below is bit-identical regardless
    of the shard count behind it).

    Synchronous use::

        svc = TraversalService(engine, admission=plan)
        res = svc.khop([17, 404], k=2)
        res = svc.bfs_visit([0], max_vertices=1000)
        res = svc.shortest_path(0, 999)

    Concurrent serving: :meth:`submit` runs the request on a bounded
    executor (``plan.servers`` workers) after passing the gate in the
    CALLER's thread — shedding is immediate, never queued.  The
    deterministic load generator (:mod:`repro.query.loadgen`) instead
    drives the :meth:`admit`/:meth:`perform`/:meth:`complete` triplet
    directly on a virtual clock.

    ``clock`` defaults to the engine's (virtual in benches/tests), so
    ``TraversalStats`` latencies and ``QueryStats`` latencies are
    measured on the same axis.
    """

    def __init__(self, engine, *,
                 admission: Optional["_policy.AdmissionPlan"] = None,
                 default_max_edges: int = DEFAULT_EDGE_BUDGET,
                 clock: Optional[Callable[[], float]] = None,
                 tracer=None):
        self._engine = engine
        self.gate = AdmissionGate(admission)
        self.default_max_edges = int(default_max_edges)
        self._clock = clock if clock is not None else engine._clock
        # share the backend's tracer by default so the request root
        # span and the engine's gather spans land in ONE trace
        self._tracer = (tracer if tracer is not None
                        else getattr(engine, "_tracer", NULL_TRACER))
        self.stats = TraversalStats()
        self._executor = None
        self._executor_lock = threading.Lock()
        self._closed = False

    # -- properties --------------------------------------------------------
    @property
    def engine(self):
        """The frontier-expansion backend (a
        :class:`NeighborQueryEngine` or sharded equivalent)."""
        return self._engine

    @property
    def n_vertices(self) -> int:
        return self._engine.n_vertices

    @property
    def plan(self) -> Optional["_policy.AdmissionPlan"]:
        return self.gate.plan

    # -- the BFS core ------------------------------------------------------
    def _validate_seeds(self, req: TraversalRequest) -> np.ndarray:
        seeds = req.seeds
        if seeds.size == 0:
            raise TraversalError("traversal needs at least one seed")
        if seeds.min() < 0 or seeds.max() >= self.n_vertices:
            raise TraversalError(
                f"seed ids must be in [0, {self.n_vertices}); got "
                f"[{seeds.min()}, {seeds.max()}]")
        if req.kind == "path" and not (
                0 <= int(req.target) < self.n_vertices):
            raise TraversalError(
                f"target must be in [0, {self.n_vertices}); "
                f"got {req.target}")
        return np.unique(seeds)

    def _traverse(self, req: TraversalRequest) -> TraversalResult:
        """The shared frontier loop (semantics in the module docstring);
        budgets and parent choice are defined so a pure CSR reference
        reproduces every field bit for bit."""
        seeds = self._validate_seeds(req)
        k = req.k
        max_vertices = (req.max_vertices if req.max_vertices is not None
                        else self.n_vertices)
        target = int(req.target) if req.kind == "path" else None
        hop_vertices: List[np.ndarray] = [seeds]
        hop_depths: List[np.ndarray] = [np.zeros(seeds.size, np.int64)]
        visited = seeds                   # sorted invariant maintained
        # parent[i] belongs to discovered[i] (path requests only)
        parent_of: dict = {}
        frontier = seeds
        # seeds beyond the visit bound are trimmed like any other hop
        truncated = False
        if seeds.size > max_vertices:
            frontier = visited = seeds[:max_vertices]
            hop_vertices[0] = frontier
            hop_depths[0] = np.zeros(frontier.size, np.int64)
            truncated = True
        found = target is not None and \
            bool(np.isin(target, frontier).item())
        edges_scanned = 0
        hops = 0
        while True:
            if found or frontier.size == 0:
                break
            if k is not None and hops == k:
                break
            if edges_scanned > req.max_edges:
                truncated = True
                break
            if visited.size >= max_vertices:
                truncated = True
                break
            # ONE engine batch per frontier: dedup, merged range reads,
            # span prefetch, per-batch host/device decode placement
            offsets, flat = self._engine.neighbors_batch_ragged(frontier)
            hops += 1
            edges_scanned += int(flat.size)
            if flat.size:
                uniq, first = np.unique(flat, return_index=True)
                fresh = ~np.isin(uniq, visited, assume_unique=True)
                new, first = uniq[fresh], first[fresh]
            else:
                new = np.zeros(0, np.int64)
                first = np.zeros(0, np.int64)
            keep = max_vertices - int(visited.size)
            if new.size > keep:
                new, first = new[:keep], first[:keep]
                truncated = True
            if target is not None and new.size:
                # parent := smallest-id frontier vertex adjacent to the
                # discovery — frontiers are sorted, so the flat stream's
                # first occurrence IS that vertex
                expand_src = np.repeat(frontier, np.diff(offsets))
                for v, j in zip(new, expand_src[first]):
                    parent_of[int(v)] = int(j)
                if bool(np.isin(target, new).item()):
                    found = True
            hop_vertices.append(new)
            hop_depths.append(np.full(new.size, hops, np.int64))
            visited = np.union1d(visited, new)
            frontier = new
        path = None
        if req.kind == "path" and found:
            chain = [target]
            while chain[-1] in parent_of:
                chain.append(parent_of[chain[-1]])
            path = np.asarray(chain[::-1], dtype=np.int64)
        return TraversalResult(
            kind=req.kind,
            vertices=np.concatenate(hop_vertices),
            depths=np.concatenate(hop_depths),
            found=found, path=path, truncated=truncated,
            hops=hops, edges_scanned=edges_scanned)

    # -- admission / accounting primitives ---------------------------------
    # the load generator drives these directly (admission and stats on a
    # virtual timeline); the sync + async paths compose them below
    def admit(self, req: TraversalRequest) -> bool:
        """Offer ``req`` to the gate; accounts submitted/admitted/shed."""
        if self._closed:
            raise ValueError("request on closed service")
        ok = self.gate.try_admit(req.max_edges)
        with self.stats._lock:
            self.stats.submitted += 1
            if ok:
                self.stats.admitted += 1
                self.stats.inflight += 1
            else:
                self.stats.shed += 1
        return ok

    def perform(self, req: TraversalRequest) -> TraversalResult:
        """Run an ADMITTED request's traversal (no release, no latency
        fold — the caller owns the request lifecycle)."""
        try:
            res = self._traverse(req)
        except BaseException:
            self.fail(req)
            raise
        with self.stats._lock:
            st = self.stats
            st.requests_by_kind[req.kind] = \
                st.requests_by_kind.get(req.kind, 0) + 1
            st.frontier_batches += res.hops
            st.edges_scanned += res.edges_scanned
            st.vertices_visited += res.n_visited
            st.truncated += res.truncated
        return res

    def complete(self, req: TraversalRequest, latency_s: float) -> None:
        """Release the gate + fold the request latency into the stats."""
        self.gate.release(req.max_edges)
        with self.stats._lock:
            st = self.stats
            st.completed += 1
            st.inflight -= 1
            st.latencies.add(float(latency_s))

    def fail(self, req: TraversalRequest) -> None:
        """Release an admitted request that errored (clean per-request
        failure: gate tokens return, siblings are untouched)."""
        self.gate.release(req.max_edges)
        with self.stats._lock:
            self.stats.failed += 1
            self.stats.inflight -= 1

    # -- the synchronous path ----------------------------------------------
    def request(self, req: TraversalRequest) -> TraversalResult:
        """Admission-gated synchronous traversal.

        The request ROOT span: every engine gather span, PG-Fuse read
        span and decode span this request causes nests under it, so one
        sampled trace attributes the request's clock time across tiers
        (``repro.obs.report.attribution``).  A shed is a zero-width
        root with one ``shed`` event — sheds stay visible in traces and
        their event count reconciles with ``TraversalStats.shed``.
        """
        with self._tracer.span("traversal.request", tier="request",
                               kind=req.kind) as rsp:
            if not self.admit(req):
                rsp.event("shed", kind=req.kind)
                raise TraversalShed(
                    f"admission gate full "
                    f"({self.gate.inflight} in flight, "
                    f"{self.gate.edges_inflight} edge budget)")
            t0 = self._clock()
            res = self.perform(req)      # fail() runs inside on error
            res.latency_s = self._clock() - t0
            self.complete(req, res.latency_s)
            rsp.set(hops=res.hops, edges=res.edges_scanned,
                    truncated=bool(res.truncated))
            return res

    def khop(self, seeds, k: int, *, max_edges: Optional[int] = None,
             max_vertices: Optional[int] = None) -> TraversalResult:
        """All vertices within ``k`` hops of ``seeds`` (+ depths)."""
        return self.request(TraversalRequest(
            "khop", seeds, k=k,
            max_edges=(max_edges if max_edges is not None
                       else self.default_max_edges),
            max_vertices=max_vertices))

    def bfs_visit(self, seeds, *, max_vertices: Optional[int] = None,
                  max_edges: Optional[int] = None,
                  max_depth: Optional[int] = None) -> TraversalResult:
        """Bounded BFS visit in deterministic order (hop-major,
        ascending id within a hop)."""
        return self.request(TraversalRequest(
            "bfs", seeds, k=max_depth,
            max_edges=(max_edges if max_edges is not None
                       else self.default_max_edges),
            max_vertices=max_vertices))

    def shortest_path(self, source: int, target: int, *,
                      max_edges: Optional[int] = None,
                      max_depth: Optional[int] = None) -> TraversalResult:
        """BFS shortest path; deterministic parents (smallest-id
        adjacent frontier vertex), ``found=False`` when unreachable
        within the budgets."""
        return self.request(TraversalRequest(
            "path", [int(source)], k=max_depth, target=int(target),
            max_edges=(max_edges if max_edges is not None
                       else self.default_max_edges)))

    # -- the async path ----------------------------------------------------
    def submit(self, req: TraversalRequest):
        """Gate in the caller's thread (immediate :class:`TraversalShed`
        on overload), execute on the service's bounded executor; returns
        a ``concurrent.futures.Future`` of :class:`TraversalResult`."""
        from concurrent.futures import ThreadPoolExecutor

        if not self.admit(req):
            # zero-width root span so async sheds are trace-visible too
            with self._tracer.span("traversal.request", tier="request",
                                   kind=req.kind) as rsp:
                rsp.event("shed", kind=req.kind)
            raise TraversalShed("admission gate full")
        with self._executor_lock:
            if self._executor is None:
                workers = self.plan.servers if self.plan else 4
                self._executor = ThreadPoolExecutor(
                    max_workers=workers,
                    thread_name_prefix="traversal-service")
            executor = self._executor

        t0 = self._clock()

        def _run() -> TraversalResult:
            # the root opens in the WORKER thread (spans propagate per
            # thread), covering the executed portion of the request
            with self._tracer.span("traversal.request", tier="request",
                                   kind=req.kind) as rsp:
                res = self.perform(req)  # fail() runs inside on error
                res.latency_s = self._clock() - t0
                self.complete(req, res.latency_s)
                rsp.set(hops=res.hops, edges=res.edges_scanned,
                        truncated=bool(res.truncated))
                return res

        return executor.submit(_run)

    def as_dict(self) -> dict:
        """Service + underlying engine accounting, one dict (plus the
        hot-set tier's, when the backend carries one — a single
        engine's cache or the sharded service's fleet fold)."""
        out = {"traversal": self.stats.as_dict(),
               "query": self._engine.stats.as_dict()}
        hs = None
        if getattr(self._engine, "hotset", None) is not None:
            hs = self._engine.hotset.stats
        elif hasattr(self._engine, "hotset_stats"):
            hs = self._engine.hotset_stats()
        if hs is not None:
            out["hotset"] = hs.as_dict()
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._executor_lock:
            if self._executor is not None:
                self._executor.shutdown(wait=True)
                self._executor = None

    def __enter__(self) -> "TraversalService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
