"""Concurrent random-access neighbor-query engine over CompBin + PG-Fuse.

Everything upstream of this module streams the graph *sequentially*; this
is the other half of the paper's CompBin claim (§IV): the packed
neighbors array is **byte-addressable** — the n-th neighbor of vertex
``v`` lives at ``neighbors_start + (offsets[v] + n) * b`` — so any
adjacency list can be fetched in O(1) reads with no sequential decode.
The engine turns that property into a serving-grade query path:

* a **batch** of vertex ids is deduplicated, its offset pairs and packed
  neighbor ranges are **coalesced** into merged range reads (two vertices
  whose bytes share a PG-Fuse block cost one request, not two), and the
  packed bytes are decoded with eq. (1)'s shift+adds;
* the packed bytes of a **large-fanout batch decode on the device**:
  the merged runs ship in ONE ``jax.device_put`` and the Pallas
  ``compbin_decode`` kernel runs eq. (1) next to the gathers it feeds —
  host and device modes are bit-identical, and
  :func:`repro.core.policy.choose_query_decode` places each micro-batch
  by its exact edge mass (known after the offsets gather, before any
  byte is decoded);
* an **async request queue** micro-batches concurrent callers: requests
  arriving within ``window_s`` (or until ``max_batch`` ids are pending)
  execute as ONE coalesced batch, and the **adaptive window**
  (:class:`repro.query.window.AdaptiveWindow`) closes the batch EARLY
  the moment the pending dedup ratio stops improving — waiting only
  pays while concurrent traffic overlaps;
* an optional **device-resident hot-set tier**
  (:class:`repro.query.hotset.HotSetCache`, ``hotset=``) sits ABOVE the
  gather: decoded neighbor runs of hub vertices stay resident in HBM
  under a byte budget with degree-aware admission
  (:func:`repro.core.policy.choose_hotset_admission` — pin hubs, bypass
  the cold tail), so a hot hit touches neither storage nor the PG-Fuse
  block cache nor the decoder, and trace-driven prefetch fetches
  predicted-hot vertices after each batch, outside any request's
  latency — hot answers are byte-identical to every decode path (the
  differential fuzzers assert it);
* :class:`QueryStats` accounts every request: virtual-clock latency
  percentiles (p50/p99 under an injectable ``clock``, so benchmarks
  measure the *request pattern* against a simulated storage clock, not
  the CI machine), unique PG-Fuse blocks touched, and the dedup ratio
  (requested ids / unique ids actually fetched).

PG-Fuse should be mounted in the **random-access mode**
(:func:`repro.core.policy.choose_access_mode`): readahead off — the next
sequential block is NOT more likely to be needed — and clock/second-
chance eviction so the hot offset blocks survive packed-byte churn.
The full three-tier hierarchy (storage blocks / host-RAM PG-Fuse / HBM
hot set) is laid out in ``docs/architecture.md``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core import codec as _codec
from repro.core import compbin
from repro.core import policy as _policy
from repro.core.paragrapher import GraphHandle
from repro.obs.metrics import LatencyHistogram
from repro.obs.trace import NULL_TRACER
from repro.query.window import AdaptiveWindow

DECODE_MODES = ("host", "device", "auto")


def _merge_ranges(ranges: List[tuple], gap: int) -> List[tuple]:
    """Merge byte ranges whose gap is <= ``gap`` into covering reads.

    ``ranges`` are (start, end) with end exclusive; the result is sorted
    and disjoint.  Merging across a small gap trades a bounded memcpy of
    unneeded bytes for one fewer cache request — on PG-Fuse the gap bytes
    are in already-acquired blocks, so no extra storage traffic occurs.
    """
    if not ranges:
        return []
    ranges = sorted(ranges)
    out = [list(ranges[0])]
    for s, e in ranges[1:]:
        if s - out[-1][1] <= gap:
            out[-1][1] = max(out[-1][1], e)
        else:
            out.append([s, e])
    return [(s, e) for s, e in out]


def _blocks_of(ranges: Sequence[tuple], block_size: int) -> set:
    """Unique block indices addressed by byte ``ranges``."""
    touched = set()
    for s, e in ranges:
        if e > s:
            touched.update(range(s // block_size, (e - 1) // block_size + 1))
    return touched


@dataclasses.dataclass
class QueryStats:
    """Per-engine accounting (reset with :meth:`reset`).

    ``latencies`` is a fixed-size log-bucket
    :class:`repro.obs.metrics.LatencyHistogram` over the engine's WHOLE
    history — bounded memory with no rolling-window truncation, and its
    merge is exactly associative (the old raw-list retention grew
    without bound and ``merge()`` concatenated untrimmed).  p50/p99 are
    within one bucket width (~2%) of the exact values, exact for
    constant (virtual-clock) distributions.
    """

    requests: int = 0          # vertex lookups requested (duplicates incl.)
    unique_vertices: int = 0   # fetched after in-batch dedup
    batches: int = 0           # coalesced executions
    coalesced_reads: int = 0   # merged range reads issued (offsets+packed)
    blocks_touched: int = 0    # unique cache blocks addressed (per batch)
    bytes_gathered: int = 0    # packed+offset bytes actually needed
    edges_returned: int = 0    # neighbor ids handed back to callers
    device_batches: int = 0    # micro-batches decoded on device
    bytes_h2d: int = 0         # packed bytes shipped for device decode
    # why each executed batch closed ("full"/"plateau"/"timeout"/"flush"/
    # "direct"); invariant: sum(close_reasons.values()) == batches —
    # held at EVERY instant, including snapshots taken concurrently
    # with in-flight batches, because every mutation (the engine's
    # per-batch fold, reset) runs under this object's _lock
    close_reasons: dict = dataclasses.field(default_factory=dict)
    latencies: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    def __post_init__(self) -> None:
        # the stats object OWNS its lock (an attribute, not a field, so
        # asdict()/replace() never touch it): the engine folds each
        # batch under it, and reset()/as_dict() take the SAME lock —
        # a reset interleaving a fold mid-batch used to tear the
        # close_reasons/batches invariant
        self._lock = threading.Lock()

    @property
    def dedup_ratio(self) -> float:
        """Requested ids per unique fetch (> 1 when batching pays)."""
        return self.requests / self.unique_vertices \
            if self.unique_vertices else 0.0

    def latency_quantile(self, q: float) -> float:
        with self._lock:
            return self.latencies.quantile(q)

    @property
    def p50_s(self) -> float:
        return self.latency_quantile(0.50)

    @property
    def p99_s(self) -> float:
        return self.latency_quantile(0.99)

    def as_dict(self) -> dict:
        with self._lock:
            d = {f.name: getattr(self, f.name)
                 for f in dataclasses.fields(self)}
            d["close_reasons"] = dict(d["close_reasons"])
            hist = d.pop("latencies")
            d["n_latencies"] = hist.n
            d["p50_s"] = hist.quantile(0.50)
            d["p99_s"] = hist.quantile(0.99)
        d["dedup_ratio"] = (d["requests"] / d["unique_vertices"]
                            if d["unique_vertices"] else 0.0)
        return d

    def _snapshot(self) -> "QueryStats":
        """A consistent copy taken under the stats lock (mutable fields
        deep-copied, so the snapshot never aliases live state)."""
        with self._lock:
            return dataclasses.replace(
                self, latencies=self.latencies.copy(),
                close_reasons=dict(self.close_reasons))

    def merge(self, other: "QueryStats") -> "QueryStats":
        """Associative cross-engine aggregation (returns a NEW instance).

        The sharded service (:mod:`repro.query.sharded`) folds every
        shard replica's engine stats into service totals with this:
        counters sum, ``close_reasons`` sum key-wise, latency
        histograms merge bucket-wise (exactly associative, so
        per-shard sums equal service totals).  Each side is snapshotted
        under its own lock — no lock ordering between the two objects,
        so merging is safe against concurrent folds AND against
        ``merge(self, self)``.  The invariant
        ``sum(close_reasons.values()) == batches`` is preserved: it
        holds for each operand, and both sides sum.
        """
        a, b = self._snapshot(), other._snapshot()
        out = QueryStats()
        for f in dataclasses.fields(out):
            if f.name in ("latencies", "close_reasons"):
                continue
            setattr(out, f.name, getattr(a, f.name) + getattr(b, f.name))
        for src in (a.close_reasons, b.close_reasons):
            for k, v in src.items():
                out.close_reasons[k] = out.close_reasons.get(k, 0) + v
        out.latencies = a.latencies.merge(b.latencies)
        return out

    def reset(self) -> "QueryStats":
        """Zero in place ATOMICALLY; returns the pre-reset snapshot.

        Runs under the stats lock, so concurrent in-flight batches
        land wholly before or wholly after the cut: the snapshot and
        the zeroed object BOTH satisfy
        ``sum(close_reasons.values()) == batches``, and no batch is
        lost across the reset (the regression suite hammers exactly
        this interleaving).
        """
        with self._lock:
            snap = dataclasses.replace(
                self, latencies=self.latencies.copy(),
                close_reasons=dict(self.close_reasons))
            for f in dataclasses.fields(self):
                cur = getattr(self, f.name)
                setattr(self, f.name,
                        LatencyHistogram()
                        if isinstance(cur, LatencyHistogram)
                        else [] if isinstance(cur, list)
                        else {} if isinstance(cur, dict) else 0)
        return snap


def merge_query_stats(stats) -> QueryStats:
    """Fold any number of engines' :class:`QueryStats` into one
    aggregate (associative; mirrors
    :func:`repro.data.graph_stream.merge_stats`)."""
    out = QueryStats()
    for s in stats:
        out = out.merge(s)
    return out


class QueryFuture:
    """Result slot for one async request (resolved by the engine)."""

    def __init__(self, vertices: np.ndarray, t_submit: float):
        self.vertices = vertices
        self.t_submit = t_submit
        self._done = threading.Event()
        self._result: Optional[List[np.ndarray]] = None
        self._error: Optional[BaseException] = None
        self.latency_s: float = 0.0

    def _resolve(self, result, error, latency_s: float) -> None:
        self._result = result
        self._error = error
        self.latency_s = latency_s
        self._done.set()

    def result(self, timeout: Optional[float] = None) -> List[np.ndarray]:
        if not self._done.wait(timeout):
            raise TimeoutError("query did not complete in time")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def done(self) -> bool:
        return self._done.is_set()


class NeighborQueryEngine:
    """Batched random-access ``neighbors(v)`` over an open CompBin graph.

    One engine per host; the graph handle's PG-Fuse mount is shared with
    whatever else the host serves (feature stores mount into the same
    budget).  Synchronous use::

        engine = NeighborQueryEngine(graph)
        adj = engine.neighbors_batch([5, 9, 5, 1022])   # list of arrays

    Concurrent serving::

        fut = engine.submit(request_vertex_ids)          # any thread
        neighbor_lists = fut.result()

    ``clock`` injects the time source for latency stats — benchmarks pass
    a SimStorage virtual clock so p50/p99 are deterministic properties of
    the request pattern.
    """

    def __init__(self, graph: GraphHandle, *,
                 max_batch: int = 1024,
                 window_s: float = 0.002,
                 merge_gap: Optional[int] = None,
                 decode: str = "auto",
                 adaptive_window: bool = True,
                 window_patience: int = 2,
                 window_min_overlap: float = 0.05,
                 hotset=None,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer=None):
        if not _codec.get_codec(graph.format).direct:
            raise ValueError(
                f"random-access queries need a direct-addressing codec "
                f"({', '.join(_codec.direct_codecs())}), not "
                f"{graph.format!r} (WebGraph requires a sequential decode "
                f"per block of vertices)")
        if decode not in DECODE_MODES:
            raise ValueError(f"decode must be one of {DECODE_MODES}, "
                             f"got {decode!r}")
        if decode == "device" and graph.n_vertices > (1 << 31):
            raise ValueError(
                f"|V|={graph.n_vertices} overflows the kernel's int32 "
                f"lanes; use decode='host' (or 'auto', which routes there)")
        self._graph = graph
        self._clock = clock
        # span tracing (repro.obs): the default NULL_TRACER makes every
        # span site a no-op context manager — zero-cost when disabled.
        # A real tracer is also handed to this engine's PG-Fuse mount so
        # storage reads nest under this engine's gather spans.
        self._tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None and graph.fs is not None:
            graph.fs.tracer = tracer
        self.decode = decode
        self.max_batch = int(max_batch)
        self.window_s = float(window_s)
        # header fields pin the direct-addressing arithmetic
        rdr = graph._reader()
        try:
            self._header = rdr.header
        finally:
            rdr.close()
        self._b = self._header.b
        self._block_size = (graph.fs.block_size if graph.fs is not None
                            else 1 << 20)
        self.merge_gap = (int(merge_gap) if merge_gap is not None
                          else self._block_size)
        # the optional HBM-resident tier above the gather: an int is a
        # byte budget (admission sized by policy from THIS graph's mean
        # degree), a HotSetCache/HotSetPlan is used as given
        self._hotset = None
        if hotset is not None:
            from repro.core.policy import HotSetPlan
            from repro.query.hotset import HotSetCache
            if isinstance(hotset, HotSetCache):
                self._hotset = hotset
            elif isinstance(hotset, HotSetPlan):
                self._hotset = HotSetCache(plan=hotset)
            else:
                plan = _policy.choose_hotset_admission(
                    graph.n_vertices, self._header.n_edges, int(hotset))
                self._hotset = HotSetCache(plan=plan)
        self.stats = QueryStats()
        # per-batch folds share the stats object's OWN lock, so an
        # external stats.reset()/as_dict() is atomic against them
        self._stats_lock = self.stats._lock
        # async micro-batching state: _have_work wakes the idle worker
        # (it blocks indefinitely between requests — no polling);
        # _full short-circuits the batching window when max_batch ids
        # are already pending
        self._pending: List[QueryFuture] = []
        self._pending_lock = threading.Lock()
        self._have_work = threading.Event()
        self._full = threading.Event()
        # the window decides WHEN the pending batch executes; its clock is
        # the engine's, so benches/tests drive it virtually
        self._window = AdaptiveWindow(
            window_s=self.window_s, max_batch=self.max_batch,
            adaptive=adaptive_window, patience=window_patience,
            min_overlap=window_min_overlap, clock=clock)
        self._close_reason: Optional[str] = None
        self._closed = False
        self._worker: Optional[threading.Thread] = None

    # -- properties --------------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._graph.n_vertices

    @property
    def graph(self) -> GraphHandle:
        return self._graph

    @property
    def hotset(self):
        """The device-resident hot-set tier, or None (see
        :mod:`repro.query.hotset`)."""
        return self._hotset

    # -- the coalesced fetch core ------------------------------------------
    @staticmethod
    def _read_range(f, start: int, nbytes: int) -> bytes:
        """One merged range read.  Over PG-Fuse the span is announced
        first (``prefetch_range``): every cold run of blocks it covers is
        fetched with ONE enlarged storage request instead of one request
        per block — random-access traffic then gets the paper's
        fewer-larger-requests property without speculative readahead."""
        if hasattr(f, "prefetch_range"):
            f.prefetch_range(start, nbytes)
        if hasattr(f, "pread"):
            return f.pread(start, nbytes)
        f.seek(start)
        return f.read(nbytes)

    def _gather_offsets(self, uniq: np.ndarray, f):
        """offsets[v] and offsets[v+1] for each (sorted unique) vertex,
        via coalesced range reads of the offsets array.

        Returns (int64 array of shape (len(uniq), 2), n_reads, byte
        ranges read).  Consecutive vertices share the boundary entry;
        runs closer than the merge gap collapse into one read.  All the
        codec-specific addressing lives in the header's contract methods
        (``offsets_span`` / ``decode_offsets`` / ``offsets_gap_vertices``
        — see :mod:`repro.core.codec`), so CompBin's plain u64 array and
        LogCSR's bit-packed one take the same path here.
        """
        h = self._header
        gap_vertices = h.offsets_gap_vertices(self.merge_gap)
        runs: List[tuple] = []       # (v_start, v_end) inclusive vertex runs
        for v in uniq:
            v = int(v)
            if runs and v - runs[-1][1] <= gap_vertices:
                runs[-1] = (runs[-1][0], v)
            else:
                runs.append((v, v))
        out = np.empty((len(uniq), 2), dtype=np.int64)
        byte_ranges = []
        n_reads = 0
        i = 0
        for a, z in runs:
            start, nbytes = h.offsets_span(a, z)   # offsets[a ..= z+1]
            raw = self._read_range(f, start, nbytes)
            words = h.decode_offsets(raw, a, z)
            n_reads += 1
            byte_ranges.append((start, start + nbytes))
            while i < len(uniq) and a <= int(uniq[i]) <= z:
                lo = int(uniq[i]) - a
                out[i, 0] = words[lo]
                out[i, 1] = words[lo + 1]
                i += 1
        assert i == len(uniq)
        return out, n_reads, byte_ranges

    def _gather_packed(self, spans: np.ndarray, f):
        """Packed neighbor bytes for each (o0, o1) edge span, via merged
        range reads of the neighbors section.  Returns (list of per-span
        uint8 arrays, n_reads, needed byte ranges)."""
        h = self._header
        b = self._b
        need = []
        for k, (o0, o1) in enumerate(spans):
            if o1 > o0:
                s = h.neighbors_start + b * int(o0)
                need.append((s, s + b * int(o1 - o0), k))
        merged = _merge_ranges([(s, e) for s, e, _ in need], self.merge_gap)
        bufs = {}
        for s, e in merged:
            raw = self._read_range(f, s, e - s)
            bufs[s] = (np.frombuffer(raw, dtype=np.uint8), e)
        starts = sorted(bufs)
        out: List[np.ndarray] = [np.zeros(0, np.uint8)] * len(spans)
        for s, e, k in need:
            # merged run containing this span
            j = int(np.searchsorted(starts, s, side="right")) - 1
            base = starts[j]
            buf, _ = bufs[base]
            out[k] = buf[s - base: e - base]
        return out, len(merged), [(s, e) for s, e, _ in need]

    def _open(self):
        """A positional-read handle: the PG-Fuse CachedFile when mounted
        (its ``pread`` assembles from cached blocks), else a plain file."""
        if self._graph.fs is not None:
            return self._graph.fs.mount(self._graph.path), False
        return open(self._graph.path, "rb"), True

    # -- decode placement (the tentpole of serving-path v2) ----------------
    def _decode_plan(self, n_edges: int) -> "_policy.QueryDecodePlan":
        """Host-vs-device placement for ONE micro-batch of ``n_edges``."""
        if self.decode == "host":
            return _policy.QueryDecodePlan("host", "engine pinned to host")
        if self.decode == "device":
            return _policy.QueryDecodePlan("device", "engine pinned to device")
        return _policy.choose_query_decode(n_edges, self._b,
                                           n_vertices=self.n_vertices)

    def _decode_host(self, packed: List[np.ndarray]
                     ) -> tuple[List[np.ndarray], int]:
        """Eq. (1) on the host, one span at a time.  Returns (decoded
        int64 arrays, 0 bytes shipped)."""
        return [compbin.decode_ids(p, self._b).astype(np.int64)
                for p in packed], 0

    def _decode_device(self, packed: List[np.ndarray]
                       ) -> tuple[List[np.ndarray], int]:
        """Eq. (1) on the device: the batch's merged packed runs ship as
        ONE transfer, the Pallas kernel decodes them, and the flat id
        stream is split back into per-span views — bit-identical to
        :meth:`_decode_host`.  The decoder is resolved per codec through
        the kernel op surface's registry (LogCSR shares CompBin's packed
        neighbor layout, hence its kernel).  Returns (decoded arrays,
        H2D bytes)."""
        from repro.kernels.compbin_decode import packed_stream_decoder

        if not packed:
            return [], 0
        lens = np.array([p.size // self._b for p in packed], dtype=np.int64)
        if int(lens.sum()) == 0:
            return [np.zeros(0, np.int64) for _ in packed], 0
        allbytes = np.concatenate(packed)
        decode_stream = packed_stream_decoder(self._graph.format)
        ids, nbytes_h2d = decode_stream(allbytes, self._b)
        # per-span COPIES, matching the host path's independent arrays:
        # handing out views into the flat batch buffer would let one
        # retained hub list pin the whole batch's decoded ids
        return [a.copy() for a in np.split(ids, np.cumsum(lens)[:-1])], \
            nbytes_h2d

    def neighbors_batch(self, vertices, *,
                        _close_reason: str = "direct") -> List[np.ndarray]:
        """Adjacency lists for ``vertices`` (duplicates fine), in order.

        The whole batch is deduplicated and fetched with coalesced reads;
        each returned array is the full (decoded) neighbor list of the
        corresponding input vertex.  ``_close_reason`` is the engine's
        internal accounting of WHY this batch executed (the async worker
        passes the window-close reason; direct calls record "direct").
        """
        vertices = np.asarray(vertices, dtype=np.int64).ravel()
        if vertices.size == 0:
            return []
        if vertices.min() < 0 or vertices.max() >= self.n_vertices:
            raise ValueError(
                f"vertex ids must be in [0, {self.n_vertices}); got "
                f"[{vertices.min()}, {vertices.max()}]")
        t0 = self._clock()
        # the gather span covers the whole coalesced fetch: PG-Fuse read
        # spans (tier=storage) and the decode span nest inside it, so
        # its SELF time is the pure batching machinery
        with self._tracer.span("query.batch", tier="gather",
                               vertices=int(vertices.size)) as bsp:
            uniq, inverse = np.unique(vertices, return_inverse=True)
            # tier-3 lookup FIRST: a hot vertex touches neither storage
            # nor the PG-Fuse block cache nor the decoder below
            hot: dict = {}
            if self._hotset is not None:
                hot = self._hotset.lookup(uniq)
                self._hotset.observe(uniq)
                bsp.event("hotset_lookup", hits=len(hot),
                          misses=int(len(uniq) - len(hot)))
            if hot:
                cold = uniq[np.fromiter((int(v) not in hot for v in uniq),
                                        bool, len(uniq))]
            else:
                cold = uniq
            off_reads = nbr_reads = 0
            off_ranges: List[tuple] = []
            nbr_ranges: List[tuple] = []
            decoded_cold: List[np.ndarray] = []
            bytes_h2d = 0
            on_device = 0
            if cold.size:
                f, own = self._open()
                try:
                    spans, off_reads, off_ranges = \
                        self._gather_offsets(cold, f)
                    packed, nbr_reads, nbr_ranges = \
                        self._gather_packed(spans, f)
                finally:
                    if own:
                        f.close()
                # placement per batch: edge mass is exact here (offsets
                # gathered, nothing decoded yet)
                n_edges = int((spans[:, 1] - spans[:, 0]).sum()) \
                    if len(spans) else 0
                plan = self._decode_plan(n_edges)
                if plan.device:
                    with self._tracer.span("query.decode", tier="decode",
                                           mode="device",
                                           edges=n_edges) as dsp:
                        decoded_cold, bytes_h2d = \
                            self._decode_device(packed)
                        # zero-width marker carrying the shipped bytes:
                        # H2D cost is folded into the device decode
                        # under the virtual clock, but the tier stays
                        # visible in the attribution
                        with self._tracer.span("query.h2d",
                                               tier="h2d") as hsp:
                            hsp.set(bytes=int(bytes_h2d))
                else:
                    with self._tracer.span("query.decode", tier="decode",
                                           mode="host", edges=n_edges):
                        decoded_cold, bytes_h2d = self._decode_host(packed)
                on_device = int(plan.device)
            if self._hotset is not None:
                # fills are free for the caller: the decode already
                # happened (admission keeps the cold tail out — see
                # hotset.fill)
                for v, d in zip(cold, decoded_cold):
                    self._hotset.fill(int(v), d)
                bsp.event("hotset_fill", offered=int(cold.size))
            if hot:
                it = iter(decoded_cold)
                decoded = [hot[int(v)] if int(v) in hot else next(it)
                           for v in uniq]
            else:
                decoded = decoded_cold
            result = [decoded[j] for j in inverse]
            latency = self._clock() - t0
            touched = _blocks_of(off_ranges + nbr_ranges, self._block_size)
            with self._stats_lock:
                st = self.stats
                st.requests += len(vertices)
                st.unique_vertices += len(uniq)
                st.batches += 1
                st.coalesced_reads += off_reads + nbr_reads
                st.blocks_touched += len(touched)
                st.bytes_gathered += sum(e - s
                                         for s, e in off_ranges + nbr_ranges)
                st.edges_returned += sum(len(d) for d in result)
                st.device_batches += on_device
                st.bytes_h2d += bytes_h2d
                st.close_reasons[_close_reason] = \
                    st.close_reasons.get(_close_reason, 0) + 1
                st.latencies.add(latency)
            bsp.event("window_close", reason=_close_reason)
        if self._hotset is not None:
            # trace-driven prefetch AFTER the request is answered and its
            # latency folded: predicted-hot vertices warm the tier on the
            # engine's time, not any caller's
            self._hotset_prefetch()
        return result

    def _hotset_prefetch(self) -> None:
        """Fetch + decode the tier's predicted-hot candidates and offer
        them back as prefetch fills.  Runs the same gather core as the
        request path (merged ranges, span announcement) but folds into
        :class:`~repro.query.hotset.HotSetStats` only — prefetch is the
        tier warming itself, not request traffic."""
        cand = np.sort(self._hotset.prefetch_candidates())
        if cand.size == 0:
            return
        # own span (tier=gather so a direct engine call may root here):
        # prefetch time is the tier warming itself, deliberately OUTSIDE
        # the request's query.batch span
        with self._tracer.span("query.prefetch", tier="gather",
                               candidates=int(cand.size)):
            f, own = self._open()
            try:
                spans, _, _ = self._gather_offsets(cand, f)
                packed, _, _ = self._gather_packed(spans, f)
            finally:
                if own:
                    f.close()
            with self._tracer.span("query.decode", tier="decode",
                                   mode="host"):
                decoded, _ = self._decode_host(packed)
            for v, d in zip(cand, decoded):
                self._hotset.fill(int(v), d, prefetch=True)

    def neighbors_batch_ragged(self, vertices) -> tuple:
        """Ragged (CSR-shard) form of :meth:`neighbors_batch`: returns
        ``(offsets, ids)`` where ``ids[offsets[i]:offsets[i+1]]`` is the
        neighbor list of ``vertices[i]`` — one flat buffer + offsets for
        consumers that ship the whole frontier onward (e.g. straight
        into a device gather) instead of a Python list per vertex."""
        lists = self.neighbors_batch(vertices)
        offsets = np.zeros(len(lists) + 1, dtype=np.int64)
        if lists:
            np.cumsum([len(x) for x in lists], out=offsets[1:])
            ids = np.concatenate(lists) if offsets[-1] else \
                np.zeros(0, np.int64)
        else:
            ids = np.zeros(0, np.int64)
        return offsets, ids

    def neighbors_of(self, v: int) -> np.ndarray:
        """Single-vertex convenience (GraphHandle-compatible)."""
        return self.neighbors_batch([int(v)])[0]

    # -- async micro-batching ----------------------------------------------
    def submit(self, vertices) -> QueryFuture:
        """Enqueue a request; it executes in the next micro-batch.

        Requests arriving within ``window_s`` of each other (or until
        ``max_batch`` ids are pending) are coalesced into ONE deduplicated
        fetch — the dedup ratio then counts cross-request sharing too.
        The adaptive window additionally closes the batch EARLY when the
        pending dedup ratio stops improving (waiting only pays while
        concurrent traffic overlaps); every executed batch's close reason
        lands in ``stats.close_reasons``.
        """
        if self._closed:
            raise ValueError("submit on closed engine")
        vertices = np.asarray(vertices, dtype=np.int64).ravel()
        fut = QueryFuture(vertices, self._clock())
        with self._pending_lock:
            self._pending.append(fut)
            reason = self._window.arrival(vertices)
            if reason is not None and self._close_reason is None:
                self._close_reason = reason
            close_now = self._close_reason is not None
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._worker_loop, daemon=True,
                    name="neighbor-query-engine")
                self._worker.start()
        self._have_work.set()
        if close_now:
            self._full.set()
        return fut

    def _take_pending(self, default_reason: str = "flush"
                      ) -> tuple[List[QueryFuture], str]:
        with self._pending_lock:
            batch, self._pending = self._pending, []
            reason = self._close_reason or default_reason
            self._close_reason = None
            self._window.reset()
        return batch, reason

    def _execute(self, batch: List[QueryFuture],
                 reason: str = "flush") -> None:
        if not batch:
            return
        splits = np.cumsum([f.vertices.size for f in batch])[:-1]
        allv = np.concatenate([f.vertices for f in batch]) \
            if batch else np.zeros(0, np.int64)
        try:
            results = self.neighbors_batch(allv, _close_reason=reason)
            per_req = [results[a:b] for a, b in
                       zip([0, *splits], [*splits, len(results)])]
            now = self._clock()
            for f, r in zip(batch, per_req):
                f._resolve(r, None, now - f.t_submit)
        except BaseException as e:
            now = self._clock()
            for f in batch:
                f._resolve(None, e, now - f.t_submit)

    def _worker_loop(self) -> None:
        while not self._closed:
            self._have_work.wait()   # idle: block, never poll
            if self._closed:
                return
            # the micro-batch window: give concurrent callers window_s
            # (REAL time — the engine's injectable clock may be virtual,
            # and an Event.wait timeout must not come from it) to pile
            # on; the window (via submit) cuts the wait short on "full"
            # or "plateau", a wait that expires untriggered is "timeout"
            self._full.wait(timeout=self.window_s)
            self._full.clear()
            self._have_work.clear()  # a submit racing past here re-sets it
            self._execute(*self._take_pending("timeout"))

    def flush(self) -> None:
        """Execute everything pending right now (on the calling thread)."""
        self._execute(*self._take_pending("flush"))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._have_work.set()  # unblock the idle worker so it can exit
        self._full.set()
        if self._worker is not None:
            self._worker.join(timeout=5)
        self.flush()  # resolve stragglers rather than hanging callers

    def __enter__(self) -> "NeighborQueryEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def gather_rows(store, ids) -> np.ndarray:
    """Feature rows for ``ids`` (duplicates fine) from a
    :class:`repro.core.featstore.FeatureStoreHandle`, with run-coalesced
    reads: sorted unique ids collapse into contiguous ``read_rows`` calls
    wherever the gap is small, so a clustered id batch costs a handful of
    range reads instead of one per row.
    """
    ids = np.asarray(ids, dtype=np.int64).ravel()
    out = np.zeros((len(ids), store.d), dtype=store.dtype)
    valid = ids >= 0   # sampler padding (-1) gathers zero rows
    if not valid.any():
        return out
    uniq, inverse = np.unique(ids[valid], return_inverse=True)
    if uniq.min() < 0 or uniq.max() >= store.n_rows:
        raise ValueError(f"row ids must be in [0, {store.n_rows})")
    # rows closer than ~64 KiB collapse into one range read: the gap rows
    # come out of blocks the run already acquired
    gap = max(1, (1 << 16) // max(1, store.header.row_stride))
    rows = np.empty((len(uniq), store.d), dtype=store.dtype)
    i = 0
    while i < len(uniq):
        j = i
        while j + 1 < len(uniq) and int(uniq[j + 1]) - int(uniq[j]) <= gap:
            j += 1
        v0, v1 = int(uniq[i]), int(uniq[j]) + 1
        chunk = store.read_rows(v0, v1)
        rows[i:j + 1] = chunk[uniq[i:j + 1] - v0]
        i = j + 1
    out[valid] = rows[inverse]
    return out
