"""Sharded scatter-gather serving across simulated processes.

PR 4–6 answer every query from ONE process with ONE PG-Fuse mount; the
paper's predecessor ("Selective Parallel Loading of Large-Scale
Compressed Graphs with ParaGrapher") frames loading as an inherently
parallel, partition-per-worker problem, and the serving side scales the
same way.  :class:`ShardedQueryService` is the first step from one
serving process toward that topology:

* **N vertex-range shards** — the graph's edge-balanced partition plan
  is cut by :func:`repro.graph.partition.shard_ranges` (the same
  :func:`~repro.graph.partition.split_plan` slicer the multi-host
  loader uses, ``shares`` skew included) into contiguous per-shard
  ranges; each shard owns its own
  :class:`~repro.query.NeighborQueryEngine` over its OWN
  :class:`~repro.core.paragrapher.GraphHandle` + PG-Fuse mount,
  simulated-process style per :mod:`repro.data.multihost` — a shard's
  cache only ever holds its range's offset/packed blocks, so per-shard
  working sets shrink by ``1/N`` (the locality lever cache-segmented
  hot sets exploit);
* **routing by vertex range** — a batched ``neighbors`` /
  ``neighbors_batch_ragged`` request splits by ``searchsorted`` over
  the shard range ends, executes as at most ONE engine batch per
  touched shard (dedup/coalescing/span prefetch/device placement all
  still apply per shard), and the per-shard answers are merged back
  into the request's own order — byte-identical to a single engine
  over the whole file;
* **scatter-gather frontiers** — the service exposes the engine's
  query surface, so a :class:`~repro.query.TraversalService` plugs it
  in unchanged: every hop's frontier scatter-gathers across shards
  (one batch per shard per hop) and reassembles into the pinned
  ascending-id order, keeping traversal semantics bit-identical to the
  single-engine service and the in-memory CSR reference (the
  differential harness in ``tests/test_sharded_differential.py``
  asserts exactly this, shard counts 1–4, host and device decode);
* **replication + load-balanced routing** — ``replication=R`` gives
  every shard R replicas (each with its own mount); a shard's slice
  routes to a replica by deterministic round-robin, so hub-heavy zipf
  traffic that concentrates on one shard's range splits across its
  replicas, and a replica whose storage fails over (``OSError``) is
  retried on its siblings (``router.reroutes`` counts the failovers);
* **aggregated accounting** — ``service.stats`` folds every replica's
  :class:`~repro.query.QueryStats` with the associative
  :meth:`~repro.query.QueryStats.merge`, so per-shard sums equal
  service totals by construction (conservation pinned by
  :attr:`ShardedQueryService.conserved`), and the service-level
  :class:`RouterStats` reconciles routed vertex counts against them;
* **per-shard hot sets** — ``hotset_bytes=`` gives every shard replica
  its own HBM-resident :class:`~repro.query.hotset.HotSetCache` above
  its engine (admission sized per shard by
  :func:`repro.core.policy.choose_hotset_admission`); a shard's hot
  set only ever holds ITS range's hubs — the same per-shard locality
  the split cache budgets buy, one tier up — and
  :meth:`ShardedQueryService.hotset_stats` /
  :meth:`~ShardedQueryService.per_shard_hotset_stats` fold the tiers'
  :class:`~repro.query.hotset.HotSetStats` RouterStats-style (per-shard
  sums equal fleet totals by the associative merge).

:func:`repro.core.policy.choose_shard_plan` sizes ``n_shards`` /
``replication`` / ``routing`` from the file size, per-shard cache
budgets and measured trace skew.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.core import paragrapher
from repro.core import policy as _policy
from repro.graph.partition import shard_ranges
from repro.obs.trace import NULL_TRACER
from repro.query.engine import NeighborQueryEngine, merge_query_stats


@dataclasses.dataclass
class RouterStats:
    """Service-level routing accounting (one instance per service).

    Conservation — pinned by the differential/fault suites:

    * ``sum(routed_by_shard.values()) == requests`` (every routed
      vertex lands on exactly one shard);
    * ``requests`` equals the merged per-shard engines'
      ``QueryStats.requests`` (nothing answered off the books; a
      failed batch that never folded engine stats is accounted in
      ``failed_batches`` instead).
    """

    requests: int = 0         # vertex lookups routed (duplicates incl.)
    batches: int = 0          # service-level batch calls
    routed_by_shard: dict = dataclasses.field(default_factory=dict)
    shard_batches: dict = dataclasses.field(default_factory=dict)
    reroutes: int = 0         # replica failovers (a sibling answered)
    failed_batches: int = 0   # per-shard batches no replica could answer

    def __post_init__(self) -> None:
        # attribute, not a field: asdict()/replace() never touch it
        self._lock = threading.Lock()

    def as_dict(self) -> dict:
        with self._lock:
            d = {f.name: getattr(self, f.name)
                 for f in dataclasses.fields(self)}
            d["routed_by_shard"] = dict(d["routed_by_shard"])
            d["shard_batches"] = dict(d["shard_batches"])
        return d


@dataclasses.dataclass
class ShardReplica:
    """One shard replica: its own graph handle (own PG-Fuse mount) and
    engine, plus the vertex range the router sends it."""

    shard: int
    replica: int
    graph: "paragrapher.GraphHandle"
    engine: NeighborQueryEngine
    v0: int
    v1: int


class ShardedQueryService:
    """Scatter-gather ``neighbors`` serving over N per-shard engines.

    Drop-in for a single :class:`~repro.query.NeighborQueryEngine`
    wherever only the query surface is used — in particular as the
    frontier-expansion backend of a
    :class:`~repro.query.TraversalService`::

        svc = ShardedQueryService(path, n_shards=2, replication=2)
        trav = TraversalService(svc, admission=plan)

    ``open_kwargs`` / ``engine_kwargs`` are dicts applied to every
    replica, or callables ``(shard, replica) -> dict`` so each
    simulated process gets its own storage backend (benchmarks hand
    every shard its own SimStorage clock this way, exactly like
    :func:`repro.data.multihost.simulate_hosts`'s ``open_kwargs``).
    ``plan`` takes a :class:`repro.core.policy.ShardPlan` (explicit
    ``n_shards`` / ``replication`` / ``routing`` override its fields).
    """

    def __init__(self, path, *,
                 n_shards: Optional[int] = None,
                 replication: Optional[int] = None,
                 routing: Optional[str] = None,
                 plan: Optional["_policy.ShardPlan"] = None,
                 shares=None,
                 n_parts: Optional[int] = None,
                 decode: str = "auto",
                 hotset_bytes: Optional[int] = None,
                 open_kwargs=None,
                 engine_kwargs=None,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer=None):
        if plan is not None:
            n_shards = plan.n_shards if n_shards is None else n_shards
            replication = (plan.replication if replication is None
                           else replication)
            routing = plan.routing if routing is None else routing
        n_shards = 1 if n_shards is None else int(n_shards)
        replication = 1 if replication is None else int(replication)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if replication < 1:
            raise ValueError(f"replication must be >= 1, "
                             f"got {replication}")
        routing = routing or ("rr" if replication > 1 else "direct")
        if routing not in ("direct", "rr"):
            raise ValueError(f"routing must be 'direct' or 'rr', "
                             f"got {routing!r}")
        self.path = path
        self.n_shards = n_shards
        self.replication = replication
        self.routing = routing
        self._clock = clock
        # ONE tracer shared with every replica engine (and, through
        # them, every PG-Fuse mount): nesting is per-thread state on
        # the tracer itself, so the route span below parents the
        # engines' gather spans only when the instances are shared
        self._tracer = tracer if tracer is not None else NULL_TRACER
        # every shard derives the same global plan from the same file —
        # the no-communication property split_plan gives the loader
        with paragrapher.open_graph(path) as g:
            self._n_vertices = g.n_vertices
            gplan = (g.partition_plan(n_parts or max(8, 4 * n_shards))
                     if g.n_vertices else [])
        self.ranges = (shard_ranges(gplan, n_shards, shares=shares)
                       if gplan else [(0, 0)] * n_shards)
        # routing table: shard i covers [bounds[i-1], bounds[i]); empty
        # shards repeat the previous end and are never selected by
        # searchsorted(side="right")
        self._bounds = np.asarray([v1 for _, v1 in self.ranges],
                                  dtype=np.int64)
        amode = _policy.choose_access_mode("serve")
        base_open = dict(use_pgfuse=True, pgfuse_readahead=amode.readahead,
                         pgfuse_eviction=amode.eviction)
        okw = (open_kwargs if callable(open_kwargs)
               else lambda s, r, _d=dict(open_kwargs or {}): _d)
        ekw = (engine_kwargs if callable(engine_kwargs)
               else lambda s, r, _d=dict(engine_kwargs or {}): _d)
        self.replicas: List[List[ShardReplica]] = []
        try:
            for s in range(n_shards):
                row = []
                for r in range(replication):
                    kw = dict(base_open)
                    kw.update(okw(s, r))
                    gh = paragrapher.open_graph(path, **kw)
                    e_kw = dict(ekw(s, r))
                    e_kw.setdefault("decode", decode)
                    e_kw.setdefault("clock", clock)
                    if tracer is not None:
                        e_kw.setdefault("tracer", tracer)
                    if hotset_bytes is not None:
                        # one hot set PER replica: each simulated process
                        # owns its range's hubs, like its PG-Fuse mount
                        e_kw.setdefault("hotset", int(hotset_bytes))
                    eng = NeighborQueryEngine(gh, **e_kw)
                    row.append(ShardReplica(s, r, gh, eng,
                                            *self.ranges[s]))
                self.replicas.append(row)
        except BaseException:
            self._close_replicas()
            raise
        self.router = RouterStats()
        self._rr = [0] * n_shards
        self._rr_lock = threading.Lock()
        self._closed = False

    # -- properties --------------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._n_vertices

    @property
    def stats(self):
        """Every replica engine's :class:`~repro.query.QueryStats`
        folded into service totals (a fresh merged snapshot per read —
        per-shard sums equal these totals by associativity)."""
        return merge_query_stats(rep.engine.stats
                                 for row in self.replicas for rep in row)

    def per_shard_stats(self) -> list:
        """One merged :class:`~repro.query.QueryStats` per shard
        (replicas folded)."""
        return [merge_query_stats(rep.engine.stats for rep in row)
                for row in self.replicas]

    def hotset_stats(self):
        """Every replica's :class:`~repro.query.hotset.HotSetStats`
        folded into fleet totals (None when the service runs without a
        hot-set tier)."""
        from repro.query.hotset import merge_hotset_stats

        caches = [rep.engine.hotset for row in self.replicas
                  for rep in row if rep.engine.hotset is not None]
        if not caches:
            return None
        return merge_hotset_stats(c.stats for c in caches)

    def per_shard_hotset_stats(self) -> list:
        """One merged :class:`~repro.query.hotset.HotSetStats` per shard
        (replicas folded; None entries for shards without the tier) —
        the hot-set analogue of ``RouterStats.routed_by_shard``:
        per-shard sums equal :meth:`hotset_stats` totals by the
        associative merge."""
        from repro.query.hotset import merge_hotset_stats

        out = []
        for row in self.replicas:
            caches = [rep.engine.hotset for rep in row
                      if rep.engine.hotset is not None]
            out.append(merge_hotset_stats(c.stats for c in caches)
                       if caches else None)
        return out

    @property
    def conserved(self) -> bool:
        """Routing/stat conservation: routed vertex counts reconcile
        with the merged engine totals, shard by shard and in total."""
        with self.router._lock:
            requests = self.router.requests
            by_shard = dict(self.router.routed_by_shard)
        if sum(by_shard.values()) != requests:
            return False
        per_shard = self.per_shard_stats()
        if sum(st.requests for st in per_shard) != self.stats.requests:
            return False
        return all(per_shard[s].requests == by_shard.get(s, 0)
                   for s in range(self.n_shards))

    def shard_of(self, v: int) -> int:
        """The shard whose vertex range covers ``v``."""
        return int(np.searchsorted(self._bounds, int(v), side="right"))

    # -- routing core ------------------------------------------------------
    def _pick_order(self, s: int) -> List[int]:
        """Replica try-order for one per-shard batch: deterministic
        round-robin start (load-balanced under ``"rr"``), siblings
        following in ring order for failover."""
        row = self.replicas[s]
        if len(row) == 1 or self.routing == "direct":
            return list(range(len(row)))
        with self._rr_lock:
            first = self._rr[s]
            self._rr[s] = (first + 1) % len(row)
        return [(first + k) % len(row) for k in range(len(row))]

    def _shard_batch(self, s: int, subset: np.ndarray) -> List[np.ndarray]:
        """ONE engine batch on shard ``s`` (failing replicas fail over
        to their siblings; only storage-class ``OSError`` reroutes —
        request errors propagate untouched)."""
        row = self.replicas[s]
        last_err: Optional[BaseException] = None
        for k, r in enumerate(self._pick_order(s)):
            try:
                return row[r].engine.neighbors_batch(subset)
            except OSError as e:
                last_err = e
                if k + 1 < len(row):
                    with self.router._lock:
                        self.router.reroutes += 1
                    # lands on the current route span (event count
                    # reconciles with RouterStats.reroutes)
                    self._tracer.event("reroute", shard=s, replica=r)
        with self.router._lock:
            self.router.failed_batches += 1
        self._tracer.event("shard_failed", shard=s)
        raise last_err

    def neighbors_batch(self, vertices) -> List[np.ndarray]:
        """Adjacency lists for ``vertices`` (duplicates fine), in input
        order — byte-identical to one engine over the whole file.  The
        batch splits by vertex range into at most one engine batch per
        touched shard; per-shard answers scatter back to their input
        positions."""
        if self._closed:
            raise ValueError("request on closed service")
        v = np.asarray(vertices, dtype=np.int64).ravel()
        if v.size == 0:
            return []
        if v.min() < 0 or v.max() >= self._n_vertices:
            raise ValueError(
                f"vertex ids must be in [0, {self._n_vertices}); got "
                f"[{v.min()}, {v.max()}]")
        sids = np.searchsorted(self._bounds, v, side="right")
        out: List[Optional[np.ndarray]] = [None] * v.size
        rt = self.router
        with rt._lock:
            rt.batches += 1
        # the route span's SELF time is the scatter/gather machinery;
        # each shard's engine work nests inside as gather/storage/decode
        with self._tracer.span("route.batch", tier="route",
                               vertices=int(v.size),
                               shards=int(np.unique(sids).size)):
            for s in np.unique(sids):
                idx = np.nonzero(sids == s)[0]
                lists = self._shard_batch(int(s), v[idx])
                for i, lst in zip(idx.tolist(), lists):
                    out[i] = lst
                # fold per shard AS each batch lands: a later shard's
                # failure leaves every answered shard's routing and
                # engine counters reconciled (conservation holds
                # mid-failure)
                with rt._lock:
                    s, k = int(s), int(idx.size)
                    rt.requests += k
                    rt.routed_by_shard[s] = rt.routed_by_shard.get(s, 0) + k
                    rt.shard_batches[s] = rt.shard_batches.get(s, 0) + 1
        return out

    def neighbors_batch_ragged(self, vertices) -> tuple:
        """Ragged (CSR-shard) form, same contract as
        :meth:`repro.query.NeighborQueryEngine.neighbors_batch_ragged`:
        a sorted traversal frontier comes back as one flat buffer in
        the same pinned ascending order a single engine produces."""
        lists = self.neighbors_batch(vertices)
        offsets = np.zeros(len(lists) + 1, dtype=np.int64)
        if lists:
            np.cumsum([len(x) for x in lists], out=offsets[1:])
            ids = np.concatenate(lists) if offsets[-1] else \
                np.zeros(0, np.int64)
        else:
            ids = np.zeros(0, np.int64)
        return offsets, ids

    def neighbors_of(self, v: int) -> np.ndarray:
        """Single-vertex convenience (engine-compatible)."""
        return self.neighbors_batch([int(v)])[0]

    # -- lifecycle ---------------------------------------------------------
    def _close_replicas(self) -> None:
        for row in getattr(self, "replicas", []):
            for rep in row:
                try:
                    rep.engine.close()
                finally:
                    rep.graph.close()

    def close(self) -> None:
        if getattr(self, "_closed", True):
            return
        self._closed = True
        self._close_replicas()

    def __enter__(self) -> "ShardedQueryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
