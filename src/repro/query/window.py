"""Adaptive micro-batch window — WHEN a pending serving batch executes.

The fixed window of PR 4 always waited ``window_s`` (or until
``max_batch`` ids piled up).  That is the right call only while waiting
keeps PAYING: the reason to hold a batch open is that concurrent
requests overlap (hub-heavy zipf traffic), so each arrival that shares
vertices with the pending set raises the dedup ratio and amortizes the
coalesced fetch further.  The moment arrivals stop overlapping, every
extra microsecond of window is pure latency with no fetch saved.

:class:`AdaptiveWindow` is that decision as an isolated, injectable-
clock state machine (so tests pin its transitions against synthetic
arrival schedules without threads): the engine reports each arrival,
and the window answers with a close reason the moment one fires —

* ``"full"``     — ``max_batch`` ids pending; executing now loses nothing;
* ``"plateau"``  — arrivals stopped overlapping the pending set: the
  MARGINAL overlap of each arrival (the fraction of its ids already
  pending or duplicated within it) stayed below ``min_overlap`` for
  ``patience`` consecutive arrivals.  The signal is deliberately
  per-arrival, not the delta of the cumulative dedup ratio — a
  cumulative ratio converges even while every arrival still
  half-duplicates the pending set (i.e. while waiting still saves half
  of each arrival's fetches);
* ``"timeout"``  — ``window_s`` elapsed (the engine's worker discovers
  this by waking from its timed wait; :meth:`timed_out` is the pure
  check).

Every executed batch records exactly one reason in
``QueryStats.close_reasons`` (sync calls record ``"direct"``, explicit
drains ``"flush"``), so ``sum(close_reasons.values()) == batches`` is an
engine invariant the differential suite asserts.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

#: every close reason the engine may record (the QueryStats invariant
#: check walks this list)
CLOSE_REASONS = ("full", "plateau", "timeout", "flush", "direct")


def close_reason_counts(close_reasons: dict) -> dict:
    """Normalize a ``QueryStats.close_reasons`` dict onto the full
    :data:`CLOSE_REASONS` axis (absent reasons become explicit zeros,
    unknown keys raise).  The observability layer uses this to compare
    stats counters against ``window_close`` span-event totals reason by
    reason — both sides on one fixed axis."""
    unknown = set(close_reasons) - set(CLOSE_REASONS)
    if unknown:
        raise ValueError(f"unknown close reasons {sorted(unknown)}; "
                         f"expected a subset of {CLOSE_REASONS}")
    return {r: int(close_reasons.get(r, 0)) for r in CLOSE_REASONS}


class AdaptiveWindow:
    """Pure micro-batch window state machine (no threads, no engine).

    Drive it with :meth:`arrival` per request and :meth:`timed_out` /
    :meth:`remaining` from the executor; :meth:`reset` when the pending
    batch is taken.  ``adaptive=False`` degrades to PR 4's fixed window
    (only ``"full"`` and ``"timeout"`` ever fire).
    """

    def __init__(self, *, window_s: float, max_batch: int,
                 adaptive: bool = True, patience: int = 2,
                 min_overlap: float = 0.05,
                 clock: Callable[[], float] = time.monotonic):
        if window_s < 0 or max_batch < 1:
            raise ValueError("window_s must be >= 0 and max_batch >= 1")
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self.adaptive = bool(adaptive)
        self.patience = int(patience)
        self.min_overlap = float(min_overlap)
        self._clock = clock
        self.reset()

    def reset(self) -> None:
        """Forget the pending batch (it was taken for execution)."""
        self._open = False
        self._t_open = 0.0
        self._total = 0
        self._unique = np.zeros(0, dtype=np.int64)  # sorted pending ids
        self._stale = 0
        self._arrivals = 0

    @property
    def is_open(self) -> bool:
        return self._open

    @property
    def pending_ids(self) -> int:
        return self._total

    @property
    def dedup_ratio(self) -> float:
        """Pending ids per unique pending id (>= 1 once non-empty)."""
        return self._total / self._unique.size if self._unique.size else 0.0

    def arrival(self, ids) -> Optional[str]:
        """Account one request's vertex ids; returns a close reason the
        moment this arrival makes waiting pointless, else None.

        All bookkeeping is vectorized, no per-id Python objects: the
        sorted pending-id array is probed with searchsorted
        (O(arrival * log pending)) and fresh ids are spliced in with one
        memmove (no re-sort) — the engine calls this under its pending
        lock on the serving hot path, so the worst per-arrival cost is
        one memcpy-rate pass over the pending set, never a sort of it.
        """
        ids = np.asarray(ids, dtype=np.int64).ravel()
        if not self._open:
            self._open = True
            self._t_open = self._clock()
        self._arrivals += 1
        self._total += ids.size
        overlap = None
        # unique-set bookkeeping exists only to feed the plateau signal;
        # a fixed (adaptive=False) window skips it entirely — submit's
        # hot path then pays nothing beyond the counters ("full" needs
        # only _total; dedup_ratio reads 0 in that mode)
        if self.adaptive and ids.size:
            uniq = np.unique(ids)
            if self._unique.size:
                known = np.isin(uniq, self._unique, assume_unique=True)
                fresh_ids = uniq[~known]
            else:
                fresh_ids = uniq
            if fresh_ids.size:
                self._unique = np.insert(
                    self._unique,
                    np.searchsorted(self._unique, fresh_ids), fresh_ids)
            # marginal overlap: the share of THIS arrival's ids the batch
            # already covers (cross-request + in-arrival duplicates)
            overlap = 1.0 - fresh_ids.size / ids.size
        if self._total >= self.max_batch:
            return "full"
        if overlap is None:   # fixed window, or an empty arrival
            return None
        # the first arrival has nothing to overlap with; judge from #2 on
        if self._arrivals >= 2:
            self._stale = 0 if overlap >= self.min_overlap \
                else self._stale + 1
            if self._stale >= self.patience:
                return "plateau"
        return None

    def timed_out(self) -> bool:
        """Pure timeout check on the WINDOW's clock.  Note the engine's
        executor times its real ``Event.wait`` with ``window_s`` in real
        seconds rather than calling this — the injectable clock may be
        virtual, and a thread wait must not take its timeout from it."""
        return self._open and self._clock() - self._t_open >= self.window_s

    def remaining(self) -> float:
        """Seconds of window left on the window's own clock."""
        if not self._open:
            return self.window_s
        return max(0.0, self.window_s - (self._clock() - self._t_open))
