"""Closed-loop multi-client load generator on a virtual clock.

Admission control is only trustworthy if its two promises — admitted
requests keep the SLO, overload surfaces as shed rate — are *measured
under overload*, and overload measurements on a shared CI machine are
noise.  :class:`LoadGenerator` therefore replays a whole serving day as
a **deterministic discrete-event simulation**: virtual clients, virtual
servers, virtual time.  Nothing here sleeps or threads; given the same
seed, graph and config, every latency sample, shed decision and stats
counter is bit-for-bit reproducible — p50/p99 and shed rate become
CI-gateable numbers.

Model
-----

* **Closed loop**: each of ``n_clients`` clients has at most one
  request outstanding — submit, wait for the answer, think for
  ``think_s``, submit again.  A shed request is retried after
  ``backoff_s``.  Closed loops self-throttle (offered load scales with
  completion rate), which is exactly how real SDK clients behave and
  why shedding, not queue collapse, is the visible overload signal.
* **Service time** is charged by an explicit cost model,
  ``cost_fn(result) -> seconds`` (default:
  :data:`HOP_DISPATCH_S` per frontier batch +
  ``edges_scanned / EDGES_PER_S``), plus whatever the storage layer's
  virtual clock charged during the traversal (pass ``charged_s=`` a
  callable reading it, e.g. ``lambda: sim_storage.charged_s``).
* **Concurrency** is ``plan.servers`` virtual executor slots: an
  admitted request starts on the earliest-free slot (FIFO) and
  finishes ``cost`` later; its latency is ``finish - arrival`` —
  queueing delay included, which is what the admission gate's sizing
  bounds.
* **Admission** drives the REAL :class:`~repro.query.traversal
  .TraversalService` gate and stats: the generator calls
  ``service.admit`` at (virtual) arrival and ``service.complete`` at
  (virtual) finish, so gate occupancy on the virtual timeline is
  exactly what a threaded deployment would see, and the conservation
  invariants (``admitted + shed == submitted``) are asserted on the
  service's own counters.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional

import numpy as np

from repro.query.traversal import (TraversalRequest, TraversalService)

#: default deterministic service-cost model: per-frontier dispatch +
#: per-edge scan cost (rates in the ballpark of the query bench's
#: decode model; the ratios are what load tests exercise)
HOP_DISPATCH_S = 100e-6
EDGES_PER_S = 5.0e6


def default_cost_fn(result) -> float:
    """Virtual seconds of service a finished traversal consumed."""
    return HOP_DISPATCH_S * max(1, result.hops) \
        + result.edges_scanned / EDGES_PER_S


@dataclasses.dataclass
class LoadReport:
    """One simulated run's outcome (all virtual-clock derived)."""

    horizon_s: float
    n_clients: int
    submitted: int = 0
    admitted: int = 0
    shed: int = 0
    completed: int = 0
    failed: int = 0
    latencies_s: list = dataclasses.field(default_factory=list)
    errors: list = dataclasses.field(default_factory=list)

    @property
    def shed_rate(self) -> float:
        return self.shed / self.submitted if self.submitted else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies_s), q))

    @property
    def p50_s(self) -> float:
        return self.latency_quantile(0.50)

    @property
    def p99_s(self) -> float:
        return self.latency_quantile(0.99)

    @property
    def throughput_rps(self) -> float:
        return self.completed / self.horizon_s if self.horizon_s else 0.0

    def as_dict(self) -> dict:
        return {
            "horizon_s": self.horizon_s, "n_clients": self.n_clients,
            "submitted": self.submitted, "admitted": self.admitted,
            "shed": self.shed, "completed": self.completed,
            "failed": self.failed, "shed_rate": self.shed_rate,
            "p50_s": self.p50_s, "p99_s": self.p99_s,
            "throughput_rps": self.throughput_rps,
            "n_errors": len(self.errors),
        }


class LoadGenerator:
    """Deterministic closed-loop driver over a
    :class:`~repro.query.traversal.TraversalService`.

    ``make_request(rng, client_id) -> TraversalRequest`` shapes the
    traffic (each client owns a ``np.random.default_rng(seed + id)``,
    so traces are reproducible per client, independent of event
    interleaving).  ``run()`` simulates until ``horizon_s`` of virtual
    time, drains in-flight requests, and returns a
    :class:`LoadReport`.
    """

    def __init__(self, service: TraversalService,
                 make_request: Callable[[np.random.Generator, int],
                                        TraversalRequest], *,
                 n_clients: int, horizon_s: float,
                 think_s: float = 0.0, backoff_s: float = 0.01,
                 cost_fn: Callable = default_cost_fn,
                 charged_s: Optional[Callable[[], float]] = None,
                 servers: Optional[int] = None,
                 seed: int = 0):
        if n_clients < 1 or horizon_s <= 0:
            raise ValueError("n_clients must be >= 1 and horizon_s > 0")
        if think_s < 0 or backoff_s < 0:
            raise ValueError("think_s and backoff_s must be >= 0")
        self.service = service
        self.make_request = make_request
        self.n_clients = int(n_clients)
        self.horizon_s = float(horizon_s)
        self.think_s = float(think_s)
        self.backoff_s = float(backoff_s)
        self.cost_fn = cost_fn
        self.charged_s = charged_s
        self.seed = int(seed)
        # executor slots: by default the admission plan's sizing; a
        # sharded deployment passes servers=plan.servers * n_shards so
        # the virtual executors match the scaled-out admission gate
        # (see docs/sharded_serving.md)
        if servers is not None and servers < 1:
            raise ValueError(f"servers must be >= 1, got {servers}")
        self.servers = (int(servers) if servers is not None
                        else (service.plan.servers if service.plan else 1))

    def run(self) -> LoadReport:
        report = LoadReport(horizon_s=self.horizon_s,
                            n_clients=self.n_clients)
        rngs = [np.random.default_rng(self.seed + c)
                for c in range(self.n_clients)]
        # event heap: (time, seq, kind, client, payload); seq breaks
        # ties deterministically (FIFO among simultaneous events)
        seq = 0
        heap: list = []
        self._server_free: List[float] = [0.0] * self.servers
        # stagger client starts across one think interval so "all
        # clients arrive at t=0" does not shed half the fleet on the
        # first tick by construction
        stagger = self.think_s / self.n_clients if self.think_s else 0.0
        for c in range(self.n_clients):
            heapq.heappush(heap, (c * stagger, seq, "submit", c, None))
            seq += 1
        svc = self.service
        while heap:
            t, _, kind, c, payload = heapq.heappop(heap)
            if kind == "finish":
                # the request virtually finishes NOW: release the gate,
                # fold the queue-inclusive latency, wake the client
                req, latency = payload
                svc.complete(req, latency)
                report.completed += 1
                report.latencies_s.append(latency)
                nxt = t + self.think_s
                if nxt <= self.horizon_s:
                    heapq.heappush(heap, (nxt, seq, "submit", c, None))
                    seq += 1
                continue
            if t > self.horizon_s:     # the client retires
                continue
            req = self.make_request(rngs[c], c)
            report.submitted += 1
            if not svc.admit(req):
                report.shed += 1
                heapq.heappush(
                    heap, (t + self.backoff_s, seq, "submit", c, None))
                seq += 1
                continue
            report.admitted += 1
            # execute the traversal NOW (results are time-independent);
            # place its virtual cost on the earliest-free server slot
            c0 = self.charged_s() if self.charged_s else 0.0
            try:
                res = svc.perform(req)
            except Exception as e:   # clean per-request failure
                report.failed += 1
                report.errors.append(e)
                nxt = t + self.backoff_s
                if nxt <= self.horizon_s:
                    heapq.heappush(heap, (nxt, seq, "submit", c, None))
                    seq += 1
                continue
            cost = self.cost_fn(res) + \
                ((self.charged_s() - c0) if self.charged_s else 0.0)
            free = heapq.heappop(self._server_free)
            start = max(t, free)
            finish = start + cost
            heapq.heappush(self._server_free, finish)
            heapq.heappush(heap, (finish, seq, "finish", c,
                                  (req, finish - t)))
            seq += 1
        return report
