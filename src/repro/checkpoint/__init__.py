from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,  # noqa: F401
                                           restore, restore_latest, save)
