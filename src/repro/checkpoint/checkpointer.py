"""Sharded, asynchronous, restart-safe checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` with the treedef, dtypes, and a completion marker.
Writes go to ``step_<N>.tmp`` and are atomically renamed, so a crash
mid-save never corrupts the restore path — ``restore_latest`` only
considers directories with a manifest (i.e. fully renamed).

* **Async**: ``AsyncCheckpointer.save`` snapshots the device arrays to host
  (blocking only for the device->host copy) and writes on a background
  thread, overlapping the next training steps.
* **Elastic restart**: leaves are stored as *global* (unsharded) arrays;
  ``restore(..., shardings=...)`` re-shards onto whatever mesh the new job
  runs — device counts may differ across restarts (see
  distributed/elastic.py and tests/test_checkpoint.py::test_elastic).
* **keep_last**: old steps are garbage-collected after a successful save.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_MANIFEST = "manifest.json"

# numpy can't serialize ml_dtypes natively; store via same-width int views
_VIEW_CONTAINERS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    dt = str(arr.dtype)
    container = _VIEW_CONTAINERS.get(dt)
    if container is not None:
        return arr.view(container), dt
    return arr, dt


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _VIEW_CONTAINERS:
        return arr.view(getattr(ml_dtypes, dtype_str))
    return arr


def _flatten_with_paths(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3) -> str:
    """Blocking save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    names = []
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        savable, dtype_str = _to_savable(arr)
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), savable)
        names.append({"key": key, "file": fname, "dtype": dtype_str,
                      "shape": list(arr.shape)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"step": step, "leaves": names}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like: Any, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``; optionally re-shard each
    leaf with the matching entry of ``shardings`` (elastic restart)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves, treedef = _flatten_with_paths(tree_like)
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None or
                                    isinstance(x, jax.sharding.Sharding))
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (key, like), shard in zip(leaves, shard_leaves):
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint at {path} missing leaf {key!r}")
        arr = _from_saved(np.load(os.path.join(path, entry["file"])),
                          entry["dtype"])
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


def restore_latest(ckpt_dir: str, tree_like: Any, *, shardings: Any = None
                   ) -> tuple[Optional[int], Any]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None, tree_like
    return step, restore(ckpt_dir, step, tree_like, shardings=shardings)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, *, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()  # one in flight; also surfaces prior errors
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True,
                                        name=f"ckpt-save-{step}")
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
