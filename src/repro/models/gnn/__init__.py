from repro.models.gnn import dimenet, gcn, meshgraphnet, pna  # noqa: F401
