"""GCN [Kipf & Welling, arXiv:1609.02907] — gcn-cora config:
n_layers=2, d_hidden=16, mean/sym-normalized aggregation.

SpMM regime:  H' = sigma( D^-1/2 (A+I) D^-1/2 H W )  realized as
gather(src) -> per-edge scale -> segment-sum -> dense W.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy_loss, dense_init
from repro.models.gnn import layers as L


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_hidden: int = 16
    d_in: int = 1433
    n_classes: int = 7
    norm: str = "sym"       # "sym" | "mean"
    dtype: type = jnp.float32
    # A(XW) instead of (AX)W when d_out < d_in: same math (both are
    # linear), but the gathered/scattered messages shrink from d_in-wide
    # to d_out-wide — for ogb_products (100 -> 16) an ~6x cut in the
    # SpMM gather/scatter traffic (EXPERIMENTS.md §Perf).
    transform_first: bool = False


def init_params(cfg: GCNConfig, key: jax.Array) -> dict:
    params = {}
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    for i in range(cfg.n_layers):
        key, k = jax.random.split(key)
        params[f"w{i}"] = dense_init(k, (dims[i], dims[i + 1]), dtype=cfg.dtype)
        params[f"b{i}"] = jnp.zeros((dims[i + 1],), cfg.dtype)
    return params


def forward(params: dict, batch: dict, cfg: GCNConfig) -> jnp.ndarray:
    x = batch["x"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    deg = L.degree(dst, n) + 1.0  # +1: self loop
    if cfg.norm == "sym":
        dn = jax.lax.rsqrt(deg)
        w_edge = L.gather(dn[:, None], src)[:, 0] * L.gather(dn[:, None], dst)[:, 0]
        self_w = 1.0 / deg
    else:
        w_edge = 1.0 / jnp.maximum(L.gather(deg[:, None], dst)[:, 0], 1)
        self_w = 1.0 / deg
    for i in range(cfg.n_layers):
        if cfg.transform_first and params[f"w{i}"].shape[1] < x.shape[1]:
            x = x @ params[f"w{i}"]
            msgs = L.gather(x, src) * w_edge[:, None]
            x = (L.scatter_sum(msgs, dst, n) + x * self_w[:, None]
                 + params[f"b{i}"])
        else:
            msgs = L.gather(x, src) * w_edge[:, None]
            agg = L.scatter_sum(msgs, dst, n) + x * self_w[:, None]
            x = agg @ params[f"w{i}"] + params[f"b{i}"]
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params: dict, batch: dict, cfg: GCNConfig) -> jnp.ndarray:
    logits = forward(params, batch, cfg)
    labels = jnp.where(batch["label_mask"], batch["labels"], -100)
    return cross_entropy_loss(logits, labels)
