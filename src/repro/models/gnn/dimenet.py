"""DimeNet — Directional Message Passing [arXiv:2003.03123].

Config: n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6.

Triplet-gather regime (kernel taxonomy §GNN): messages live on *edges*
m_ji, and each interaction block refines them with angular information from
edge pairs (k->j, j->i):

    m_ji' = f( m_ji,  sum_k  W_bilinear[ a_SBF(d_kj, alpha_kji) ] ( m_kj ) )

Inputs carry precomputed triplet index lists (t_kj, t_ji) — pairs of edge
indices sharing vertex j — padded with -1.  The radial basis is the paper's
envelope-damped Bessel-like sine basis; the angular basis uses cos(l*alpha)
harmonics in place of spherical Bessel roots (simplification recorded in
DESIGN.md §Arch-applicability: identical compute graph shape — basis eval,
(T, n_sph*n_rad) outer features, bilinear contraction, triplet scatter —
only the basis constants differ).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn import layers as L


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    envelope_p: int = 6
    d_in: int = 16            # node (atom-type) embedding in
    n_targets: int = 1        # regression targets (energy)
    dtype: type = jnp.float32


def init_params(cfg: DimeNetConfig, key: jax.Array) -> dict:
    d, nb = cfg.d_hidden, cfg.n_bilinear
    nsr = cfg.n_spherical * cfg.n_radial
    p: dict = {}
    keys = iter(jax.random.split(key, 8 + 8 * cfg.n_blocks))

    def W(*shape):
        return dense_init(next(keys), shape, dtype=cfg.dtype)

    # embedding block: h_ji = MLP([x_j, x_i, rbf(d_ji)])
    p["emb_w"] = W(2 * cfg.d_in + cfg.n_radial, d)
    p["emb_b"] = jnp.zeros((d,), cfg.dtype)
    for i in range(cfg.n_blocks):
        blk = {
            "rbf_w": W(cfg.n_radial, d),                    # radial gate
            "sbf_w": W(nsr, nb),                            # angular -> bilinear
            "down_w": W(d, nb),                             # m_kj -> bilinear
            "up_w": W(nb, d),                               # bilinear -> hidden
            "self_w": W(d, d), "self_b": jnp.zeros((d,), cfg.dtype),
            "out_w": W(d, d), "out_b": jnp.zeros((d,), cfg.dtype),
            # per-block output head (edge -> node -> target)
            "head_w": W(d, cfg.n_targets),
        }
        p[f"block{i}"] = blk
    return p


def _envelope(r: jnp.ndarray, p: int) -> jnp.ndarray:
    """Smooth cutoff polynomial u(r) of DimeNet eq. (8), r in [0, 1]."""
    a = -(p + 1) * (p + 2) / 2
    b = p * (p + 2)
    c = -p * (p + 1) / 2
    return 1.0 / jnp.maximum(r, 1e-6) + a * r ** (p - 1) + b * r ** p + c * r ** (p + 1)


def radial_basis(dist: jnp.ndarray, cfg: DimeNetConfig) -> jnp.ndarray:
    """e_RBF(d): envelope(d/c) * sin(n pi d / c) (paper eq. 7)."""
    r = dist[:, None] / cfg.cutoff
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    return _envelope(r, cfg.envelope_p) * jnp.sin(jnp.pi * n * r)


def angular_basis(dist_kj: jnp.ndarray, angle: jnp.ndarray,
                  cfg: DimeNetConfig) -> jnp.ndarray:
    """a_SBF(d_kj, alpha): radial sines x cos(l alpha) harmonics -> [T, S*R]."""
    r = dist_kj[:, None] / cfg.cutoff
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    rad = _envelope(r, cfg.envelope_p) * jnp.sin(jnp.pi * n * r)   # [T, R]
    l = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])                      # [T, S]
    return (ang[:, :, None] * rad[:, None, :]).reshape(dist_kj.shape[0], -1)


def forward(params: dict, batch: dict, cfg: DimeNetConfig) -> jnp.ndarray:
    """Returns per-graph predictions [n_graphs, n_targets].

    batch: x[N,d_in], pos[N,3], edge_src/dst[E], triplet_kj/ji[T] (edge
    indices), graph_id[N], n_graphs (static int).
    """
    x = batch["x"].astype(cfg.dtype)
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    t_kj, t_ji = batch["triplet_kj"], batch["triplet_ji"]
    n_graphs = int(batch["n_graphs"])
    E = src.shape[0]

    # geometry
    dvec = L.gather(pos, dst) - L.gather(pos, src)         # edge vectors j->i
    dist = jnp.sqrt(jnp.sum(dvec * dvec, -1) + 1e-12)
    rbf = radial_basis(dist, cfg)                           # [E, R]

    # triplet angles alpha_kji between edges (k->j) and (j->i)
    v_ji = L.gather(dvec, t_ji)
    v_kj = L.gather(dvec, t_kj)
    cosa = jnp.sum(v_ji * -v_kj, -1) / (
        jnp.maximum(jnp.linalg.norm(v_ji, axis=-1), 1e-6)
        * jnp.maximum(jnp.linalg.norm(v_kj, axis=-1), 1e-6))
    angle = jnp.arccos(jnp.clip(cosa, -1 + 1e-6, 1 - 1e-6))
    d_kj = L.gather(dist[:, None], t_kj)[:, 0]
    sbf = angular_basis(d_kj, angle, cfg)                   # [T, S*R]

    # embedding block
    m = jnp.concatenate([L.gather(x, src), L.gather(x, dst), rbf.astype(cfg.dtype)],
                        axis=-1)
    m = jax.nn.silu(m @ params["emb_w"] + params["emb_b"])  # [E, d]

    out = jnp.zeros((x.shape[0], cfg.n_targets), cfg.dtype)
    for i in range(cfg.n_blocks):
        blk = params[f"block{i}"]
        # directional message: bilinear over the angular basis
        m_kj = L.gather(m, t_kj)                            # [T, d]
        tt = (m_kj @ blk["down_w"]) * (sbf.astype(cfg.dtype) @ blk["sbf_w"])
        agg = L.scatter_sum(tt, t_ji, E)                    # [E, nb] -> edges
        upd = agg @ blk["up_w"] + (rbf.astype(cfg.dtype) @ blk["rbf_w"]) * m
        m = m + jax.nn.silu(
            jax.nn.silu(upd @ blk["self_w"] + blk["self_b"]) @ blk["out_w"]
            + blk["out_b"])
        # output block: edges -> nodes -> per-block target contribution
        node = L.scatter_sum(m, dst, x.shape[0])
        out = out + node @ blk["head_w"]

    # per-graph readout
    gid = batch["graph_id"]
    valid = gid >= 0
    return jax.ops.segment_sum(jnp.where(valid[:, None], out, 0),
                               jnp.where(valid, gid, 0), num_segments=n_graphs)


def loss_fn(params: dict, batch: dict, cfg: DimeNetConfig) -> jnp.ndarray:
    pred = forward(params, batch, cfg)
    err = (pred - batch["targets"].astype(pred.dtype)) ** 2
    return jnp.mean(err.astype(jnp.float32))
