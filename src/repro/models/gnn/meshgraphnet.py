"""MeshGraphNet [arXiv:2010.03409] — learned mesh-based simulation.

Config: n_layers=15, d_hidden=128, sum aggregation, 2-layer MLPs.
Encode-Process-Decode: node/edge encoders, 15 graph-net blocks with
residual edge+node updates, node decoder predicting dynamics targets.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import init_mlp, mlp
from repro.models.gnn import layers as L


@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 16
    d_edge_in: int = 8
    d_out: int = 3            # e.g. acceleration / velocity targets
    dtype: type = jnp.float32


def _mlp_sizes(cfg: MeshGraphNetConfig, d_in: int, d_out: int) -> list[int]:
    return [d_in] + [cfg.d_hidden] * (cfg.mlp_layers - 1) + [d_out]


def _names(cfg: MeshGraphNetConfig) -> list[str]:
    return [f"l{i}" for i in range(cfg.mlp_layers)]


def init_params(cfg: MeshGraphNetConfig, key: jax.Array) -> dict:
    names = _names(cfg)
    p: dict = {}
    key, k1, k2, k3 = jax.random.split(key, 4)
    p["node_enc"] = init_mlp(k1, _mlp_sizes(cfg, cfg.d_node_in, cfg.d_hidden),
                             names, cfg.dtype)
    p["edge_enc"] = init_mlp(k2, _mlp_sizes(cfg, cfg.d_edge_in, cfg.d_hidden),
                             names, cfg.dtype)
    p["decoder"] = init_mlp(k3, _mlp_sizes(cfg, cfg.d_hidden, cfg.d_out),
                            names, cfg.dtype)
    for i in range(cfg.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        p[f"edge_mlp{i}"] = init_mlp(
            k1, _mlp_sizes(cfg, 3 * cfg.d_hidden, cfg.d_hidden), names, cfg.dtype)
        p[f"node_mlp{i}"] = init_mlp(
            k2, _mlp_sizes(cfg, 2 * cfg.d_hidden, cfg.d_hidden), names, cfg.dtype)
    return p


def forward(params: dict, batch: dict, cfg: MeshGraphNetConfig) -> jnp.ndarray:
    names = _names(cfg)
    x = batch["x"].astype(cfg.dtype)
    e = batch["edge_attr"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]

    h = mlp(params["node_enc"], x, names, act=jax.nn.relu)
    he = mlp(params["edge_enc"], e, names, act=jax.nn.relu)
    for i in range(cfg.n_layers):
        cat = jnp.concatenate([he, L.gather(h, src), L.gather(h, dst)], axis=-1)
        he = he + mlp(params[f"edge_mlp{i}"], cat, names, act=jax.nn.relu)
        agg = L.scatter_sum(he, dst, n)                    # sum aggregator
        h = h + mlp(params[f"node_mlp{i}"],
                    jnp.concatenate([h, agg], axis=-1), names, act=jax.nn.relu)
    return mlp(params["decoder"], h, names, act=jax.nn.relu)


def loss_fn(params: dict, batch: dict, cfg: MeshGraphNetConfig) -> jnp.ndarray:
    pred = forward(params, batch, cfg)
    err = (pred - batch["targets"].astype(pred.dtype)) ** 2
    mask = batch.get("node_mask")
    if mask is not None:
        err = jnp.where(mask[:, None], err, 0)
        return err.astype(jnp.float32).sum() / jnp.maximum(mask.sum() * pred.shape[-1], 1)
    return jnp.mean(err.astype(jnp.float32))
