"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Config: n_layers=4, d_hidden=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation.  Multi-aggregator regime:
4 parallel segment reductions x 3 degree scalers -> 12 concatenated views
-> linear tower, residual connections.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy_loss, dense_init
from repro.models.gnn import layers as L


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 64
    n_classes: int = 10
    avg_log_degree: float = 2.0   # delta: dataset mean of log(deg+1)
    dtype: type = jnp.float32


def init_params(cfg: PNAConfig, key: jax.Array) -> dict:
    params = {}
    key, k = jax.random.split(key)
    params["enc_w"] = dense_init(k, (cfg.d_in, cfg.d_hidden), dtype=cfg.dtype)
    params["enc_b"] = jnp.zeros((cfg.d_hidden,), cfg.dtype)
    for i in range(cfg.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        # pre-message MLP on (h_src || h_dst) and post-aggregation tower
        params[f"msg_w{i}"] = dense_init(k1, (2 * cfg.d_hidden, cfg.d_hidden),
                                         dtype=cfg.dtype)
        params[f"msg_b{i}"] = jnp.zeros((cfg.d_hidden,), cfg.dtype)
        params[f"tower_w{i}"] = dense_init(
            k2, ((12 + 1) * cfg.d_hidden, cfg.d_hidden), dtype=cfg.dtype)
        params[f"tower_b{i}"] = jnp.zeros((cfg.d_hidden,), cfg.dtype)
    key, k = jax.random.split(key)
    params["head_w"] = dense_init(k, (cfg.d_hidden, cfg.n_classes), dtype=cfg.dtype)
    params["head_b"] = jnp.zeros((cfg.n_classes,), cfg.dtype)
    return params


def forward(params: dict, batch: dict, cfg: PNAConfig) -> jnp.ndarray:
    x = batch["x"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    deg = L.degree(dst, n)
    # scalers (PNA eq. 5): identity, amplification, attenuation
    logd = jnp.log(deg + 1.0)
    amp = (logd / cfg.avg_log_degree)[:, None]
    att = (cfg.avg_log_degree / jnp.maximum(logd, 1e-2))[:, None]

    x = x @ params["enc_w"] + params["enc_b"]
    for i in range(cfg.n_layers):
        m_in = jnp.concatenate([L.gather(x, src), L.gather(x, dst)], axis=-1)
        msgs = jax.nn.relu(m_in @ params[f"msg_w{i}"] + params[f"msg_b{i}"])
        aggs = [L.scatter_mean(msgs, dst, n), L.scatter_max(msgs, dst, n),
                L.scatter_min(msgs, dst, n), L.scatter_std(msgs, dst, n)]
        views = []
        for a in aggs:
            views += [a, a * amp, a * att]
        h = jnp.concatenate([x] + views, axis=-1)
        x = x + jax.nn.relu(h @ params[f"tower_w{i}"] + params[f"tower_b{i}"])
    return x @ params["head_w"] + params["head_b"]


def loss_fn(params: dict, batch: dict, cfg: PNAConfig) -> jnp.ndarray:
    logits = forward(params, batch, cfg)
    labels = jnp.where(batch["label_mask"], batch["labels"], -100)
    return cross_entropy_loss(logits, labels)
