"""GNN message-passing primitives over padded edge lists.

JAX sparse is BCOO-only, so message passing is built on
``jax.ops.segment_sum`` / ``segment_max`` over an edge-index -> node
scatter (system-prompt requirement — this IS part of the system).  Edge
lists are padded with ``-1`` (dropped by masking); all shapes static.

The blocked Pallas kernel (kernels/segment_sum) implements the same
contract for the small-N regimes; ``scatter_sum(..., use_kernel=True)``
switches it in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """x[idx] with idx == -1 -> zeros (padding)."""
    safe = jnp.maximum(idx, 0)
    out = x[safe]
    return jnp.where((idx >= 0)[:, None], out, 0)


def scatter_sum(messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int,
                *, use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels.segment_sum import segment_sum as seg_kernel
        return seg_kernel(messages, dst.astype(jnp.int32), n_nodes)
    valid = dst >= 0
    safe = jnp.where(valid, dst, 0)
    msgs = jnp.where(valid[:, None], messages, 0)
    return jax.ops.segment_sum(msgs, safe, num_segments=n_nodes)


def scatter_mean(messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    s = scatter_sum(messages, dst, n_nodes)
    d = degree(dst, n_nodes)
    return s / jnp.maximum(d, 1)[:, None]


def scatter_max(messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int,
                neutral: float = -1e30) -> jnp.ndarray:
    valid = dst >= 0
    safe = jnp.where(valid, dst, 0)
    msgs = jnp.where(valid[:, None], messages, neutral)
    out = jax.ops.segment_max(msgs, safe, num_segments=n_nodes)
    return jnp.where(out <= neutral / 2, 0.0, out)


def scatter_min(messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    return -scatter_max(-messages, dst, n_nodes)


def degree(dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    valid = (dst >= 0).astype(jnp.float32)
    safe = jnp.where(dst >= 0, dst, 0)
    return jax.ops.segment_sum(valid, safe, num_segments=n_nodes)


def scatter_std(messages: jnp.ndarray, dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    mu = scatter_mean(messages, dst, n_nodes)
    mu2 = scatter_mean(jnp.square(messages), dst, n_nodes)
    return jnp.sqrt(jnp.maximum(mu2 - jnp.square(mu), 0) + 1e-5)
