"""DIN — Deep Interest Network [arXiv:1706.06978].

Config: embed_dim=18, seq_len=100, attention MLP 80-40, final MLP 200-80,
target-attention interaction.

The hot path is the **embedding lookup** over huge sparse tables
(item table 10M x 18, category table 10k x 18, row-sharded over the model
axis on a pod).  JAX has no native EmbeddingBag — the bag here is
``jnp.take`` + masked mean over the behaviour sequence, and the history/
candidate ID streams are CompBin-packed on storage (3 bytes per ID for a
10M-item catalog — DESIGN.md §2 beyond-paper application).

Target attention (the paper's contribution): per history item j,
  a_j = MLP([e_j, e_c, e_j - e_c, e_j * e_c]) -> scalar
with the candidate embedding e_c; the user interest is sum_j a_j e_j
(un-normalized, as in the paper).  ``score_candidates`` broadcasts one
user's history against N candidates for retrieval scoring.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, init_mlp, mlp


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    n_items: int = 10_000_000
    n_cates: int = 10_000
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    dtype: type = jnp.float32

    @property
    def d_item(self) -> int:          # item embedding || category embedding
        return 2 * self.embed_dim


def init_params(cfg: DINConfig, key: jax.Array) -> dict:
    keys = iter(jax.random.split(key, 8))
    d = cfg.d_item
    attn_sizes = [4 * d, *cfg.attn_mlp, 1]
    attn_names = [f"a{i}" for i in range(len(attn_sizes) - 1)]
    mlp_sizes = [3 * d, *cfg.mlp, 1]
    mlp_names = [f"m{i}" for i in range(len(mlp_sizes) - 1)]
    return {
        "item_table": dense_init(next(keys), (cfg.n_items, cfg.embed_dim),
                                 scale=0.01, dtype=cfg.dtype),
        "cate_table": dense_init(next(keys), (cfg.n_cates, cfg.embed_dim),
                                 scale=0.01, dtype=cfg.dtype),
        "attn": init_mlp(next(keys), attn_sizes, attn_names, cfg.dtype),
        "mlp": init_mlp(next(keys), mlp_sizes, mlp_names, cfg.dtype),
    }


def _attn_names(cfg: DINConfig) -> list[str]:
    return [f"a{i}" for i in range(len(cfg.attn_mlp) + 1)]


def _mlp_names(cfg: DINConfig) -> list[str]:
    return [f"m{i}" for i in range(len(cfg.mlp) + 1)]


def embed_items(params: dict, item_ids: jnp.ndarray, cate_ids: jnp.ndarray
                ) -> jnp.ndarray:
    """[..., ] ids -> [..., 2*embed_dim]; id == -1 -> zeros (padding)."""
    safe_i = jnp.maximum(item_ids, 0)
    safe_c = jnp.maximum(cate_ids, 0)
    e = jnp.concatenate([params["item_table"][safe_i],
                         params["cate_table"][safe_c]], axis=-1)
    return jnp.where((item_ids >= 0)[..., None], e, 0)


def target_attention(params: dict, hist: jnp.ndarray, cand: jnp.ndarray,
                     mask: jnp.ndarray, cfg: DINConfig) -> jnp.ndarray:
    """hist: [B, S, d]; cand: [B, d]; mask: [B, S] -> interest [B, d]."""
    c = jnp.broadcast_to(cand[:, None, :], hist.shape)
    a_in = jnp.concatenate([hist, c, hist - c, hist * c], axis=-1)
    scores = mlp(params["attn"], a_in, _attn_names(cfg), act=jax.nn.sigmoid)
    scores = jnp.where(mask[..., None], scores, 0.0)       # no softmax (paper)
    return jnp.sum(scores * hist, axis=1)


def forward(params: dict, batch: dict, cfg: DINConfig) -> jnp.ndarray:
    """CTR logits [B].  batch: hist_items/hist_cates [B,S], cand_item/
    cand_cate [B]; padding ids == -1."""
    hist = embed_items(params, batch["hist_items"], batch["hist_cates"])
    cand = embed_items(params, batch["cand_item"], batch["cand_cate"])
    mask = batch["hist_items"] >= 0
    interest = target_attention(params, hist, cand, mask, cfg)
    feats = jnp.concatenate([interest, cand, interest * cand], axis=-1)
    return mlp(params["mlp"], feats, _mlp_names(cfg))[..., 0]


def score_candidates(params: dict, batch: dict, cfg: DINConfig) -> jnp.ndarray:
    """Retrieval scoring: one user, N candidates -> logits [N].

    batch: hist_items/hist_cates [S], cand_items/cand_cates [N].  The
    target attention is recomputed per candidate (that is DIN's point),
    batched over N as one [N, S, 4d] MLP sweep — not a loop.
    """
    hist = embed_items(params, batch["hist_items"], batch["hist_cates"])  # [S,d]
    cands = embed_items(params, batch["cand_items"], batch["cand_cates"])  # [N,d]
    mask = batch["hist_items"] >= 0
    N, S = cands.shape[0], hist.shape[0]
    hist_b = jnp.broadcast_to(hist[None], (N, S, hist.shape[-1]))
    interest = target_attention(params, hist_b, cands,
                                jnp.broadcast_to(mask[None], (N, S)), cfg)
    feats = jnp.concatenate([interest, cands, interest * cands], axis=-1)
    return mlp(params["mlp"], feats, _mlp_names(cfg))[..., 0]


def loss_fn(params: dict, batch: dict, cfg: DINConfig) -> jnp.ndarray:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"].astype(jnp.float32)
    # sigmoid binary CE
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
