"""Decoder-only transformer family (dense + MoE) — pure functional JAX.

Covers all five assigned LM architectures through one config:

  * GQA attention (n_kv_heads <= n_heads), optional QKV bias (qwen2),
    RoPE with partial rotary (stablelm rope_pct=0.25), RMSNorm or LayerNorm.
  * Dense SwiGLU FFN, or MoE FFN with routed top-k experts + optional
    shared experts with a sigmoid gate (qwen2-moe), capacity-based
    dispatch (GShard-style, sort + scatter — static shapes for AOT).
  * ``jax.lax.scan`` over layers (small HLO, fast 512-device compiles) with
    optional per-layer remat.
  * Three entry points: ``train_step_loss`` (causal LM loss), ``prefill``
    (builds the KV cache) and ``decode_step`` (one token against the cache)
    — the latter two lower the ``serve_step`` shapes of the dry-run.

Attention backends: ``dense`` (materialized scores) or ``chunked`` —
an online-softmax scan over KV chunks (FlashAttention dataflow expressed
in pure jnp, so it compiles for any backend; on real TPU the Pallas kernel
in kernels/flash_attention implements the same contract).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy_loss, dense_init, layer_norm, rms_norm

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "transformer"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 256
    vocab: int = 1024
    max_seq: int = 4096
    qkv_bias: bool = False
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    rope_pct: float = 1.0
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0               # routed experts (router logits)
    n_experts_padded: int = 0        # physical expert slots (EP divisibility)
    top_k: int = 0
    moe_d_ff: int = 0                # per-routed-expert hidden
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    shared_expert_gate: bool = False  # qwen2-moe sigmoid gate on shared out
    router_norm_topk: bool = False    # dbrx renormalizes top-k weights
    capacity_factor: float = 1.25
    lb_loss_coef: float = 0.01
    moe_dispatch: str = "scatter"     # "scatter" (GShard-style value
    #   scatter; the paper-faithful baseline) | "gather" (slot->token
    #   gather formulation: value-sized ops are all gathers + one masked
    #   psum-combine; only int32 index arrays are scattered — measured
    #   in EXPERIMENTS.md §Perf to cut the dispatch collectives)
    # --- runtime ---
    dtype: Any = jnp.bfloat16
    attn_impl: str = "chunked"        # "dense" | "chunked"
    attn_chunk: int = 1024
    remat: bool = True
    moe_ep_axis: Optional[str] = None  # mesh axis for the (E, C, d) dispatch
    #   buffer (expert parallelism); set by the launcher, e.g. "model"
    # Unrolled (python-loop) execution. XLA's cost_analysis counts a
    # while-loop body ONCE, not x trip count (verified in EXPERIMENTS.md
    # §Dry-run), so the dry-run lowers unrolled programs for exact
    # FLOP/byte accounting; unrolling also enables causal block skipping
    # in the chunked attention (fully-masked tiles never emitted).
    unroll_layers: bool = False
    attn_unroll: bool = False
    # Keep the post-softmax probability tile in bf16 for the PV matmul
    # (running max/denominator stay f32): halves the dominant attention
    # tile traffic at <=1e-2 relative error (FlashAttention-2 keeps the
    # same compromise on TPU/GPU kernels).
    attn_p_bf16: bool = False
    # Chunked cross-entropy: compute logits + CE per sequence chunk
    # (python loop, checkpointed) instead of materializing the full
    # (B, S, V/TP) f32 logits (+ iota mask) at once. 0 = disabled.
    ce_chunk: int = 0
    # Megatron-style head tensor-parallelism. Projections are stored 4-D
    # (d, H, dh) and sharded on the HEAD axis, which GSPMD pads when H
    # doesn't divide the model axis (smollm: 15 heads over 16 ranks) —
    # imbalance <= 1 head, no weight replication, no per-layer batch
    # reshard.  When n_kv_heads doesn't divide the axis, K/V are expanded
    # to per-q-head copies before attention (attn_kv_expand) so the S^2
    # attention core is sharded by q-heads instead of idling ranks.
    attn_head_axis: Optional[str] = None
    attn_kv_expand: bool = False
    # (kept for §Perf ablation: redistribute batch over (data x model) for
    # the attention section instead of head TP — measured pathological,
    # see EXPERIMENTS.md)
    attn_batch_shard_axes: Optional[tuple] = None
    batch_axes: Optional[tuple] = None

    @property
    def e_pad(self) -> int:
        return self.n_experts_padded or self.n_experts

    def n_params(self) -> int:
        """Total parameter count (padding experts excluded)."""
        d, H, Hk, dh, f = (self.d_model, self.n_heads, self.n_kv_heads,
                           self.d_head, self.d_ff)
        attn = d * (H * dh) + 2 * d * (Hk * dh) + (H * dh) * d
        if self.qkv_bias:
            attn += (H + 2 * Hk) * dh
        if self.moe:
            ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            if self.n_shared_experts:
                ffn += 3 * d * self.shared_d_ff + (d if self.shared_expert_gate else 0)
        else:
            ffn = 3 * d * f
        norms = 2 * d * (2 if self.norm == "layernorm" else 1)
        per_layer = attn + ffn + norms
        embed = self.vocab * d
        head = 0 if self.tie_embeddings else d * self.vocab
        return self.n_layers * per_layer + embed + head + d

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top_k routed + shared)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        routed_all = self.n_experts * 3 * d * self.moe_d_ff
        routed_act = self.top_k * 3 * d * self.moe_d_ff
        return self.n_params() - self.n_layers * (routed_all - routed_act)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    L = cfg.n_layers
    keys = iter(jax.random.split(key, 32))
    dt = cfg.dtype

    def W(k, *shape, scale=None):
        return dense_init(k, shape, scale=scale, dtype=dt)

    layers: Params = {
        "attn_norm_scale": jnp.ones((L, d), dt),
        "ffn_norm_scale": jnp.ones((L, d), dt),
        # 4-D projections: head axis explicit so TP shards whole heads
        "wq": W(next(keys), L, d, H, dh),
        "wk": W(next(keys), L, d, Hk, dh),
        "wv": W(next(keys), L, d, Hk, dh),
        "wo": W(next(keys), L, H, dh, d),
    }
    if cfg.norm == "layernorm":
        layers["attn_norm_bias"] = jnp.zeros((L, d), dt)
        layers["ffn_norm_bias"] = jnp.zeros((L, d), dt)
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, H, dh), dt)
        layers["bk"] = jnp.zeros((L, Hk, dh), dt)
        layers["bv"] = jnp.zeros((L, Hk, dh), dt)
    if cfg.moe:
        E, fe = cfg.e_pad, cfg.moe_d_ff
        layers["router"] = dense_init(next(keys), (L, d, cfg.n_experts),
                                      dtype=jnp.float32)  # router in f32
        layers["we_gate"] = W(next(keys), L, E, d, fe)
        layers["we_up"] = W(next(keys), L, E, d, fe)
        layers["we_down"] = W(next(keys), L, E, fe, d)
        if cfg.n_shared_experts:
            fs = cfg.shared_d_ff
            layers["ws_gate"] = W(next(keys), L, d, fs)
            layers["ws_up"] = W(next(keys), L, d, fs)
            layers["ws_down"] = W(next(keys), L, fs, d)
            if cfg.shared_expert_gate:
                layers["shared_gate"] = W(next(keys), L, d, 1)
    else:
        f = cfg.d_ff
        layers["w_gate"] = W(next(keys), L, d, f)
        layers["w_up"] = W(next(keys), L, d, f)
        layers["w_down"] = W(next(keys), L, f, d)

    params: Params = {
        "embed": dense_init(next(keys), (cfg.vocab, d), scale=0.02, dtype=dt),
        "final_norm_scale": jnp.ones((d,), dt),
        "layers": layers,
    }
    if cfg.norm == "layernorm":
        params["final_norm_bias"] = jnp.zeros((d,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = W(next(keys), d, cfg.vocab)
    return params


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rope_freqs(cfg: TransformerConfig) -> jnp.ndarray:
    rot = int(cfg.d_head * cfg.rope_pct) // 2 * 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: TransformerConfig
               ) -> jnp.ndarray:
    """x: [B, S, H, dh]; positions: [B, S] (absolute). Partial rotary."""
    freqs = _rope_freqs(cfg)                       # [rot/2]
    rot = 2 * freqs.shape[0]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,rot/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# attention backends
# ---------------------------------------------------------------------------

def _dense_attention(q, k, v, *, causal: bool, q_offset) -> jnp.ndarray:
    """q: [B,S,H,dh]; k,v: [B,T,Hk,dh].  q_offset: absolute position of
    q[0] minus absolute position of k[0] (for caches/prefill)."""
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qh = q.reshape(B, S, Hk, g, dh)
    scores = jnp.einsum("bshgd,bthd->bhgst", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * (dh ** -0.5)
    if causal:
        qpos = jnp.arange(S)[:, None] + q_offset
        kpos = jnp.arange(T)[None, :]
        mask = kpos <= qpos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal: bool, q_offset, chunk: int,
                       unroll: bool = False, p_bf16: bool = False) -> jnp.ndarray:
    """Online-softmax over (q-chunk outer, kv-chunk inner) scans — the
    FlashAttention dataflow in pure jnp.

    Memory shape: the outer scan over q chunks emits its result as a scan
    *output* (no giant carry), and the inner kv scan carries only the
    (B, Hk, g, bq, dh) running state, so the peak live set is one
    (bq x bk) score tile + one q-chunk state — O(S·chunk), not O(S²).
    The outer body is checkpointed: a layer's backward recomputes one
    q-chunk at a time."""
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    bq = min(chunk, S)
    nq = -(-S // bq)
    bk = min(chunk, T)
    nk = -(-T // bk)
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * bk - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * bk - T), (0, 0), (0, 0)))
    # (n, B, b, Hk, {g,}, dh) chunked layouts, f32 compute
    qc = qp.reshape(B, nq, bq, Hk, g, dh).transpose(1, 0, 2, 3, 4, 5)
    kc = kp.reshape(B, nk, bk, Hk, dh).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nk, bk, Hk, dh).transpose(1, 0, 2, 3, 4)

    def kv_body(carry, kxs, qb, qpos):
        m, l, acc = carry
        ki, kb, vb = kxs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb.astype(jnp.float32)
                       ) * (dh ** -0.5)
        kpos = ki * bk + jnp.arange(bk)[None, :]
        mask = kpos < T
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        if p_bf16:
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.bfloat16),
                            vb.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        acc = alpha[..., None] * acc + pv
        return (m_new, l, acc), None

    def q_init(qi_static_or_traced):
        return (jnp.full((B, Hk, g, bq), -1e30, jnp.float32),
                jnp.zeros((B, Hk, g, bq), jnp.float32),
                jnp.zeros((B, Hk, g, bq, dh), jnp.float32))

    def finish(m, l, acc):
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    if unroll:
        # python-loop tiles: exact HLO cost accounting + causal block skip
        # (fully-masked tiles are never emitted). Requires static q_offset.
        off = int(q_offset)

        def tile(qb, qpos, ki, carry):
            return kv_body(carry, (ki, kc[ki], vc[ki]), qb, qpos)[0]

        tile = jax.checkpoint(tile)  # one live (bq x bk) tile per backward
        outs = []
        for qi in range(nq):
            qb = qc[qi].astype(jnp.float32)
            qpos = qi * bq + jnp.arange(bq)[:, None] + off
            carry = q_init(qi)
            q_hi = qi * bq + bq - 1 + off   # highest query position
            for ki in range(nk):
                if causal and ki * bk > q_hi:
                    continue                 # block fully in the future
                carry = tile(qb, qpos, ki, carry)
            outs.append(finish(*carry))
        ys = jnp.stack(outs)
    else:
        def q_body(_, xs):
            qi, qb = xs
            qb = qb.astype(jnp.float32)                 # [B,bq,Hk,g,dh]
            qpos = qi * bq + jnp.arange(bq)[:, None] + q_offset
            (m, l, acc), _ = jax.lax.scan(
                functools.partial(kv_body, qb=qb, qpos=qpos),
                q_init(qi), (jnp.arange(nk), kc, vc))
            return None, finish(m, l, acc)

        _, ys = jax.lax.scan(jax.checkpoint(q_body), None,
                             (jnp.arange(nq), qc))
    # ys: [nq, B, Hk, g, bq, dh] -> [B, S, H, dh]
    out = ys.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, H, dh)
    return out[:, :S]


def attention(q, k, v, cfg: TransformerConfig, *, causal: bool, q_offset=0):
    if cfg.attn_impl == "dense" or q.shape[1] == 1:
        return _dense_attention(q, k, v, causal=causal, q_offset=q_offset)
    return _chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                              chunk=cfg.attn_chunk, unroll=cfg.attn_unroll,
                              p_bf16=cfg.attn_p_bf16)


# ---------------------------------------------------------------------------
# FFN blocks
# ---------------------------------------------------------------------------

def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def moe_ffn(x: jnp.ndarray, lp: Params, cfg: TransformerConfig, *,
            no_drop: bool = False, eval_mode: bool = False
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Routed top-k MoE with capacity-based dispatch (sort + scatter).

    x: [T, d] (flattened tokens).  Returns (out [T, d], lb_loss scalar).
    ``no_drop=True`` (decode: T is small) sizes the buffer for the worst
    case so no assignment is ever dropped; ``eval_mode=True`` (prefill)
    uses a 2x capacity factor — the no-drop bound C = T*K at prefill T ~ 1M
    would inflate expert compute Ep-fold (measured 16x on dbrx).
    """
    T, d = x.shape
    E, Ep, K = cfg.n_experts, cfg.e_pad, cfg.top_k
    if no_drop:
        C = T * K  # worst case: every token routes to one expert
    elif eval_mode:
        C = min(T * K, max(1, int(2.0 * T * K / Ep)))
    else:
        C = max(1, int(cfg.capacity_factor * T * K / Ep))

    logits = (x.astype(jnp.float32) @ lp["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                        # [T, K]
    if cfg.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss over the *routed* experts.
    me = probs.mean(0)                                          # [E]
    ce = jnp.zeros(E, jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    lb_loss = cfg.lb_loss_coef * E * jnp.sum(me * ce)

    expert_flat = idx.reshape(-1)                               # [T*K]
    tok_flat = jnp.repeat(jnp.arange(T), K)                     # [T*K]
    order = jnp.argsort(expert_flat)                            # stable
    e_sorted = expert_flat[order]
    t_sorted = tok_flat[order]
    # position of each assignment within its expert
    counts = jnp.zeros(Ep, jnp.int32).at[e_sorted].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[e_sorted]
    keep = pos < C                                              # overflow drops

    from jax.sharding import PartitionSpec as _P

    if cfg.moe_dispatch == "gather":
        # slot -> token GATHER: buf[e, c] = x[token filling slot (e, c)].
        # No (T*K, d) value scatter exists in the program; the only
        # scatters are int32 index arrays (1000x smaller).
        slot_assign = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        valid_slot = jnp.arange(C)[None, :] < counts[:, None]   # [Ep, C]
        slot_tok = t_sorted[jnp.clip(slot_assign, 0, T * K - 1)]
        buf = jnp.where(valid_slot[..., None], x[slot_tok], 0)
    else:
        buf = jnp.zeros((Ep, C, d), x.dtype)
        # overflow assignments carry pos >= C -> out of bounds -> dropped
        buf = buf.at[e_sorted, pos].set(x[t_sorted], mode="drop")
    if cfg.moe_ep_axis is not None:
        # expert parallelism: dispatch buffer lives expert-sharded; the
        # token->expert exchange becomes the EP collective in the HLO
        buf = jax.lax.with_sharding_constraint(
            buf, _P(cfg.moe_ep_axis, None, None))
    # per-expert SwiGLU on the MXU: [E,C,d] x [E,d,f]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, lp["we_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, lp["we_up"])
    yb = jnp.einsum("ecf,efd->ecd", h, lp["we_down"])           # [E,C,d]

    if cfg.moe_dispatch == "gather":
        # combine by GATHER in original assignment order + reduce over K
        pos_unsorted = jnp.zeros(T * K, jnp.int32).at[order].set(pos)
        e_unsorted = expert_flat
        y_flat = yb[e_unsorted, jnp.minimum(pos_unsorted, C - 1)]  # [T*K, d]
        keep_unsorted = pos_unsorted < C
        w = gates.reshape(-1) * keep_unsorted                      # [T*K]
        out = jnp.sum(y_flat.reshape(T, K, d).astype(jnp.float32)
                      * w.reshape(T, K)[..., None], axis=1)
    else:
        y_assign = yb[e_sorted, jnp.minimum(pos, C - 1)]        # [T*K, d]
        gate_sorted = gates.reshape(-1)[order] * keep
        out = jnp.zeros((T, d), jnp.float32).at[t_sorted].add(
            y_assign.astype(jnp.float32) * gate_sorted[:, None])

    if cfg.n_shared_experts:
        shared = swiglu(x, lp["ws_gate"], lp["ws_up"], lp["ws_down"])
        if cfg.shared_expert_gate:
            shared = shared * jax.nn.sigmoid(
                x.astype(jnp.float32) @ lp["shared_gate"]).astype(shared.dtype)
        out = out + shared.astype(jnp.float32)
    return out.astype(x.dtype), lb_loss


# ---------------------------------------------------------------------------
# transformer blocks / forward
# ---------------------------------------------------------------------------

def _norm(x, scale, bias, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, scale, bias)
    return rms_norm(x, scale)


def _wsc(x, axes_first, ndim):
    """with_sharding_constraint on the leading (batch) dim."""
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(
        x, _P(axes_first, *([None] * (ndim - 1))))


def _layer(x, lp: Params, cfg: TransformerConfig, positions, cache_k, cache_v,
           cache_len):
    """One transformer block.  cache_*: [B, Smax, Hk, dh] or None."""
    B, S, d = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    h = _norm(x, lp["attn_norm_scale"], lp.get("attn_norm_bias"), cfg)
    if cfg.attn_batch_shard_axes and cache_k is None:
        # §Perf ablation path: spread the batch over the idle model axis
        # instead of head TP (measured pathological — kept for comparison).
        h = _wsc(h, cfg.attn_batch_shard_axes, 3)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)

    if cache_k is not None:
        # functional cache update at [.., cache_len : cache_len+S, ..];
        # the cache stores UNexpanded KV heads
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, cache_len, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, cache_len, 0, 0))
        kk, vv = cache_k, cache_v
        q_offset = cache_len
    else:
        kk, vv = k, v
        q_offset = 0

    # Head-TP activation slicing applies to every S > 1 attention
    # (training AND prefill); single-token decode attention is tiny and
    # stays on the cache's own sharding.
    if S > 1:
        if cfg.attn_kv_expand:
            # n_kv_heads doesn't divide the TP axis: expand K/V to q-heads
            # so the S^2 core shards by q-head (no idle ranks)
            kk = jnp.repeat(kk, H // Hk, axis=2)
            vv = jnp.repeat(vv, H // Hk, axis=2)
        if cfg.attn_head_axis is not None:
            from jax.sharding import PartitionSpec as _P
            b_ax = tuple(cfg.batch_axes) if cfg.batch_axes else None
            hspec = _P(b_ax, None, cfg.attn_head_axis, None)
            q = jax.lax.with_sharding_constraint(q, hspec)
            kk = jax.lax.with_sharding_constraint(kk, hspec)
            vv = jax.lax.with_sharding_constraint(vv, hspec)

    attn = attention(q, kk, vv, cfg, causal=True, q_offset=q_offset)
    attn_out = jnp.einsum("bshk,hkd->bsd", attn, lp["wo"])
    if cfg.attn_batch_shard_axes and cache_k is None:
        attn_out = _wsc(attn_out, cfg.batch_axes, 3)
    x = x + attn_out

    h = _norm(x, lp["ffn_norm_scale"], lp.get("ffn_norm_bias"), cfg)
    if cfg.moe:
        serving = cache_k is not None
        y, lb = moe_ffn(h.reshape(B * S, d), lp, cfg,
                        no_drop=serving and B * S <= 4096,
                        eval_mode=serving)
        y = y.reshape(B, S, d)
    else:
        y, lb = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), 0.0
    return x + y, cache_k, cache_v, lb


def forward(params: Params, tokens: jnp.ndarray, cfg: TransformerConfig, *,
            cache: Optional[Params] = None) -> tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """tokens: [B, S] -> (logits [B, S, V], new_cache, lb_loss).

    With ``cache`` (dict: k/v [L, B, Smax, Hk, dh], len scalar) the call is a
    prefill (S > 1) or decode (S == 1) step at position ``cache["len"]``.
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    cache_len = cache["len"] if cache is not None else 0
    positions = jnp.arange(S)[None, :] + cache_len

    def body(carry, xs):
        x, lb_sum = carry
        if cache is not None:
            lp, ck, cv = xs
            x, ck, cv, lb = _layer(x, lp, cfg, positions, ck, cv, cache_len)
            return (x, lb_sum + lb), (ck, cv)
        lp = xs
        x, _, _, lb = _layer(x, lp, cfg, positions, None, None, 0)
        return (x, lb_sum + lb), None

    if cfg.unroll_layers:
        # python loop: exact per-layer HLO cost; remat per layer.
        # Caches in unrolled mode are LAYERED (a tuple of per-layer
        # arrays, see init_cache): each layer touches only its own
        # (B, S, Hk, dh) buffer — a stacked (L, ...) cache would need
        # full-buffer update ops whose cost counts L x the whole cache.
        body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body
        carry = (x, jnp.float32(0))
        new_k, new_v = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            if cache is not None:
                carry, (ck, cv) = body_fn(carry, (lp, cache["k"][i], cache["v"][i]))
                new_k.append(ck)
                new_v.append(cv)
            else:
                carry, _ = body_fn(carry, lp)
        x, lb_loss = carry
        ys = (tuple(new_k), tuple(new_v)) if cache is not None else None
    else:
        body_fn = jax.checkpoint(body) if (cfg.remat and cache is None) else body
        xs = (params["layers"], cache["k"], cache["v"]) if cache is not None \
            else params["layers"]
        (x, lb_loss), ys = jax.lax.scan(body_fn, (x, jnp.float32(0)), xs)

    x = _norm(x, params["final_norm_scale"], params.get("final_norm_bias"), cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"k": ys[0], "v": ys[1], "len": cache_len + S}
    return logits, new_cache, lb_loss


# ---------------------------------------------------------------------------
# entry points (lowered by the dry-run)
# ---------------------------------------------------------------------------

def loss_fn(params: Params, tokens: jnp.ndarray, labels: jnp.ndarray,
            cfg: TransformerConfig) -> jnp.ndarray:
    if not cfg.ce_chunk:
        logits, _, lb = forward(params, tokens, cfg)
        return cross_entropy_loss(logits, labels) + lb
    # chunked CE: head matmul + CE one sequence chunk at a time
    x, lb = forward_hidden(params, tokens, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    def chunk_nll(xc, lc):
        logits = xc @ head.astype(xc.dtype)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(lc.dtype, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota == lc[..., None], logits, 0), axis=-1)
        return jnp.sum(logz - gold)

    chunk_nll = jax.checkpoint(chunk_nll)
    B, S = tokens.shape
    c = cfg.ce_chunk
    total = jnp.float32(0)
    for s0 in range(0, S, c):
        total = total + chunk_nll(x[:, s0:s0 + c], labels[:, s0:s0 + c])
    return total / (B * S) + lb


def forward_hidden(params: Params, tokens: jnp.ndarray, cfg: TransformerConfig
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """forward() without the LM head: final hidden states + lb loss."""
    logits_unused = None
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        x, lb_sum = carry
        x, _, _, lb = _layer(x, lp, cfg, positions, None, None, 0)
        return (x, lb_sum + lb), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll_layers:
        carry = (x, jnp.float32(0))
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            carry, _ = body_fn(carry, lp)
        x, lb = carry
    else:
        (x, lb), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)),
                                  params["layers"])
    x = _norm(x, params["final_norm_scale"], params.get("final_norm_bias"), cfg)
    return x, lb


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    # "len" stays a python int so a fresh-cache prefill has a STATIC
    # q_offset (required by the unrolled attention's causal tile skip);
    # decode steps carry it as a traced scalar input instead.
    if cfg.unroll_layers:
        # layered cache: tuple of per-layer (B, S, Hk, dh) buffers
        shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
        return {"k": tuple(jnp.zeros(shape, dtype) for _ in range(cfg.n_layers)),
                "v": tuple(jnp.zeros(shape, dtype) for _ in range(cfg.n_layers)),
                "len": 0}
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": 0}


def prefill(params: Params, tokens: jnp.ndarray, cfg: TransformerConfig,
            max_len: Optional[int] = None) -> tuple[jnp.ndarray, Params]:
    """Build a KV cache from a prompt; returns (last-token logits, cache)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len or S)
    logits, cache, _ = forward(params, tokens, cfg, cache=cache)
    return logits[:, -1], cache


def decode_step(params: Params, tokens: jnp.ndarray, cache: Params,
                cfg: TransformerConfig) -> tuple[jnp.ndarray, Params]:
    """One-token decode: tokens [B, 1] -> (logits [B, V], updated cache)."""
    logits, cache, _ = forward(params, tokens, cfg, cache=cache)
    return logits[:, -1], cache
