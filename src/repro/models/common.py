"""Shared model building blocks (pure functional JAX — no flax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray | None,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std
            ).astype(dtype)


def mlp(params_prefix: dict, x: jnp.ndarray, names: list[str],
        act=jax.nn.relu, final_act=None) -> jnp.ndarray:
    """Apply a stack of dense layers ``names`` from a params dict holding
    ``{name}_w`` / ``{name}_b``."""
    for i, n in enumerate(names):
        x = x @ params_prefix[f"{n}_w"] + params_prefix[f"{n}_b"]
        if i < len(names) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def init_mlp(key, sizes: list[int], names: list[str], dtype=jnp.float32) -> dict:
    assert len(sizes) == len(names) + 1
    out = {}
    for i, n in enumerate(names):
        key, k1 = jax.random.split(key)
        out[f"{n}_w"] = dense_init(k1, (sizes[i], sizes[i + 1]), dtype=dtype)
        out[f"{n}_b"] = jnp.zeros((sizes[i + 1],), dtype)
    return out


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       ignore: int = -100) -> jnp.ndarray:
    """Mean token CE in f32; ``labels == ignore`` positions are masked.

    The gold logit is extracted with a fused mask-reduce (iota == label)
    rather than ``take_along_axis`` so a vocab-sharded logits tensor never
    gets all-gathered: both the logsumexp and the masked sum are plain
    reductions over the sharded vocab axis, which GSPMD turns into local
    reductions + a scalar psum."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore
    labels_safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(labels.dtype, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels_safe[..., None], logits, 0),
                   axis=-1)
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
