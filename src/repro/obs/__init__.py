"""Unified observability layer for the serving stack.

``repro.obs`` gives the stack one telemetry surface (see
``docs/observability.md``):

* :mod:`repro.obs.trace` — per-request span tracing with deterministic
  ids, an injectable (virtual) clock, and zero-cost no-op default;
* :mod:`repro.obs.metrics` — the bounded latency histogram every stats
  class retains, plus the registry / namespace / drift check;
* :mod:`repro.obs.report` — per-tier time attribution and the
  span-vs-stats conservation helpers.

This package never imports ``repro.*`` at module level (the stats
modules import it), keeping the dependency direction acyclic.
"""

from .metrics import (
    LatencyHistogram,
    MetricsRegistry,
    NAMESPACE,
    RATIO_SPECS,
    STATS_SOURCES,
    flatten_numeric,
    metrics_drift,
)
from .report import (
    attribution,
    event_counts,
    render_report,
    tier_times,
    verify_span_tree,
    window_close_counts,
)
from .trace import (
    NAMED_TIERS,
    NULL_TRACER,
    NullTracer,
    ROOT_TIERS,
    Span,
    SpanEvent,
    TraceContext,
    Tracer,
)

__all__ = [
    "LatencyHistogram", "MetricsRegistry", "NAMESPACE", "RATIO_SPECS",
    "STATS_SOURCES", "flatten_numeric", "metrics_drift",
    "attribution", "event_counts", "render_report", "tier_times",
    "verify_span_tree", "window_close_counts",
    "NAMED_TIERS", "NULL_TRACER", "NullTracer", "ROOT_TIERS",
    "Span", "SpanEvent", "TraceContext", "Tracer",
]
