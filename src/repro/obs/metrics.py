"""Metrics: bounded latency histogram, registry, namespace, drift check.

Three jobs, all serving the same invariant — every number the serving
stack can report has exactly one name, and folding numbers across
shards/tenants/hosts follows the same associative-merge contract the
``*Stats.merge()`` methods already obey:

* :class:`LatencyHistogram` — the fixed-size, merge-associative
  replacement for the raw per-batch latency lists ``QueryStats`` and
  ``TraversalStats`` used to retain (unbounded, and ``merge()``
  concatenated them untrimmed).  Log-spaced buckets (2% ratio) over
  [100ns, ~10^4 s]; quantiles interpolate within a bucket and clamp to
  the observed [min, max], so p50/p99 stay within ~2% of the exact
  list-based values the bench gates were tuned on (and are EXACT for
  constant distributions, which is what the virtual-clock unit tests
  pin).

* :class:`MetricsRegistry` — one flat namespace (``query.batches``,
  ``hotset.hits``, ``pgfuse.span_fetch_blocks``) every ``*Stats
  .as_dict()`` surface registers into.  Registering the same prefix
  again FOLDS: sum-kind keys add (matching each class's ``merge()``),
  ratio keys recompute from their merged parts (:data:`RATIO_SPECS`),
  and summary keys (quantiles, wall-clock) keep the max — an upper
  bound, the honest scalar fold for a quantile.  Exposition renders
  the registry as Prometheus text or a JSON snapshot.

* :data:`NAMESPACE` + :func:`metrics_drift` — the literal table of
  every registered key per prefix, diffed bidirectionally against the
  live ``as_dict()`` surfaces.  A stats field added without a
  namespace entry (or vice versa) fails
  ``.github/scripts/metrics_drift.py`` in the docs CI job, and the
  table in ``docs/observability.md`` is synced against it by
  ``tests/test_docs_sync.py``.
"""

from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Tuple

# -- bounded latency histogram ---------------------------------------------

#: lower edge of the first log bucket; values at or below it (including
#: zero) land in the underflow bucket whose range is [0, LOW]
HIST_LOW_S = 1e-7
#: geometric bucket width — also the worst-case relative quantile error
HIST_RATIO = 1.02
#: log-spaced bucket count; LOW * RATIO**N ≈ 1.05e4 s top edge
HIST_N_BUCKETS = 1280

_LOG_RATIO = math.log(HIST_RATIO)


class LatencyHistogram:
    """Fixed-size log-bucket histogram of nonnegative durations.

    Storage is a sparse ``{bucket_index: count}`` dict bounded by
    ``HIST_N_BUCKETS + 2`` entries (underflow 0, log buckets 1..N,
    overflow N+1), so memory is O(1) in the number of observations and
    :meth:`merge` (sum counts, min/max fold — integer and order-
    insensitive math only, deliberately no float ``total``) is EXACTLY
    associative and commutative — the property ``QueryStats.merge`` /
    ``TraversalStats.merge`` require of every field, and what lets the
    differential fuzzers pin fold results bit-for-bit.
    """

    __slots__ = ("counts", "n", "min_s", "max_s")

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.min_s = math.inf
        self.max_s = 0.0

    @staticmethod
    def _bucket(v: float) -> int:
        if v <= HIST_LOW_S:
            return 0
        i = 1 + int(math.log(v / HIST_LOW_S) / _LOG_RATIO)
        return i if i <= HIST_N_BUCKETS else HIST_N_BUCKETS + 1

    @staticmethod
    def _edges(i: int) -> Tuple[float, float]:
        """[lower, upper] value range of bucket ``i``."""
        if i == 0:
            return 0.0, HIST_LOW_S
        return (HIST_LOW_S * HIST_RATIO ** (i - 1),
                HIST_LOW_S * HIST_RATIO ** i)

    def add(self, v: float) -> None:
        v = float(v)
        i = self._bucket(v)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.n += 1
        if v < self.min_s:
            self.min_s = v
        if v > self.max_s:
            self.max_s = v

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        out = LatencyHistogram()
        out.counts = dict(self.counts)
        for i, c in other.counts.items():
            out.counts[i] = out.counts.get(i, 0) + c
        out.n = self.n + other.n
        out.min_s = min(self.min_s, other.min_s)
        out.max_s = max(self.max_s, other.max_s)
        return out

    def copy(self) -> "LatencyHistogram":
        return LatencyHistogram().merge(self)

    def quantile(self, q: float) -> float:
        """q-quantile estimate (numpy 'linear' rank convention), within
        one bucket width (~2%) of the exact list-based value and
        clamped to the observed [min, max] — exact when all
        observations are equal."""
        if not self.n:
            return 0.0
        rank = q * (self.n - 1)
        c = 0
        for i in sorted(self.counts):
            cnt = self.counts[i]
            if c + cnt > rank:
                lo, hi = self._edges(i)
                pos = (rank - c + 0.5) / cnt     # mid-rank within bucket
                v = lo + (hi - lo) * min(pos, 1.0)
                return min(max(v, self.min_s), self.max_s)
            c += cnt
        return self.max_s

    def __eq__(self, other) -> bool:
        return (isinstance(other, LatencyHistogram)
                and self.counts == other.counts and self.n == other.n
                and self.min_s == other.min_s and self.max_s == other.max_s)

    def __repr__(self) -> str:
        return (f"LatencyHistogram(n={self.n}, "
                f"buckets={len(self.counts)}, "
                f"min={self.min_s if self.n else 0.0:.3g}, "
                f"max={self.max_s:.3g})")


# -- namespace -------------------------------------------------------------

#: where each prefix's stats class lives — ``metrics_drift`` imports
#: these lazily (obs never imports repro.* at module level, because the
#: stats modules import THIS module for LatencyHistogram)
STATS_SOURCES = {
    "query": ("repro.query.engine", "QueryStats"),
    "traversal": ("repro.query.traversal", "TraversalStats"),
    "router": ("repro.query.sharded", "RouterStats"),
    "hotset": ("repro.query.hotset", "HotSetStats"),
    "stream": ("repro.data.graph_stream", "StreamStats"),
    "pgfuse": ("repro.core.pgfuse", "PGFuseStats"),
}

#: every key each ``as_dict()`` surface exposes, per prefix.  Dict-
#: valued keys (``close_reasons`` …) appear once here and flatten to
#: ``prefix.key.subkey`` gauges at registration.  This literal IS the
#: contract: ``.github/scripts/metrics_drift.py`` fails when it and the
#: live surfaces disagree in either direction, and the table in
#: ``docs/observability.md`` must list exactly these names.
NAMESPACE = {
    "query": (
        "requests", "unique_vertices", "batches", "coalesced_reads",
        "blocks_touched", "bytes_gathered", "edges_returned",
        "device_batches", "bytes_h2d", "close_reasons", "n_latencies",
        "dedup_ratio", "p50_s", "p99_s",
    ),
    "traversal": (
        "submitted", "admitted", "shed", "completed", "failed",
        "inflight", "requests_by_kind", "frontier_batches",
        "edges_scanned", "vertices_visited", "truncated", "n_latencies",
        "p50_s", "p99_s", "shed_rate",
    ),
    "router": (
        "requests", "batches", "routed_by_shard", "shard_batches",
        "reroutes", "failed_batches",
    ),
    "hotset": (
        "lookups", "hits", "misses", "fills", "admitted", "bypassed",
        "rejected", "evicted", "pinned", "prefetch_fills",
        "prefetch_hits", "prefetch_evicted", "hit_edges",
        "resident_bytes", "resident_entries", "hit_rate",
        "prefetch_hit_rate",
    ),
    "stream": (
        "partitions", "vertices", "edges", "decode_mode",
        "decode_reason", "underlying_reads", "underlying_bytes",
        "cache_hits", "cache_misses", "readahead_blocks", "bytes_h2d",
        "host_decode_bytes", "decode_s", "feature_rows",
        "feature_bytes", "feature_bytes_h2d", "feature_read_s",
        "feature_cache_hits", "feature_cache_misses", "label_rows",
        "label_bytes", "wall_s", "decode_edges_per_s",
        "h2d_bytes_per_s", "edges_per_s", "feature_bytes_per_s",
        "feature_hit_rate",
    ),
    "pgfuse": (
        "underlying_reads", "underlying_bytes", "cache_hits",
        "cache_misses", "waits", "evictions", "bytes_served",
        "readahead_blocks", "span_fetch_blocks", "retried_reads",
        "hit_rate",
    ),
}

#: derived ratios recomputed after a fold: name -> (numerator keys,
#: denominator keys); value = sum(num) / sum(den), 0 when den == 0.
RATIO_SPECS = {
    "query.dedup_ratio": (("query.requests",), ("query.unique_vertices",)),
    "traversal.shed_rate": (("traversal.shed",), ("traversal.submitted",)),
    "hotset.hit_rate": (("hotset.hits",), ("hotset.lookups",)),
    "hotset.prefetch_hit_rate": (("hotset.prefetch_hits",),
                                 ("hotset.prefetch_fills",)),
    "pgfuse.hit_rate": (("pgfuse.cache_hits",),
                        ("pgfuse.cache_hits", "pgfuse.cache_misses")),
    "stream.decode_edges_per_s": (("stream.edges",), ("stream.decode_s",)),
    "stream.h2d_bytes_per_s": (("stream.bytes_h2d",), ("stream.wall_s",)),
    "stream.edges_per_s": (("stream.edges",), ("stream.wall_s",)),
    "stream.feature_bytes_per_s": (("stream.feature_bytes",),
                                   ("stream.wall_s",)),
    "stream.feature_hit_rate": (("stream.feature_cache_hits",),
                                ("stream.feature_cache_hits",
                                 "stream.feature_cache_misses")),
}

#: non-recomputable summary keys: folding keeps the MAX (an upper
#: bound — the honest scalar fold for a quantile or a parallel
#: wall-clock, and it matches ``StreamStats.merge``'s wall_s rule)
MAX_KEYS = frozenset({
    "query.p50_s", "query.p99_s",
    "traversal.p50_s", "traversal.p99_s",
    "stream.wall_s",
})


# -- metric primitives -----------------------------------------------------

class Counter:
    """Monotonic count; folds by summing."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time level; ``fold`` picks sum or max per key kind."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, value: float = 0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Registry-resident :class:`LatencyHistogram` with metric kind."""

    __slots__ = ("hist",)
    kind = "histogram"

    def __init__(self, hist: Optional[LatencyHistogram] = None):
        self.hist = hist if hist is not None else LatencyHistogram()

    def observe(self, v: float) -> None:
        self.hist.add(v)

    @property
    def value(self) -> float:
        return self.hist.quantile(0.5)


class MetricsRegistry:
    """One flat metric namespace with fold-on-register semantics.

    ``register_stats("query", engine.stats.as_dict())`` flattens the
    dict into ``query.*`` entries.  Registering the same prefix again
    (another shard, another tenant) folds: sum-kind keys add, ratio
    keys recompute from their folded parts (:data:`RATIO_SPECS`), and
    :data:`MAX_KEYS` keep the max.  Non-numeric values (decode mode
    strings) land in the ``info`` side-channel, last-write-wins.
    """

    def __init__(self):
        self._values: Dict[str, float] = {}
        self.info: Dict[str, str] = {}
        self._sources: Dict[str, int] = {}   # prefix -> folds seen

    # -- registration ------------------------------------------------------
    def register_stats(self, prefix: str, stats: dict) -> None:
        self._sources[prefix] = self._sources.get(prefix, 0) + 1
        flat: Dict[str, float] = {}
        for key, val in stats.items():
            name = f"{prefix}.{key}"
            if isinstance(val, dict):
                for sub, v in val.items():
                    flat[f"{name}.{sub}"] = float(v)
            elif isinstance(val, str):
                self.info[name] = val
            elif isinstance(val, LatencyHistogram):
                flat[f"{name}.n"] = float(val.n)
            else:
                flat[name] = float(val)
        for name, v in flat.items():
            if name in RATIO_SPECS:
                continue                     # recomputed below
            if name in MAX_KEYS:
                self._values[name] = max(self._values.get(name, 0.0), v)
            else:
                self._values[name] = self._values.get(name, 0.0) + v
        for name in RATIO_SPECS:
            if not name.startswith(prefix + "."):
                continue
            num_keys, den_keys = RATIO_SPECS[name]
            num = sum(self._values.get(k, 0.0) for k in num_keys)
            den = sum(self._values.get(k, 0.0) for k in den_keys)
            self._values[name] = num / den if den else 0.0

    def set(self, name: str, value: float) -> None:
        """Directly set one metric (exposition-side extras like
        ``obs.dropped_traces``)."""
        self._values[name] = float(value)

    # -- reads -------------------------------------------------------------
    def get(self, name: str, default: float = 0.0) -> float:
        return self._values.get(name, default)

    def names(self) -> List[str]:
        return sorted(self._values)

    def snapshot(self) -> dict:
        """JSON-ready snapshot: sorted numeric metrics + info strings +
        per-prefix fold counts."""
        return {
            "metrics": {k: self._values[k] for k in sorted(self._values)},
            "info": dict(sorted(self.info.items())),
            "sources": dict(sorted(self._sources.items())),
        }

    # -- exposition --------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text format: ``repro_`` prefix, dots to
        underscores, one ``# TYPE`` line per metric."""
        lines = []
        for name in sorted(self._values):
            pname = "repro_" + name.replace(".", "_").replace("-", "_")
            lines.append(f"# TYPE {pname} gauge")
            v = self._values[name]
            lines.append(f"{pname} {v:.17g}" if isinstance(v, float)
                         else f"{pname} {v}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")


def flatten_numeric(d: dict, prefix: str = "") -> Dict[str, float]:
    """Recursively flatten a nested result dict to dotted numeric keys
    (strings/lists dropped) — the shape the ``BENCH_*_metrics.json``
    sidecars persist so bench runs double as metrics-surface smoke
    tests."""
    out: Dict[str, float] = {}
    for k, v in d.items():
        name = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_numeric(v, name))
        elif isinstance(v, bool) or isinstance(v, (int, float)):
            out[name] = float(v)
    return out


# -- drift check -----------------------------------------------------------

def metrics_drift() -> List[str]:
    """Diff the live ``as_dict()`` surfaces against :data:`NAMESPACE`.

    Returns one message per violation (empty list == in sync): a stats
    key missing from the namespace, a namespace key the class no longer
    exposes, or a prefix whose class cannot be imported.  Run by
    ``.github/scripts/metrics_drift.py`` (docs CI job) and
    ``tests/test_docs_sync.py``.
    """
    import importlib

    problems: List[str] = []
    for prefix, (mod_name, cls_name) in sorted(STATS_SOURCES.items()):
        try:
            cls = getattr(importlib.import_module(mod_name), cls_name)
            live = set(cls().as_dict())
        except Exception as exc:   # pragma: no cover - import breakage
            problems.append(f"{prefix}: cannot load "
                            f"{mod_name}.{cls_name}: {exc!r}")
            continue
        declared = set(NAMESPACE[prefix])
        for key in sorted(live - declared):
            problems.append(
                f"{prefix}.{key}: exposed by {cls_name}.as_dict() but "
                f"missing from repro.obs.metrics.NAMESPACE")
        for key in sorted(declared - live):
            problems.append(
                f"{prefix}.{key}: declared in NAMESPACE but not exposed "
                f"by {cls_name}.as_dict()")
    for prefix in sorted(set(NAMESPACE) - set(STATS_SOURCES)):
        problems.append(f"{prefix}: in NAMESPACE but has no entry in "
                        f"STATS_SOURCES")
    return problems
