"""Per-tier time attribution and the bottleneck report.

The paper's core figures (Figs. 2-4) attribute load time to layers —
storage calls vs block cache vs decompression.  This module produces
the same shaped answer for our serving stack from sampled span trees:
for one request, how much (virtual-clock) time went to routing,
gather machinery, storage reads, decode, and H2D?

Attribution sums each span's EXCLUSIVE time (``Span.self_time_s`` —
duration minus children) into its tier, so nested same-tier spans
(an engine-level storage span over the PG-Fuse read spans it caused)
never double count, and the per-tier times plus untiered overhead sum
exactly to the root's duration.  ``coverage`` is the named-tier
fraction of the root — the acceptance bar requires >= 0.95 on a
sharded traversal under the virtual clock.

Also here: the span/stats conservation helpers the differential
fuzzers assert (event counts in a trace set must equal the stats
counters they shadow) and structural span-tree validation.
"""

from __future__ import annotations

from typing import Dict, Iterable, List

from .trace import NAMED_TIERS, Span


def tier_times(root: Span) -> Dict[str, float]:
    """Exclusive time per tier over the whole tree (all tiers seen,
    not just the named ones)."""
    out: Dict[str, float] = {}
    for s in root.iter_spans():
        out[s.tier] = out.get(s.tier, 0.0) + s.self_time_s
    return out

def attribution(root: Span) -> dict:
    """Attribute the root's duration to named tiers.

    Returns ``{"total_s", "tiers": {tier: s}, "untiered_s",
    "coverage"}`` where ``tiers`` covers :data:`~repro.obs.trace
    .NAMED_TIERS`, ``untiered_s`` is everything else (request envelope
    overhead, unnamed spans), and ``coverage`` = named / total.
    """
    times = tier_times(root)
    tiers = {t: times.get(t, 0.0) for t in NAMED_TIERS}
    total = root.duration_s
    named = sum(tiers.values())
    return {
        "total_s": total,
        "tiers": tiers,
        "untiered_s": total - named,
        "coverage": named / total if total > 0 else 1.0,
    }


def event_counts(traces: Iterable[Span], name: str) -> int:
    """Occurrences of event ``name`` across a set of traces — compared
    against the stats counter the event shadows (``retry`` vs
    ``PGFuseStats.retried_reads``, ``reroute`` vs
    ``RouterStats.reroutes``, ``shed`` vs ``TraversalStats.shed``)."""
    return sum(root.event_count(name) for root in traces)


def window_close_counts(traces: Iterable[Span]) -> Dict[str, int]:
    """Per-reason totals of ``window_close`` events — reconciles with
    ``QueryStats.close_reasons`` when every batch is traced."""
    out: Dict[str, int] = {}
    for root in traces:
        for s in root.iter_spans():
            for e in s.events:
                if e.name == "window_close":
                    reason = e.attrs.get("reason", "?")
                    out[reason] = out.get(reason, 0) + 1
    return out


def verify_span_tree(root: Span) -> List[str]:
    """Structural invariants of one trace; returns violation messages
    (empty == valid).  Checked by the differential fuzzers on every
    sampled trace:

    * every span's ``t1 >= t0`` (the injectable clock is monotonic);
    * every child lies within its parent's [t0, t1] window;
    * ``parent_id`` links match the tree structure;
    * span ids are unique within the tree.
    """
    problems: List[str] = []
    seen: Dict[int, str] = {}
    for s in root.iter_spans():
        if s.t1 < s.t0:
            problems.append(f"span {s.span_id} ({s.name}): t1 < t0")
        if s.span_id in seen:
            problems.append(f"span id {s.span_id} duplicated "
                            f"({seen[s.span_id]} and {s.name})")
        seen[s.span_id] = s.name
        for c in s.children:
            if c.parent_id != s.span_id:
                problems.append(f"span {c.span_id} ({c.name}): "
                                f"parent_id {c.parent_id} != "
                                f"{s.span_id}")
            if c.t0 < s.t0 or c.t1 > s.t1:
                problems.append(f"span {c.span_id} ({c.name}): outside "
                                f"parent {s.span_id} window")
        for e in s.events:
            if not (s.t0 <= e.t <= s.t1):
                problems.append(f"event {e.name} in span {s.span_id}: "
                                f"outside span window")
    if root.parent_id is not None:
        problems.append(f"root span {root.span_id} has parent_id "
                        f"{root.parent_id}")
    return problems


def render_report(traces: Iterable[Span]) -> str:
    """The bottleneck report: per-tier time share summed over sampled
    traces, one line per tier plus untiered overhead and coverage —
    the Fig. 2/3-shaped table for our own stack."""
    traces = list(traces)
    if not traces:
        return "tier attribution: no sampled traces"
    total = 0.0
    tiers = {t: 0.0 for t in NAMED_TIERS}
    events = 0
    for root in traces:
        att = attribution(root)
        total += att["total_s"]
        for t in NAMED_TIERS:
            tiers[t] += att["tiers"][t]
        events += sum(len(s.events) for s in root.iter_spans())
    named = sum(tiers.values())
    lines = [f"tier attribution over {len(traces)} sampled trace(s), "
             f"{total:.6g}s total, {events} event(s):"]
    for t in NAMED_TIERS:
        share = tiers[t] / total if total > 0 else 0.0
        lines.append(f"  {t:<8s} {tiers[t]:>12.6g}s  {share:>6.1%}")
    unt = total - named
    lines.append(f"  {'(other)':<8s} {unt:>12.6g}s  "
                 f"{(unt / total if total > 0 else 0.0):>6.1%}")
    lines.append(f"  coverage {named / total if total > 0 else 1.0:.1%} "
                 f"of request time attributed to named tiers")
    return "\n".join(lines)
