"""Span tracing for the serving path — deterministic, injectable-clock.

One traversal request crosses five layers (router -> engine micro-batch
-> hot-set tier -> PG-Fuse -> decode), each with its own ``*Stats``
accounting but — before this module — no way to follow a SINGLE request
through them.  :class:`Tracer` produces that view: every instrumented
layer opens a :class:`Span` around its work, spans nest by the calling
thread's :class:`TraceContext`, and a finished root span is a tree
attributing the request's (virtual-clock) time to tiers:

``request``  the traversal service's per-request envelope
``route``    scatter-gather routing in the sharded service
``gather``   engine micro-batch machinery (dedup, range merge, scatter)
``storage``  PG-Fuse underlying reads (cache misses only — hits never
             touch storage and correctly attribute nothing here)
``decode``   eq. (1), host or device
``h2d``      packed-byte transfer accounting on the device path

Design constraints, all load-bearing:

* **no globals** — a ``Tracer`` is an ordinary object injected into the
  components that should trace (``NeighborQueryEngine(tracer=...)``,
  ``ShardedQueryService(tracer=...)``, ``TraversalService(tracer=...)``,
  ``PGFuseFS.tracer``).  Two services with two tracers never share
  state;
* **zero-cost when disabled** — :data:`NULL_TRACER` (the default
  everywhere) returns one shared no-op handle; the serving path adds
  only an attribute load + a no-op context manager per span site, and
  the bench lane's tracked gates prove no regression;
* **deterministic** — span ids come from a seeded counter, timestamps
  from the injectable ``clock`` (benchmarks pass the SimStorage virtual
  clock), and sampling is a modular counter over root spans — so two
  same-seed runs produce bit-identical span trees (asserted by
  ``tests/test_obs_tracing.py``);
* **bounded** — at most ``max_traces`` finished roots are retained
  (``dropped_traces`` counts the overflow), and sampling keeps only
  every ``sample_every``-th root, suppressing the whole subtree of an
  unsampled request (children of a suppressed span never become roots).

Span **events** mark point occurrences inside a span: PG-Fuse transient
retries (``"retry"``), replica failovers (``"reroute"``), admission
sheds (``"shed"``), micro-batch window closes (``"window_close"``,
with the :data:`repro.query.window.CLOSE_REASONS` reason), hot-set
lookups/fills.  Event counts reconcile exactly with the stats counters
they shadow (``PGFuseStats.retried_reads``, ``RouterStats.reroutes``,
``TraversalStats.shed``, ``QueryStats.close_reasons``) — the
conservation cross-checks ``repro.obs.report`` verifies and the
differential fuzzers assert.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Tuple

#: tiers the attribution report names; spans may carry other tier
#: strings ("request", "other") but those count as untiered time
NAMED_TIERS = ("route", "gather", "storage", "decode", "h2d")

#: tiers allowed to START a trace (root spans).  Orphan spans of other
#: tiers — e.g. a storage read issued by a background producer thread
#: with no request context — are suppressed rather than recorded as
#: meaningless single-span traces.
ROOT_TIERS = ("request", "route", "gather")


class SpanEvent:
    """A point occurrence inside a span (retry, reroute, shed, ...)."""

    __slots__ = ("name", "t", "attrs")

    def __init__(self, name: str, t: float, attrs: dict):
        self.name = name
        self.t = t
        self.attrs = attrs

    def as_dict(self) -> dict:
        return {"name": self.name, "t": self.t, "attrs": dict(self.attrs)}


class Span:
    """One timed tree node; built by :meth:`Tracer.span`, closed by the
    ``with`` block.  ``self_time_s`` (duration minus children) is the
    quantity the per-tier attribution sums, so nested same-tier spans
    (an engine storage span over a PG-Fuse storage span) never double
    count."""

    __slots__ = ("span_id", "parent_id", "name", "tier", "t0", "t1",
                 "attrs", "events", "children")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 tier: str, t0: float, attrs: dict):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.tier = tier
        self.t0 = t0
        self.t1 = t0
        self.attrs = attrs
        self.events: List[SpanEvent] = []
        self.children: List["Span"] = []

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    @property
    def self_time_s(self) -> float:
        """Exclusive time: duration minus the children's durations."""
        return self.duration_s - sum(c.duration_s for c in self.children)

    def iter_spans(self):
        """Pre-order walk of the subtree rooted here."""
        yield self
        for c in self.children:
            yield from c.iter_spans()

    def event_count(self, name: str) -> int:
        """Occurrences of event ``name`` across the whole subtree."""
        return sum(sum(1 for e in s.events if e.name == name)
                   for s in self.iter_spans())

    def as_dict(self) -> dict:
        """Fully serialized subtree — the bit-for-bit comparison surface
        the same-seed determinism tests pin."""
        return {
            "span_id": self.span_id, "parent_id": self.parent_id,
            "name": self.name, "tier": self.tier,
            "t0": self.t0, "t1": self.t1, "attrs": dict(self.attrs),
            "events": [e.as_dict() for e in self.events],
            "children": [c.as_dict() for c in self.children],
        }


class TraceContext:
    """Per-thread propagation state: the open-span stack plus the
    suppression depth (non-zero while inside an unsampled or orphan
    subtree).  Created lazily per thread by the tracer; user code never
    constructs one — it propagates implicitly through nested ``with
    tracer.span(...)`` blocks and explicitly across threads via
    :meth:`Tracer.attach`."""

    __slots__ = ("stack", "suppress")

    def __init__(self):
        self.stack: List[Span] = []
        self.suppress = 0


class _SpanHandle:
    """The live handle a ``with tracer.span(...) as sp:`` block holds."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._finish(self.span)
        return False

    def event(self, name: str, **attrs) -> None:
        self.span.events.append(
            SpanEvent(name, self._tracer._clock(), attrs))

    def set(self, **attrs) -> None:
        self.span.attrs.update(attrs)


class _SuppressedHandle:
    """Handle for spans inside an unsampled/orphan subtree: keeps the
    suppression depth balanced, records nothing."""

    __slots__ = ("_tracer",)

    def __init__(self, tracer: "Tracer"):
        self._tracer = tracer

    def __enter__(self) -> "_SuppressedHandle":
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._ctx().suppress -= 1
        return False

    def event(self, name: str, **attrs) -> None:
        pass

    def set(self, **attrs) -> None:
        pass


class _NullHandle:
    """The one shared no-op handle :data:`NULL_TRACER` hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullHandle":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def event(self, name: str, **attrs) -> None:
        pass

    def set(self, **attrs) -> None:
        pass


_NULL_HANDLE = _NullHandle()


class NullTracer:
    """Disabled tracing: every call is a no-op on shared singletons, so
    an uninstrumented serving path and one built with the default
    ``tracer=None`` are the same code at the same cost."""

    enabled = False
    traces: Tuple[Span, ...] = ()
    dropped_traces = 0

    def span(self, name: str, tier: str = "other", **attrs) -> _NullHandle:
        return _NULL_HANDLE

    def event(self, name: str, **attrs) -> None:
        pass

    def attach(self, span) -> _NullHandle:
        return _NULL_HANDLE

    @property
    def current(self) -> None:
        return None

    def drain(self) -> list:
        return []


#: the module-wide disabled tracer every component defaults to
NULL_TRACER = NullTracer()


class Tracer:
    """Span recorder with deterministic ids and an injectable clock.

    ``sample_every=N`` records every N-th root span (and its whole
    subtree); the requests in between cost one suppressed-handle
    allocation per span site.  ``seed`` starts the span-id counter —
    two tracers with the same seed over the same single-threaded call
    sequence assign identical ids.  ``clock`` is any ``() -> float``;
    benches pass the SimStorage charged clock so span durations are
    virtual (machine-independent) seconds.
    """

    enabled = True

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 sample_every: int = 1, seed: int = 0,
                 max_traces: int = 256,
                 root_tiers: Tuple[str, ...] = ROOT_TIERS):
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, "
                             f"got {sample_every}")
        self._clock = clock
        self.sample_every = int(sample_every)
        self.root_tiers = tuple(root_tiers)
        self.max_traces = int(max_traces)
        self._next_id = int(seed)
        self._roots_seen = 0
        self._lock = threading.Lock()
        self._local = threading.local()
        self._suppressed = _SuppressedHandle(self)
        self.traces: List[Span] = []   # finished sampled roots, in order
        self.dropped_traces = 0

    # -- propagation state -------------------------------------------------
    def _ctx(self) -> TraceContext:
        ctx = getattr(self._local, "ctx", None)
        if ctx is None:
            ctx = self._local.ctx = TraceContext()
        return ctx

    @property
    def current(self) -> Optional[Span]:
        """The calling thread's innermost open span (None outside any)."""
        stack = self._ctx().stack
        return stack[-1] if stack else None

    # -- span lifecycle ----------------------------------------------------
    def span(self, name: str, tier: str = "other", **attrs):
        """Open a span; use as ``with tracer.span(...) as sp:``.

        A span opened with no parent in this thread is a ROOT: it is
        recorded only if its tier is in ``root_tiers`` AND the sampler
        selects it; otherwise the whole subtree is suppressed (children
        never become accidental roots).
        """
        ctx = self._ctx()
        if ctx.suppress:
            ctx.suppress += 1
            return self._suppressed
        parent = ctx.stack[-1] if ctx.stack else None
        if parent is None:
            if tier not in self.root_tiers:
                ctx.suppress += 1
                return self._suppressed
            with self._lock:
                nth = self._roots_seen
                self._roots_seen += 1
            if nth % self.sample_every:
                ctx.suppress += 1
                return self._suppressed
        with self._lock:
            sid = self._next_id
            self._next_id += 1
        sp = Span(sid, parent.span_id if parent is not None else None,
                  name, tier, self._clock(), attrs)
        if parent is not None:
            parent.children.append(sp)
        ctx.stack.append(sp)
        return _SpanHandle(self, sp)

    def _finish(self, sp: Span) -> None:
        sp.t1 = self._clock()
        stack = self._ctx().stack
        assert stack and stack[-1] is sp, "span exited out of order"
        stack.pop()
        if sp.parent_id is None:
            with self._lock:
                if len(self.traces) < self.max_traces:
                    self.traces.append(sp)
                else:
                    self.dropped_traces += 1

    def event(self, name: str, **attrs) -> None:
        """Attach an event to the calling thread's current span (dropped
        silently outside any span — orphan events have no tree to live
        in)."""
        cur = self.current
        if cur is not None:
            cur.events.append(SpanEvent(name, self._clock(), attrs))

    def attach(self, span: Span):
        """Adopt ``span`` as the calling thread's current parent — the
        explicit cross-thread propagation hook (a worker thread doing a
        request's work on its behalf)::

            with tracer.attach(request_span):
                ...   # spans opened here nest under request_span
        """
        return _AttachHandle(self, span)

    def drain(self) -> List[Span]:
        """Return and clear the retained traces (exposition reads this
        so long-running servers do not accumulate unboundedly)."""
        with self._lock:
            out, self.traces = self.traces, []
        return out


class _AttachHandle:
    """Context manager pushing an existing span as this thread's
    current parent (see :meth:`Tracer.attach`)."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: Tracer, span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._ctx().stack.append(self._span)
        return self._span

    def __exit__(self, *exc) -> bool:
        stack = self._tracer._ctx().stack
        assert stack and stack[-1] is self._span
        stack.pop()
        return False
