"""Node-feature converters: build ``core.featstore`` files for a graph.

Real pipelines convert whatever raw feature source they have (npy dumps,
parquet columns, an embedding table) into the fixed-stride FeatStore
layout once, then stream it through PG-Fuse on every epoch.  This module
provides that converter plus a deterministic synthesizer for graphs that
ship without features (RMAT/ER benchmark graphs): the synthesized matrix
is a pure function of ``(n_vertices, d, seed)``, so tests can regenerate
any row range independently and byte-compare it against store reads.
"""

from __future__ import annotations

import os
from typing import Optional, Union

import numpy as np

from repro.core import featstore


def synthesize_node_features(n_vertices: int, d: int, *, seed: int = 0,
                             dtype=np.float32) -> np.ndarray:
    """Deterministic stand-in feature matrix (n_vertices, d)."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_vertices, d)).astype(dtype)


def write_node_features(path: Union[str, os.PathLike], x: np.ndarray, *,
                        dtype=None,
                        data_align: int = featstore.DEFAULT_DATA_ALIGN
                        ) -> int:
    """Convert a feature matrix into a FeatStore file; returns bytes
    written.  Pass ``data_align=pgfuse_block_size`` so block-aligned
    plan cuts (``partition.split_plan(align=...)``) make per-host
    feature reads block-disjoint."""
    return featstore.write_featstore(path, x, dtype=dtype,
                                     data_align=data_align)


def featstore_for_graph(graph_path: Union[str, os.PathLike],
                        out_path: Union[str, os.PathLike], d: int, *,
                        seed: int = 0, dtype=None,
                        data_align: int = featstore.DEFAULT_DATA_ALIGN,
                        x: Optional[np.ndarray] = None) -> str:
    """Write the feature store matching ``graph_path``'s vertex count.

    ``x`` supplies real features (row count must equal |V|) and is
    stored in ITS dtype unless ``dtype`` explicitly overrides — a
    caller's float16 matrix must not silently widen to float32 and
    double the store's byte stream.  Without ``x`` a synthesized matrix
    stands in (float32 unless ``dtype`` says otherwise).  Returns
    ``out_path``.
    """
    from repro.core import paragrapher

    with paragrapher.open_graph(graph_path) as g:
        n = g.n_vertices
    if x is None:
        x = synthesize_node_features(n, d, seed=seed,
                                     dtype=dtype or np.float32)
    elif x.shape[0] != n:
        raise ValueError(
            f"feature rows {x.shape[0]} != graph vertices {n}")
    write_node_features(out_path, x, dtype=dtype, data_align=data_align)
    return os.fspath(out_path)
