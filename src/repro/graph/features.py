"""Node-feature converters: build ``core.featstore`` files for a graph.

Real pipelines convert whatever raw feature source they have (npy dumps,
parquet columns, an embedding table) into the fixed-stride FeatStore
layout once, then stream it through PG-Fuse on every epoch.  This module
provides that converter plus a deterministic synthesizer for graphs that
ship without features (RMAT/ER benchmark graphs): the synthesized matrix
is a pure function of ``(n_vertices, d, seed)``, so tests can regenerate
any row range independently and byte-compare it against store reads.
"""

from __future__ import annotations

import os
from typing import Optional, Union

import numpy as np

from repro.core import featstore


def synthesize_node_features(n_vertices: int, d: int, *, seed: int = 0,
                             dtype=np.float32) -> np.ndarray:
    """Deterministic stand-in feature matrix (n_vertices, d)."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_vertices, d)).astype(dtype)


def write_node_features(path: Union[str, os.PathLike], x: np.ndarray, *,
                        dtype=None,
                        data_align: int = featstore.DEFAULT_DATA_ALIGN
                        ) -> int:
    """Convert a feature matrix into a FeatStore file; returns bytes
    written.  Pass ``data_align=pgfuse_block_size`` so block-aligned
    plan cuts (``partition.split_plan(align=...)``) make per-host
    feature reads block-disjoint."""
    return featstore.write_featstore(path, x, dtype=dtype,
                                     data_align=data_align)


#: column layout of the label family: row v = [class id, train-mask flag]
LABEL_FAMILY_D = 2


def synthesize_node_labels(n_vertices: int, n_classes: int, *, seed: int = 0,
                           train_fraction: float = 0.3) -> np.ndarray:
    """Deterministic (n_vertices, 2) uint8 label family:
    column 0 = class id, column 1 = 1 where the vertex is in the training
    mask.  Like :func:`synthesize_node_features` it is a pure function of
    its arguments, so tests regenerate any row range and byte-compare."""
    if not 0 < n_classes <= 256:
        raise ValueError(f"n_classes must be in (0, 256] for the u8 "
                         f"label family, got {n_classes}")
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n_vertices).astype(np.uint8)
    mask = (rng.random(n_vertices) < train_fraction).astype(np.uint8)
    return np.stack([y, mask], axis=1)


def synthesize_separable_labels(x: np.ndarray, n_classes: int, *,
                                seed: int = 0) -> np.ndarray:
    """Labels a model can actually learn from ``x``: argmax of a fixed
    random linear projection of the feature rows.  Deterministic in
    ``(x, n_classes, seed)``, so a training run on the synthesized
    stores has a decreasing loss to assert on — uniformly random labels
    would leave nothing to fit."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((x.shape[1], n_classes))
    return np.argmax(np.asarray(x, dtype=np.float64) @ w, axis=1).astype(
        np.uint8)


def labelstore_for_graph(graph_path: Union[str, os.PathLike],
                         out_path: Union[str, os.PathLike], n_classes: int,
                         *, seed: int = 0,
                         data_align: int = featstore.DEFAULT_DATA_ALIGN,
                         labels: Optional[np.ndarray] = None,
                         mask: Optional[np.ndarray] = None) -> str:
    """Write the label/mask column family matching ``graph_path``.

    Labels and masks are a SECOND fixed-stride column family beside the
    feature store — same FeatStore wire format, same PG-Fuse mount at
    stream time — so full-graph batches carry zero synthetic tensors
    (``x`` from the feature family, ``labels``/``label_mask`` from this
    one).  ``labels``/``mask`` supply real data; without them the
    deterministic synthesizer stands in.  Returns ``out_path``.
    """
    from repro.core import paragrapher

    with paragrapher.open_graph(graph_path) as g:
        n = g.n_vertices
    if labels is None:
        fam = synthesize_node_labels(n, n_classes, seed=seed)
        if mask is not None:
            fam[:, 1] = np.asarray(mask).astype(np.uint8)
    else:
        labels = np.asarray(labels)
        if labels.shape[0] != n:
            raise ValueError(f"label rows {labels.shape[0]} != "
                             f"graph vertices {n}")
        if labels.max(initial=0) >= n_classes:
            raise ValueError(f"label {int(labels.max())} out of range for "
                             f"{n_classes} classes")
        m = (np.ones(n, np.uint8) if mask is None
             else np.asarray(mask).astype(np.uint8))
        fam = np.stack([labels.astype(np.uint8), m], axis=1)
    featstore.write_featstore(out_path, fam, data_align=data_align)
    return os.fspath(out_path)


def featstore_for_graph(graph_path: Union[str, os.PathLike],
                        out_path: Union[str, os.PathLike], d: int, *,
                        seed: int = 0, dtype=None,
                        data_align: int = featstore.DEFAULT_DATA_ALIGN,
                        x: Optional[np.ndarray] = None) -> str:
    """Write the feature store matching ``graph_path``'s vertex count.

    ``x`` supplies real features (row count must equal |V|) and is
    stored in ITS dtype unless ``dtype`` explicitly overrides — a
    caller's float16 matrix must not silently widen to float32 and
    double the store's byte stream.  Without ``x`` a synthesized matrix
    stands in (float32 unless ``dtype`` says otherwise).  Returns
    ``out_path``.
    """
    from repro.core import paragrapher

    with paragrapher.open_graph(graph_path) as g:
        n = g.n_vertices
    if x is None:
        x = synthesize_node_features(n, d, seed=seed,
                                     dtype=dtype or np.float32)
    elif x.shape[0] != n:
        raise ValueError(
            f"feature rows {x.shape[0]} != graph vertices {n}")
    write_node_features(out_path, x, dtype=dtype, data_align=data_align)
    return os.fspath(out_path)
