"""Edge partitioning for distributed full-graph GNN training.

Full-graph message passing shards the *edge list* across devices; each
device computes gather(src) -> message -> partial segment-sum, and partials
are reduced with a psum over the edge-shard axis (models/gnn/layers.py).
The partitioner pads every shard to a common length so the result is a
dense (n_shards, shard_len) array — shardable by a ShapeDtypeStruct.
"""

from __future__ import annotations

import numpy as np

from repro.core.csr import CSR


def edge_balanced_partition(csr: CSR, n_shards: int, *, pad_value: int = -1
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Split the COO edge list into ``n_shards`` equal (padded) shards.

    Returns (src, dst) of shape [n_shards, shard_len] with ``pad_value``
    marking padding (segment ops drop ids < 0).
    """
    src, dst = csr.edge_index()
    E = src.shape[0]
    shard_len = -(-E // n_shards)
    total = shard_len * n_shards
    src_p = np.full(total, pad_value, dtype=np.int64)
    dst_p = np.full(total, pad_value, dtype=np.int64)
    src_p[:E] = src
    dst_p[:E] = dst
    return src_p.reshape(n_shards, shard_len), dst_p.reshape(n_shards, shard_len)


def vertex_range_partition(csr: CSR, n_parts: int) -> list[tuple[int, int]]:
    """Contiguous vertex ranges with approximately equal edge counts
    (mirrors GraphHandle.partition_plan but for in-memory CSR)."""
    total = csr.n_edges
    targets = [(total * (i + 1)) // n_parts for i in range(n_parts)]
    cuts = np.searchsorted(csr.offsets, targets, side="left")
    cuts = np.clip(cuts, 1, csr.n_vertices)
    bounds = [0] + sorted(set(int(c) for c in cuts))
    if bounds[-1] != csr.n_vertices:
        bounds.append(csr.n_vertices)
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def _normalized_shares(shares, process_count: int) -> np.ndarray:
    s = np.asarray(shares, dtype=np.float64)
    if s.shape != (process_count,):
        raise ValueError(f"shares shape {s.shape} != ({process_count},)")
    if np.any(s < 0) or s.sum() <= 0:
        raise ValueError("shares must be >= 0 with a positive sum")
    return s / s.sum()


def _clip_entries(plan: list[tuple[int, int]], a: int, b: int
                  ) -> list[tuple[int, int]]:
    """Plan entries intersected with vertex range [a, b)."""
    out = []
    for v0, v1 in plan:
        lo, hi = max(v0, a), min(v1, b)
        if lo < hi:
            out.append((lo, hi))
    return out


def split_plan(plan: list[tuple[int, int]], process_count: int,
               weights=None, *, shares=None, align: int = 1
               ) -> list[list[tuple[int, int]]]:
    """Assign a partition plan's entries to ``process_count`` processes.

    Each process receives a *contiguous* run of plan entries (so its
    vertex coverage is one contiguous range and its storage reads stay
    sequential — the access pattern PG-Fuse readahead is built for).
    With the defaults, the concatenation of the returned slices is
    exactly ``plan``: ranges across processes are disjoint and cover the
    same vertices.

    ``weights`` (per-entry work, e.g. edge counts) balances the cut
    points; plans from ``GraphHandle.partition_plan`` are already
    edge-balanced, so the default equal-weight split inherits that
    balance.  Greedy cumulative-target cutting bounds every process at
    ``total * share + max(weights)``.  With more processes than entries
    the trailing processes receive empty slices.

    ``shares`` (per-process capacity fractions, normalized internally)
    sizes the slices unevenly — the straggler-aware mode: a host measured
    at half the others' bandwidth passes half their share and receives
    roughly half their work (see :func:`resplit_from_stats`).

    ``align`` > 1 snaps every inter-host cut VERTEX to the nearest
    multiple of ``align``, splitting plan entries where needed (the
    returned ranges still tile the plan's coverage exactly, but entry
    boundaries may move).  Pass ``align = block_size // row_stride`` of a
    fixed-stride store whose data section is block-aligned
    (``featstore.write_featstore(data_align=block_size)``) and
    neighboring hosts' private PG-Fuse caches never fetch the same
    feature block — the cut lands exactly on a block boundary instead of
    mid-block, where both hosts would pay for the full 32 MiB block.
    """
    if process_count < 1:
        raise ValueError(f"process_count must be >= 1, got {process_count}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    n = len(plan)
    w = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    if w.shape != (n,):
        raise ValueError(f"weights shape {w.shape} != ({n},)")
    if np.any(w < 0):
        raise ValueError("weights must be >= 0")
    cum_share = (np.arange(1, process_count + 1) / process_count
                 if shares is None
                 else np.cumsum(_normalized_shares(shares, process_count)))
    cum = np.concatenate([[0.0], np.cumsum(w)])
    total = cum[-1]
    bounds = [0]
    for i in range(process_count):
        target = total * cum_share[i]
        cut = int(np.searchsorted(cum, target, side="left"))
        bounds.append(min(n, max(bounds[-1], cut)))
    bounds[-1] = n
    if align == 1 or n == 0:
        return [plan[bounds[i]: bounds[i + 1]] for i in range(process_count)]

    # vertex-level cuts snapped to the block grid (monotonic, clamped to
    # the plan's coverage); entries crossing a snapped cut are split
    v_lo, v_hi = plan[0][0], plan[-1][1]
    cuts = [v_lo]
    for i in range(1, process_count):
        b = bounds[i]
        v = plan[b][0] if b < n else v_hi
        snapped = int(round(v / align)) * align
        cuts.append(min(max(snapped, cuts[-1]), v_hi))
    cuts.append(v_hi)
    return [_clip_entries(plan, cuts[i], cuts[i + 1])
            for i in range(process_count)]


def host_vertex_range(entries: list[tuple[int, int]]) -> tuple[int, int]:
    """Vertex range [v0, v1) covered by one process's plan slice
    (empty slices cover nothing and report (0, 0))."""
    if not entries:
        return (0, 0)
    return (entries[0][0], entries[-1][1])


def shard_ranges(plan: list[tuple[int, int]], n_shards: int, *,
                 shares=None, align: int = 1) -> list[tuple[int, int]]:
    """Contiguous per-shard vertex ranges ``[v0, v1)`` for the sharded
    serving path, cut from an edge-balanced partition plan.

    A thin composition of :func:`split_plan` (the same slicer the
    multi-host loader uses, including capacity-``shares`` skew and
    block-grid ``align``) and :func:`host_vertex_range`: each shard's
    slice collapses to its covering vertex range.  The returned ranges
    tile the plan's coverage exactly — a shard the plan could not feed
    (more shards than entries) gets a zero-width range pinned at the
    previous cut, so routing by ``searchsorted`` over the range ends
    never selects it.
    """
    slices = split_plan(plan, n_shards, shares=shares, align=align)
    ranges: list[tuple[int, int]] = []
    prev = plan[0][0] if plan else 0
    for sl in slices:
        if sl:
            v0, v1 = host_vertex_range(sl)
            ranges.append((v0, v1))
            prev = v1
        else:
            ranges.append((prev, prev))
    return ranges


def stream_shares_from_stats(stats, *, floor: float = 0.25) -> np.ndarray:
    """Per-host capacity shares from the previous epoch's ``StreamStats``.

    Host ``i``'s measured loading speed is ``work_i / wall_s_i`` (work =
    streamed edges, or vertices for a pure feature stream); the next
    epoch's :func:`split_plan` ``shares`` are proportional to speed, so a
    straggler — slow NIC, contended OST, busy neighbor VM — receives a
    smaller slice instead of gating the whole cluster at the barrier.

    ``floor`` bounds every share at ``floor / n_hosts`` (a fraction of
    the equal share) before renormalizing: a host that had one terrible
    epoch must keep enough work to be re-measured, or a transient stall
    would starve it forever.  Hosts with no measurement (empty slice,
    zero wall time) are assigned the mean speed of the measured ones.
    All hosts compute identical shares from the same (allgathered) stats,
    so the new cut points agree without further coordination — the same
    no-communication property the original plan split has.
    """
    stats = list(stats)
    k = len(stats)
    if k < 1:
        raise ValueError("need at least one host's stats")
    if not 0 <= floor <= 1:
        raise ValueError(f"floor must be in [0, 1], got {floor}")
    # one work unit for ALL hosts (edges when any host streamed edges,
    # else vertices): mixing units across hosts would make the speeds
    # incomparable — a host whose slice happens to hold an edge-less
    # tail would be scored in vertices/s against its peers' edges/s.
    # A host with zero work in the chosen unit has no measurement and
    # falls into the mean-speed bucket below.
    use_edges = any(s.edges for s in stats)
    speeds = np.zeros(k)
    for i, s in enumerate(stats):
        work = s.edges if use_edges else s.vertices
        wall = getattr(s, "wall_s", 0.0)
        speeds[i] = work / wall if (work and wall > 0) else np.nan
    measured = speeds[~np.isnan(speeds)]
    if measured.size == 0:
        return np.full(k, 1.0 / k)
    speeds = np.where(np.isnan(speeds), measured.mean(), speeds)
    shares = speeds / speeds.sum()
    shares = np.maximum(shares, floor / k)
    return shares / shares.sum()


def resplit_from_stats(plan: list[tuple[int, int]], stats, weights=None, *,
                       align: int = 1, floor: float = 0.25
                       ) -> tuple[list[list[tuple[int, int]]], np.ndarray]:
    """Re-split ``plan`` using last epoch's per-host ``StreamStats``.

    The between-epochs hook: measured per-host wall times become capacity
    ``shares`` (:func:`stream_shares_from_stats`) and the SAME global
    plan is re-cut — ``align`` keeps the new cuts on the block grid.
    Returns ``(slices, shares)``; feed ``shares`` to the next epoch's
    :class:`~repro.data.graph_stream.GraphStream` so every process
    derives the identical re-split.
    """
    stats = list(stats)
    shares = stream_shares_from_stats(stats, floor=floor)
    return (split_plan(plan, len(stats), weights, shares=shares,
                       align=align), shares)
