"""Edge partitioning for distributed full-graph GNN training.

Full-graph message passing shards the *edge list* across devices; each
device computes gather(src) -> message -> partial segment-sum, and partials
are reduced with a psum over the edge-shard axis (models/gnn/layers.py).
The partitioner pads every shard to a common length so the result is a
dense (n_shards, shard_len) array — shardable by a ShapeDtypeStruct.
"""

from __future__ import annotations

import numpy as np

from repro.core.csr import CSR


def edge_balanced_partition(csr: CSR, n_shards: int, *, pad_value: int = -1
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Split the COO edge list into ``n_shards`` equal (padded) shards.

    Returns (src, dst) of shape [n_shards, shard_len] with ``pad_value``
    marking padding (segment ops drop ids < 0).
    """
    src, dst = csr.edge_index()
    E = src.shape[0]
    shard_len = -(-E // n_shards)
    total = shard_len * n_shards
    src_p = np.full(total, pad_value, dtype=np.int64)
    dst_p = np.full(total, pad_value, dtype=np.int64)
    src_p[:E] = src
    dst_p[:E] = dst
    return src_p.reshape(n_shards, shard_len), dst_p.reshape(n_shards, shard_len)


def vertex_range_partition(csr: CSR, n_parts: int) -> list[tuple[int, int]]:
    """Contiguous vertex ranges with approximately equal edge counts
    (mirrors GraphHandle.partition_plan but for in-memory CSR)."""
    total = csr.n_edges
    targets = [(total * (i + 1)) // n_parts for i in range(n_parts)]
    cuts = np.searchsorted(csr.offsets, targets, side="left")
    cuts = np.clip(cuts, 1, csr.n_vertices)
    bounds = [0] + sorted(set(int(c) for c in cuts))
    if bounds[-1] != csr.n_vertices:
        bounds.append(csr.n_vertices)
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]
