"""Edge partitioning for distributed full-graph GNN training.

Full-graph message passing shards the *edge list* across devices; each
device computes gather(src) -> message -> partial segment-sum, and partials
are reduced with a psum over the edge-shard axis (models/gnn/layers.py).
The partitioner pads every shard to a common length so the result is a
dense (n_shards, shard_len) array — shardable by a ShapeDtypeStruct.
"""

from __future__ import annotations

import numpy as np

from repro.core.csr import CSR


def edge_balanced_partition(csr: CSR, n_shards: int, *, pad_value: int = -1
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Split the COO edge list into ``n_shards`` equal (padded) shards.

    Returns (src, dst) of shape [n_shards, shard_len] with ``pad_value``
    marking padding (segment ops drop ids < 0).
    """
    src, dst = csr.edge_index()
    E = src.shape[0]
    shard_len = -(-E // n_shards)
    total = shard_len * n_shards
    src_p = np.full(total, pad_value, dtype=np.int64)
    dst_p = np.full(total, pad_value, dtype=np.int64)
    src_p[:E] = src
    dst_p[:E] = dst
    return src_p.reshape(n_shards, shard_len), dst_p.reshape(n_shards, shard_len)


def vertex_range_partition(csr: CSR, n_parts: int) -> list[tuple[int, int]]:
    """Contiguous vertex ranges with approximately equal edge counts
    (mirrors GraphHandle.partition_plan but for in-memory CSR)."""
    total = csr.n_edges
    targets = [(total * (i + 1)) // n_parts for i in range(n_parts)]
    cuts = np.searchsorted(csr.offsets, targets, side="left")
    cuts = np.clip(cuts, 1, csr.n_vertices)
    bounds = [0] + sorted(set(int(c) for c in cuts))
    if bounds[-1] != csr.n_vertices:
        bounds.append(csr.n_vertices)
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def split_plan(plan: list[tuple[int, int]], process_count: int,
               weights=None) -> list[list[tuple[int, int]]]:
    """Assign a partition plan's entries to ``process_count`` processes.

    Each process receives a *contiguous* run of plan entries (so its
    vertex coverage is one contiguous range and its storage reads stay
    sequential — the access pattern PG-Fuse readahead is built for).
    The concatenation of the returned slices is exactly ``plan``: ranges
    across processes are disjoint and cover the same vertices.

    ``weights`` (per-entry work, e.g. edge counts) balances the cut
    points; plans from ``GraphHandle.partition_plan`` are already
    edge-balanced, so the default equal-weight split inherits that
    balance.  Greedy cumulative-target cutting bounds every process at
    ``total/process_count + max(weights)``.  With more processes than
    entries the trailing processes receive empty slices.
    """
    if process_count < 1:
        raise ValueError(f"process_count must be >= 1, got {process_count}")
    n = len(plan)
    w = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    if w.shape != (n,):
        raise ValueError(f"weights shape {w.shape} != ({n},)")
    if np.any(w < 0):
        raise ValueError("weights must be >= 0")
    cum = np.concatenate([[0.0], np.cumsum(w)])
    total = cum[-1]
    bounds = [0]
    for i in range(process_count):
        target = total * (i + 1) / process_count
        cut = int(np.searchsorted(cum, target, side="left"))
        bounds.append(min(n, max(bounds[-1], cut)))
    bounds[-1] = n
    return [plan[bounds[i]: bounds[i + 1]] for i in range(process_count)]


def host_vertex_range(entries: list[tuple[int, int]]) -> tuple[int, int]:
    """Vertex range [v0, v1) covered by one process's plan slice
    (empty slices cover nothing and report (0, 0))."""
    if not entries:
        return (0, 0)
    return (entries[0][0], entries[-1][1])
