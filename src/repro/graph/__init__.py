from repro.graph.features import (LABEL_FAMILY_D,  # noqa: F401
                                  featstore_for_graph, labelstore_for_graph,
                                  synthesize_node_features,
                                  synthesize_node_labels,
                                  synthesize_separable_labels,
                                  write_node_features)
from repro.graph.generators import erdos_renyi, rmat  # noqa: F401
from repro.graph.partition import (edge_balanced_partition,  # noqa: F401
                                   resplit_from_stats, split_plan,
                                   stream_shares_from_stats)
from repro.graph.reorder import (CompileReport, bfs_order,  # noqa: F401
                                 compile_graph, degree_order,
                                 invert_permutation, map_back, permute_csr,
                                 read_sidecar, write_sidecar)
from repro.graph.sampler import NeighborSampler, SampledBlock  # noqa: F401
