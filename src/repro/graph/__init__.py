from repro.graph.features import (featstore_for_graph,  # noqa: F401
                                  synthesize_node_features,
                                  write_node_features)
from repro.graph.generators import erdos_renyi, rmat  # noqa: F401
from repro.graph.partition import (edge_balanced_partition,  # noqa: F401
                                   resplit_from_stats, split_plan,
                                   stream_shares_from_stats)
from repro.graph.sampler import NeighborSampler, SampledBlock  # noqa: F401
