from repro.graph.generators import erdos_renyi, rmat  # noqa: F401
from repro.graph.partition import edge_balanced_partition  # noqa: F401
from repro.graph.sampler import NeighborSampler, SampledBlock  # noqa: F401
