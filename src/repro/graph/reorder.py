"""The offline graph compiler: locality reordering + recompression.

The ROADMAP's recompression stage, and the consumer of the codec
registry (:mod:`repro.core.codec`).  The pipeline is

1. **order** — compute a locality-improving vertex permutation
   (:func:`bfs_order` from a max-degree root, :func:`degree_order`, or
   identity), selected by :func:`repro.core.policy.choose_reorder`;
2. **permute** — remap the CSR through the permutation
   (:func:`permute_csr`): ids renamed, rows re-sorted, so each
   neighborhood's vertices land on nearby ids — a batch's packed-byte
   reads then touch fewer PG-Fuse blocks, and the PG-Fuse/hot-set hit
   rates rise on the same logical trace (the ``benchmarks/reorder``
   suite gates exactly this);
3. **encode** — re-serialize through ANY registered codec (CompBin or
   the bit-packed LogCSR), plus a **sidecar** holding the inverse
   permutation so query answers map back to original ids byte-
   identically (:func:`map_back`).

A compiled graph is queried in its NEW id space: translate request ids
with ``new_of_old``, answer, then :func:`map_back` the neighbor lists
with the sidecar's ``old_of_new`` — for sorted adjacency lists the
result equals the original graph's answer exactly.

Sidecar layout (little-endian): 16-byte header (magic b"GPRM",
version u16, 2 pad, n_vertices u64) followed by ``old_of_new`` as
``|V|`` u64 words.
"""

from __future__ import annotations

import dataclasses
import os
import struct
from typing import Optional, Union

import numpy as np

from repro.core import codec as _codec
from repro.core import policy as _policy
from repro.core.csr import CSR, csr_from_edges

SIDECAR_MAGIC = b"GPRM"
SIDECAR_VERSION = 1
_SIDECAR_STRUCT = struct.Struct("<4sHxxQ")
SIDECAR_HEADER_SIZE = 16
assert _SIDECAR_STRUCT.size == SIDECAR_HEADER_SIZE


# ---------------------------------------------------------------------------
# orderings
# ---------------------------------------------------------------------------


def bfs_order(csr: CSR) -> np.ndarray:
    """BFS level-order permutation ``new_of_old`` from a max-degree root.

    Vertices are numbered in visit order: level by level, ascending old
    id within a level (deterministic).  Each further component restarts
    at its max-degree unvisited vertex, so disconnected hubs still lead
    their component's block.  Neighborhoods end up numerically clustered
    — the locality the paper leaves on the table when vertex order is
    "whatever the input had".
    """
    n = csr.n_vertices
    new_of_old = np.full(n, -1, dtype=np.int64)
    if n == 0:
        return new_of_old
    degrees = csr.degrees()
    # visit components by descending root degree (ties: ascending id)
    root_rank = np.lexsort((np.arange(n), -degrees))
    visited = np.zeros(n, dtype=bool)
    next_id = 0
    for root in root_rank:
        if visited[root]:
            continue
        visited[root] = True
        frontier = np.array([root], dtype=np.int64)
        while frontier.size:
            new_of_old[frontier] = np.arange(
                next_id, next_id + frontier.size)
            next_id += frontier.size
            # all neighbors of the level in one gather, then the unseen
            # ones (sorted unique = ascending ids within the next level)
            spans = [csr.neighbors[csr.offsets[v]:csr.offsets[v + 1]]
                     for v in frontier]
            nxt = np.unique(np.concatenate(spans)) if spans else \
                np.zeros(0, np.int64)
            nxt = nxt[~visited[nxt]]
            visited[nxt] = True
            frontier = nxt.astype(np.int64)
    assert next_id == n
    return new_of_old


def degree_order(csr: CSR) -> np.ndarray:
    """Hubs-first permutation ``new_of_old``: descending degree,
    ascending old id on ties — the cheap frequency clustering (the hot
    set lands in the first blocks)."""
    n = csr.n_vertices
    order = np.lexsort((np.arange(n), -csr.degrees()))  # old ids by rank
    new_of_old = np.empty(n, dtype=np.int64)
    new_of_old[order] = np.arange(n)
    return new_of_old


def identity_order(csr: CSR) -> np.ndarray:
    return np.arange(csr.n_vertices, dtype=np.int64)


ORDER_FNS = {
    "bfs": bfs_order,
    "degree": degree_order,
    "identity": identity_order,
}


def invert_permutation(perm: np.ndarray) -> np.ndarray:
    """``inv[perm[i]] = i`` — turns ``new_of_old`` into ``old_of_new``
    and vice versa.  Validates that ``perm`` IS a permutation."""
    perm = np.asarray(perm, dtype=np.int64)
    n = perm.size
    inv = np.full(n, -1, dtype=np.int64)
    if n and (perm.min() < 0 or perm.max() >= n):
        raise ValueError("not a permutation: ids out of range")
    inv[perm] = np.arange(n)
    if (inv < 0).any():
        raise ValueError("not a permutation: duplicate ids")
    return inv


def permute_csr(csr: CSR, new_of_old: np.ndarray) -> CSR:
    """Rename every vertex through ``new_of_old`` and rebuild the CSR
    (rows re-sorted ascending in the new id space)."""
    new_of_old = np.asarray(new_of_old, dtype=np.int64)
    if new_of_old.size != csr.n_vertices:
        raise ValueError(f"permutation has {new_of_old.size} entries "
                         f"for |V|={csr.n_vertices}")
    invert_permutation(new_of_old)  # validation only
    src, dst = csr.edge_index()
    return csr_from_edges(new_of_old[np.asarray(src, dtype=np.int64)],
                          new_of_old[np.asarray(dst, dtype=np.int64)],
                          csr.n_vertices)


def map_back(old_of_new: np.ndarray, new_ids: np.ndarray) -> np.ndarray:
    """Translate a neighbor run answered in compiled-id space back to
    ORIGINAL ids, re-sorted ascending — byte-identical to the original
    graph's (sorted) adjacency list."""
    old = np.asarray(old_of_new, dtype=np.int64)[
        np.asarray(new_ids, dtype=np.int64)]
    return np.sort(old)


# ---------------------------------------------------------------------------
# the sidecar (inverse permutation persisted next to the compiled graph)
# ---------------------------------------------------------------------------


def sidecar_path_for(graph_path: Union[str, os.PathLike]) -> str:
    return os.fspath(graph_path) + ".perm"


def write_sidecar(path: Union[str, os.PathLike],
                  old_of_new: np.ndarray) -> int:
    """Persist ``old_of_new`` (compiled id -> original id)."""
    old_of_new = np.asarray(old_of_new, dtype=np.int64)
    invert_permutation(old_of_new)  # refuse to persist a non-permutation
    header = _SIDECAR_STRUCT.pack(SIDECAR_MAGIC, SIDECAR_VERSION,
                                  old_of_new.size)
    body = old_of_new.astype("<u8").tobytes()
    with open(path, "wb") as f:
        n = f.write(header)
        n += f.write(body)
    return n


def read_sidecar(path: Union[str, os.PathLike]) -> np.ndarray:
    """Load ``old_of_new`` back (int64), validating the header."""
    with open(path, "rb") as f:
        raw = f.read(SIDECAR_HEADER_SIZE)
        if len(raw) != SIDECAR_HEADER_SIZE:
            raise ValueError("truncated permutation sidecar header")
        magic, version, n = _SIDECAR_STRUCT.unpack(raw)
        if magic != SIDECAR_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a permutation "
                             f"sidecar")
        if version != SIDECAR_VERSION:
            raise ValueError(f"unsupported sidecar version {version}")
        body = f.read(8 * n)
    if len(body) != 8 * n:
        raise IOError(f"corrupt/truncated sidecar: promises {n} entries, "
                      f"holds {len(body) // 8}")
    old_of_new = np.frombuffer(body, dtype="<u8").astype(np.int64)
    invert_permutation(old_of_new)
    return old_of_new


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompileReport:
    """What one :func:`compile_graph` run did (the CLI prints this)."""

    in_path: str
    out_path: str
    sidecar_path: str
    codec: str
    strategy: str
    reason: str
    n_vertices: int
    n_edges: int
    in_bytes: int
    out_bytes: int
    verified_vertices: int

    @property
    def compression_ratio(self) -> float:
        """Input bytes per output byte (> 1: the compile shrank it)."""
        return self.in_bytes / self.out_bytes if self.out_bytes else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["compression_ratio"] = self.compression_ratio
        return d


def compile_graph(in_path: Union[str, os.PathLike],
                  out_path: Union[str, os.PathLike], *,
                  codec: str = "compbin",
                  strategy: Optional[str] = None,
                  sidecar: Optional[Union[str, os.PathLike]] = None,
                  verify_samples: int = 64,
                  seed: int = 0) -> CompileReport:
    """Reorder + re-encode one on-disk graph (the offline compile).

    Reads ``in_path`` (any registered codec), applies the permutation
    :func:`repro.core.policy.choose_reorder` picks (or the explicit
    ``strategy``), writes the compiled graph to ``out_path`` with codec
    ``codec`` and the inverse permutation to ``sidecar`` (default:
    ``out_path + ".perm"``).  Before returning it samples
    ``verify_samples`` vertices and asserts the compiled graph's
    answers, mapped back through the sidecar, equal the original's —
    the compile is refused (files removed) if they ever differ.
    """
    from repro.core import paragrapher

    spec = _codec.get_codec(codec)
    in_path = os.fspath(in_path)
    out_path = os.fspath(out_path)
    sidecar = os.fspath(sidecar) if sidecar is not None \
        else sidecar_path_for(out_path)

    with paragrapher.open_graph(in_path) as g:
        original = g.read_full()
    plan = _policy.choose_reorder(original.n_vertices, original.n_edges,
                                  strategy=strategy)
    new_of_old = ORDER_FNS[plan.strategy](original)
    old_of_new = invert_permutation(new_of_old)
    compiled = permute_csr(original, new_of_old)

    out_bytes = spec.write(out_path, compiled)
    write_sidecar(sidecar, old_of_new)

    # sample verification: compiled answers must map back byte-identically
    rng = np.random.default_rng(seed)
    n_check = min(verify_samples, original.n_vertices)
    sample = rng.choice(original.n_vertices, size=n_check, replace=False) \
        if n_check else np.zeros(0, np.int64)
    rdr = spec.open(out_path)
    try:
        for v in sample:
            v = int(v)
            got = map_back(old_of_new,
                           np.asarray(rdr.neighbors_of(new_of_old[v])))
            want = np.sort(np.asarray(
                original.neighbors[original.offsets[v]:
                                   original.offsets[v + 1]],
                dtype=np.int64))
            if not np.array_equal(got, want):
                raise AssertionError(
                    f"compiled graph diverged at vertex {v}: inverse-"
                    f"mapped answer != original adjacency list")
    except BaseException:
        rdr.close()
        for p in (out_path, sidecar):  # never leave a bad compile behind
            if os.path.exists(p):
                os.remove(p)
        raise
    rdr.close()

    return CompileReport(
        in_path=in_path, out_path=out_path, sidecar_path=sidecar,
        codec=codec, strategy=plan.strategy, reason=plan.reason,
        n_vertices=original.n_vertices, n_edges=original.n_edges,
        in_bytes=os.path.getsize(in_path), out_bytes=out_bytes,
        verified_vertices=int(n_check))
