"""Synthetic graph generators (paper §I: synthetic generators are one of the
three pillars of algorithm evaluation; the paper's g500 dataset is a
Graph500 RMAT graph).

RMAT [Chakrabarti et al., SDM'04] with Graph500 parameters
(a,b,c,d) = (0.57, 0.19, 0.19, 0.05) produces the skewed, power-law-ish
degree distributions of web/social graphs — the regime where WebGraph
compression shines and CompBin pays storage for decode speed.
"""

from __future__ import annotations

import numpy as np

from repro.core.csr import CSR, csr_from_edges


def rmat(scale: int, edge_factor: int = 16, *,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         seed: int = 0, dedupe: bool = True) -> CSR:
    """RMAT graph with 2^scale vertices and ~edge_factor * 2^scale edges."""
    n = 1 << scale
    n_edges = edge_factor * n
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    ab = a + b
    abc = a + b + c
    for level in range(scale):
        r = rng.random(n_edges)
        go_right = (r >= a) & (r < ab) | (r >= abc)   # quadrant b or d
        go_down = r >= ab                             # quadrant c or d
        src |= (go_down.astype(np.int64) << level)
        dst |= (go_right.astype(np.int64) << level)
    return csr_from_edges(src, dst, n, dedupe=dedupe)


def erdos_renyi(n_vertices: int, n_edges: int, *, seed: int = 0,
                dedupe: bool = True) -> CSR:
    """Uniform random directed graph (low-skew contrast to RMAT)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    return csr_from_edges(src, dst, n_vertices, dedupe=dedupe)


def bipartite_mesh(nx: int, ny: int) -> CSR:
    """Regular 2-D mesh (MeshGraphNet-style simulation meshes): node (i,j)
    connects to its 4-neighborhood, both directions."""
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    srcs, dsts = [], []
    for (sa, sb) in [((slice(None, -1), slice(None)), (slice(1, None), slice(None))),
                     ((slice(None), slice(None, -1)), (slice(None), slice(1, None)))]:
        u = idx[sa].reshape(-1)
        v = idx[sb].reshape(-1)
        srcs += [u, v]
        dsts += [v, u]
    return csr_from_edges(np.concatenate(srcs), np.concatenate(dsts), n, dedupe=True)
