"""Layer-wise fanout neighbor sampling (GraphSAGE regime) — the host-side
producer for the ``minibatch_lg`` shape.

The sampler reads adjacency through the ParaGrapher API (or an in-memory
CSR), so on a pod each host samples its own seed range while the graph
lives in CompBin on shared storage behind PG-Fuse — the paper's loading
path *is* the sampler's hot loop.

Preferred adjacency source: a
:class:`repro.query.NeighborQueryEngine` (anything exposing
``neighbors_batch``) — each layer's whole frontier is fetched as ONE
deduplicated, block-coalesced batch instead of one storage round-trip
per slot, which is where CompBin's byte-addressable random access
(paper §IV) actually pays.  The sampled output is bit-identical to the
per-vertex path for the same seed: only the fetch is batched, the RNG
consumption order is unchanged.

Output is a **padded tree layout** with static shapes (required for jit):
layer l holds ``n_seeds * prod(fanouts[:l])`` node slots; slot ``i`` of
layer l+1 region ``[i*f : (i+1)*f]`` holds the sampled neighbors of layer-l
slot ``i``.  Missing neighbors (degree < fanout) are marked invalid and
masked in the aggregation (models/gnn/layers.py::tree_aggregate).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import numpy as np

from repro.core.csr import CSR
from repro.core.paragrapher import GraphHandle


@dataclasses.dataclass
class SampledBlock:
    """One minibatch of layered samples (all arrays static-shaped)."""

    seeds: np.ndarray                 # int64[n_seeds]
    layer_nodes: list[np.ndarray]     # [n_seeds * prod(fanouts[:l])] per layer
    layer_valid: list[np.ndarray]     # bool, same shapes
    fanouts: tuple[int, ...]

    @property
    def frontier(self) -> np.ndarray:
        return self.layer_nodes[-1]

    def num_nodes(self) -> int:
        return sum(len(x) for x in self.layer_nodes)


class NeighborSampler:
    """Uniform fanout sampler over a CSR, an open ParaGrapher handle, or
    a batched query engine (``neighbors_batch`` duck type)."""

    def __init__(self, graph: Union[CSR, GraphHandle], fanouts: Sequence[int],
                 *, seed: int = 0):
        self._g = graph
        self._batched = hasattr(graph, "neighbors_batch")
        self.fanouts = tuple(int(f) for f in fanouts)
        self._rng = np.random.default_rng(seed)

    def _neighbors(self, v: int) -> np.ndarray:
        if isinstance(self._g, CSR):
            return self._g.neighbors_of(v)
        return self._g.neighbors_of(v)

    def _layer_adjacency(self, nodes: np.ndarray, valid: np.ndarray) -> dict:
        """Adjacency for one layer's frontier, keyed by vertex id.

        With a query engine the whole frontier goes out as one
        deduplicated coalesced batch (vertices shared between slots — the
        hub-heavy common case — are fetched once); otherwise each unique
        vertex is read individually.
        """
        if self._batched:
            # the engine dedups internally — handing it the raw frontier
            # (repeated hubs and all) keeps its dedup-ratio stats honest.
            # The per-slot lists come back as views into the decoded
            # spans, so this is already copy-free; the engine's ragged
            # form exists for consumers that want ONE flat buffer.
            live = nodes[valid]
            lists = self._g.neighbors_batch(live)
            return {int(v): np.asarray(nbrs) for v, nbrs in zip(live, lists)}
        uniq = np.unique(nodes[valid]) if valid.any() else np.zeros(0, np.int64)
        lists = [self._neighbors(int(v)) for v in uniq]
        return {int(v): np.asarray(nbrs) for v, nbrs in zip(uniq, lists)}

    @property
    def n_vertices(self) -> int:
        return self._g.n_vertices

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        seeds = np.asarray(seeds, dtype=np.int64)
        layer_nodes = [seeds]
        layer_valid = [np.ones(len(seeds), dtype=bool)]
        for f in self.fanouts:
            prev = layer_nodes[-1]
            prev_valid = layer_valid[-1]
            adj = self._layer_adjacency(prev, prev_valid)
            nxt = np.full(len(prev) * f, -1, dtype=np.int64)
            val = np.zeros(len(prev) * f, dtype=bool)
            for i, (v, ok) in enumerate(zip(prev, prev_valid)):
                if not ok:
                    continue
                nbrs = adj[int(v)]
                d = len(nbrs)
                if d == 0:
                    continue
                if d >= f:
                    pick = self._rng.choice(nbrs, size=f, replace=False)
                    nxt[i * f : (i + 1) * f] = pick
                    val[i * f : (i + 1) * f] = True
                else:
                    nxt[i * f : i * f + d] = nbrs
                    val[i * f : i * f + d] = True
            layer_nodes.append(nxt)
            layer_valid.append(val)
        return SampledBlock(seeds=seeds, layer_nodes=layer_nodes,
                            layer_valid=layer_valid, fanouts=self.fanouts)

    def sample_batches(self, batch_nodes: int, n_batches: int):
        """Yield blocks over random seed batches (training epochs)."""
        n = self.n_vertices
        for _ in range(n_batches):
            seeds = self._rng.integers(0, n, batch_nodes)
            yield self.sample(seeds)
