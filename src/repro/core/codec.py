"""Pluggable on-disk graph codecs — one contract, many encodings.

This module is the seam the locality-ordering graph compiler
(src/repro/graph/reorder.py) re-encodes through: every codec registers a
:class:`CodecSpec` here, and `GraphHandle`, `NeighborQueryEngine` and
`GraphStream` consume *any* registered codec through the same surface
instead of hardcoding CompBin.

The **direct-addressing contract** (``CodecSpec.direct = True``) is what
the random-access paths require of a reader ``spec.open(file)``:

* metadata: ``n_vertices``, ``n_edges``, ``b`` (bytes per packed
  neighbor id), ``header`` with ``neighbors_start`` / ``total_size``;
* offsets addressing on the header: ``offsets_span(a, z)`` -> byte span
  covering ``offsets[a ..= z+1]``, ``decode_offsets(raw, a, z)`` ->
  int64 array, ``offsets_gap_vertices(gap_bytes)`` -> merge-gap width;
* neighbors: byte-packed little-endian ids of fixed width ``b`` at
  ``neighbors_start`` (eq. (1) packing), so the byte address of the
  n-th neighbor of v is ``neighbors_start + (offsets[v] + n) * b`` and
  ONE Pallas decode kernel (kernels/compbin_decode) serves every direct
  codec;
* reads: ``offsets(v0, v1)``, ``read_edge_range``, ``neighbors_of``,
  ``read_partition``, ``read_full``, ``raw_neighbor_bytes``, ``close``
  — all safe to call concurrently (positional reads).

Sequential codecs (``direct = False``, e.g. WebGraph's bit-level gamma/
zeta codes) only promise the loading surface (``read_partition`` /
``read_full`` / ``neighbors_of`` / ``bit_offsets``); the query engine
rejects them.

The second direct codec implemented here, **LogCSR**, applies the
Log(Graph) idea (PAPERS.md) to the offsets array: offsets are stored
bit-packed at ``obits = max(1, ceil(log2(|E|+1)))`` bits per entry
instead of CompBin's fixed 8 bytes, while neighbors keep the exact
CompBin byte packing.  On-disk layout (little-endian)::

    +---------------------+--------------------------------------+
    | magic      4 bytes  | b"LGSR"                              |
    | version    u16      | 1                                    |
    | b          u8       | bytes per neighbor id (CompBin rule) |
    | obits      u8       | bits per offsets entry (1..57 or 64) |
    | flags      u8       | bit0: neighbors sorted per row       |
    | pad        3 bytes  | zero                                 |
    | n_vertices u64      |                                      |
    | n_edges    u64      |                                      |
    | offsets_nbytes u64  | bit-packed size incl. 8 guard bytes  |
    +---------------------+--------------------------------------+
    | offsets   ceil((|V|+1)*obits/8) bytes + 8 zero guard bytes |
    +------------------------------------------------------------+
    | neighbors |E| * b bytes (eq. (1) packing, as CompBin)      |
    +------------------------------------------------------------+

Entry ``i`` occupies bits ``[i*obits, (i+1)*obits)`` of the offsets
section, LSB-first within the little-endian byte stream.  The 8 guard
bytes let the reader decode any entry with one unaligned 8-byte window
load (``value = window >> (bit & 7) & mask``), which is why ``obits``
is capped: any width that would straddle more than 64 bits after the
worst-case 7-bit shift (58..63) is rounded up to 64 (plain ``<u8``,
i.e. CompBin-shaped offsets).  For web-scale graphs ``obits`` ~ 35-40,
a ~2x offsets-section saving over CompBin.
"""

from __future__ import annotations

import dataclasses
import io
import os
import struct
import threading
from typing import BinaryIO, Callable, Optional, Union

import numpy as np

from repro.core import compbin, webgraph
from repro.core.csr import CSR

# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """One registered on-disk codec.

    ``write(path_or_file, csr) -> bytes_written`` serializes;
    ``open(file_like) -> reader`` returns the codec's reader (validating
    the header eagerly); ``direct`` declares the direct-addressing
    contract above (a requirement of the query engine and the raw
    device-decode streaming path); ``suffix`` is the conventional file
    extension (golden fixtures, the compile_graph CLI); ``nbytes``
    predicts the on-disk size of a CSR without encoding it (None when
    only encoding can tell, e.g. entropy-coded formats).
    """

    name: str
    magic: bytes
    suffix: str
    direct: bool
    write: Callable[..., int]
    open: Callable[[Union[str, os.PathLike, BinaryIO]], object]
    nbytes: Optional[Callable[[int, int], int]] = None


_registry: dict[str, CodecSpec] = {}
_by_magic: dict[bytes, CodecSpec] = {}


def register_codec(spec: CodecSpec) -> CodecSpec:
    """Add ``spec`` to the registry (idempotent per name+magic)."""
    if len(spec.magic) != 4:
        raise ValueError(f"codec magic must be 4 bytes, got {spec.magic!r}")
    prev = _registry.get(spec.name)
    if prev is not None and prev.magic != spec.magic:
        raise ValueError(f"codec {spec.name!r} already registered "
                         f"with magic {prev.magic!r}")
    _registry[spec.name] = spec
    _by_magic[spec.magic] = spec
    return spec


def get_codec(name: str) -> CodecSpec:
    try:
        return _registry[name]
    except KeyError:
        raise ValueError(f"unknown codec {name!r}; registered: "
                         f"{', '.join(sorted(_registry))}") from None


def codec_for_magic(magic: bytes) -> Optional[CodecSpec]:
    """The codec owning a 4-byte magic, or None."""
    return _by_magic.get(bytes(magic[:4]))


def registered_codecs() -> dict[str, CodecSpec]:
    return dict(sorted(_registry.items()))


def direct_codecs() -> list[str]:
    """Names of codecs honoring the direct-addressing contract."""
    return [n for n, s in sorted(_registry.items()) if s.direct]


# ---------------------------------------------------------------------------
# LogCSR — bit-packed offsets, CompBin-packed neighbors
# ---------------------------------------------------------------------------

LOGCSR_MAGIC = b"LGSR"
LOGCSR_VERSION = 1
LOGCSR_HEADER_SIZE = 36
_LOGCSR_STRUCT = struct.Struct("<4sHBBB3xQQQ")
assert _LOGCSR_STRUCT.size == LOGCSR_HEADER_SIZE
_GUARD_BYTES = 8  # lets any entry be read with one 8-byte window load


def offset_bits(n_edges: int) -> int:
    """Bits per offsets entry: enough for the largest value (``|E|``),
    capped so a 7-bit-shifted window load never straddles 64 bits —
    widths 58..63 round up to the byte-aligned 64."""
    if n_edges < 0:
        raise ValueError("n_edges must be >= 0")
    obits = max(1, int(n_edges).bit_length())
    return 64 if obits > 57 else obits


def packed_offsets_nbytes(n_vertices: int, obits: int) -> int:
    """On-disk bytes of the bit-packed offsets section, guard included."""
    return ((n_vertices + 1) * obits + 7) // 8 + _GUARD_BYTES


def pack_offsets(offsets: np.ndarray, obits: int) -> bytes:
    """Bit-pack ``offsets`` LSB-first at ``obits`` bits per entry."""
    vals = np.ascontiguousarray(offsets, dtype=np.uint64)
    if vals.size and int(vals.max()) >= (1 << obits) and obits < 64:
        raise ValueError(f"offset {int(vals.max())} does not fit "
                         f"in {obits} bits")
    if obits == 64:
        return vals.astype("<u8").tobytes() + b"\0" * _GUARD_BYTES
    nbytes = (vals.size * obits + 7) // 8 + _GUARD_BYTES
    buf = np.zeros(nbytes, dtype=np.uint8)
    bit = np.arange(vals.size, dtype=np.int64) * obits
    byte, shift = bit >> 3, (bit & 7).astype(np.uint64)
    # each shifted entry fits one u64 (obits <= 57, shift <= 7): spread
    # its 8 LE bytes and OR them in place (entries may share bytes)
    chunk = vals << shift
    lanes = np.arange(8, dtype=np.uint64)
    chunk_bytes = ((chunk[:, None] >> (8 * lanes)) & np.uint64(0xFF)
                   ).astype(np.uint8)
    np.bitwise_or.at(buf, byte[:, None] + np.arange(8), chunk_bytes)
    return buf.tobytes()


def unpack_offsets(raw: bytes, obits: int, first_bit: int,
                   count: int) -> np.ndarray:
    """Decode ``count`` entries whose first entry starts at ``first_bit``
    relative to ``raw`` (which must extend 8 bytes past the start byte
    of the last entry — the guard guarantee)."""
    u8 = np.frombuffer(raw, dtype=np.uint8)
    bit = first_bit + np.arange(count, dtype=np.int64) * obits
    byte, shift = bit >> 3, (bit & 7).astype(np.uint64)
    win = np.ascontiguousarray(
        u8[byte[:, None] + np.arange(8)]).view("<u8")[:, 0]
    vals = win >> shift
    if obits < 64:
        vals = vals & np.uint64((1 << obits) - 1)
    return vals.astype(np.int64)


@dataclasses.dataclass
class LogCSRHeader:
    b: int
    obits: int
    flags: int
    n_vertices: int
    n_edges: int
    offsets_nbytes: int

    @property
    def offsets_start(self) -> int:
        return LOGCSR_HEADER_SIZE

    @property
    def neighbors_start(self) -> int:
        return LOGCSR_HEADER_SIZE + self.offsets_nbytes

    @property
    def total_size(self) -> int:
        return self.neighbors_start + self.b * self.n_edges

    # -- the direct-addressing contract ------------------------------------
    def offsets_span(self, a: int, z: int) -> tuple[int, int]:
        """(byte start, byte length) covering ``offsets[a ..= z+1]``.

        The span always reaches 8 bytes past the LAST entry's start byte
        so :func:`unpack_offsets` can window-load it; the file's guard
        bytes keep that in-bounds even at ``z + 1 == n_vertices``.
        """
        start = self.offsets_start + ((a * self.obits) >> 3)
        last_start = self.offsets_start + (((z + 1) * self.obits) >> 3)
        return start, last_start + 8 - start

    def decode_offsets(self, raw: bytes, a: int, z: int) -> np.ndarray:
        first_bit = a * self.obits - 8 * ((a * self.obits) >> 3)
        return unpack_offsets(raw, self.obits, first_bit, z - a + 2)

    def offsets_gap_vertices(self, gap_bytes: int) -> int:
        return max(1, (8 * gap_bytes) // self.obits)


def logcsr_nbytes(n_vertices: int, n_edges: int) -> int:
    """Total on-disk size of a LogCSR file."""
    obits = offset_bits(n_edges)
    return (LOGCSR_HEADER_SIZE + packed_offsets_nbytes(n_vertices, obits)
            + compbin.bytes_per_vertex(n_vertices) * n_edges)


def write_logcsr(path_or_file: Union[str, os.PathLike, BinaryIO], csr: CSR,
                 *, sorted_rows: bool = True) -> int:
    """Serialize ``csr`` to LogCSR. Returns bytes written."""
    b = compbin.bytes_per_vertex(csr.n_vertices)
    obits = offset_bits(csr.n_edges)
    packed_offs = pack_offsets(csr.offsets, obits)
    header = _LOGCSR_STRUCT.pack(
        LOGCSR_MAGIC, LOGCSR_VERSION, b, obits,
        compbin.FLAG_SORTED if sorted_rows else 0,
        csr.n_vertices, csr.n_edges, len(packed_offs))
    packed_ids = compbin.encode_ids(
        csr.neighbors.astype(np.uint64, copy=False), b)

    own = False
    if isinstance(path_or_file, (str, os.PathLike)):
        f: BinaryIO = open(path_or_file, "wb")
        own = True
    else:
        f = path_or_file
    try:
        n = f.write(header)
        n += f.write(packed_offs)
        n += f.write(packed_ids.tobytes())
    finally:
        if own:
            f.close()
    return n


def read_logcsr_header(f) -> LogCSRHeader:
    f.seek(0)
    raw = f.read(LOGCSR_HEADER_SIZE)
    if len(raw) != LOGCSR_HEADER_SIZE:
        raise ValueError("truncated LogCSR header")
    magic, version, b, obits, flags, n_v, n_e, off_nb = \
        _LOGCSR_STRUCT.unpack(raw)
    if magic != LOGCSR_MAGIC:
        raise ValueError(f"bad magic {magic!r}; not a LogCSR file")
    if version != LOGCSR_VERSION:
        raise ValueError(f"unsupported LogCSR version {version}")
    # same hardening rule as CompBin's read_header: every field the
    # addressing arithmetic trusts is validated before any payload read
    if not 1 <= b <= 8:
        raise IOError(f"corrupt LogCSR header: b={b} outside [1, 8]")
    if not (1 <= obits <= 57 or obits == 64):
        raise IOError(f"corrupt LogCSR header: obits={obits} "
                      f"outside [1, 57] u {{64}}")
    if flags & ~compbin.FLAG_SORTED:
        raise IOError(f"corrupt LogCSR header: unknown flags 0x{flags:x}")
    if off_nb != packed_offsets_nbytes(n_v, obits):
        raise IOError(
            f"corrupt LogCSR header: offsets_nbytes={off_nb}, expected "
            f"{packed_offsets_nbytes(n_v, obits)} for |V|={n_v}, "
            f"obits={obits}")
    hdr = LogCSRHeader(b=b, obits=obits, flags=flags, n_vertices=n_v,
                       n_edges=n_e, offsets_nbytes=off_nb)
    actual = compbin._file_size(f)
    if actual is not None and actual < hdr.total_size:
        raise IOError(
            f"corrupt/truncated LogCSR file: header promises "
            f"{hdr.total_size} bytes (|V|={n_v}, |E|={n_e}, b={b}, "
            f"obits={obits}) but the file holds {actual}")
    return hdr


class LogCSRFile:
    """Random-access LogCSR reader — same surface as
    :class:`repro.core.compbin.CompBinFile` (the direct-addressing
    contract), different offsets decode."""

    def __init__(self, file: Union[str, os.PathLike, BinaryIO]):
        if isinstance(file, (str, os.PathLike)):
            self._f: BinaryIO = open(file, "rb")
            self._own = True
        else:
            self._f = file
            self._own = False
        self._lock = threading.Lock()
        self._pread_fn = getattr(self._f, "pread", None)
        self.header = read_logcsr_header(self._f)
        self._offsets_cache: Optional[np.ndarray] = None

    def _pread(self, start: int, nbytes: int) -> bytes:
        if self._pread_fn is not None:
            return self._pread_fn(start, nbytes)
        with self._lock:
            self._f.seek(start)
            return self._f.read(nbytes)

    # -- metadata ---------------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self.header.n_vertices

    @property
    def n_edges(self) -> int:
        return self.header.n_edges

    @property
    def b(self) -> int:
        return self.header.b

    # -- offsets ----------------------------------------------------------
    def offsets(self, v0: int = 0, v1: Optional[int] = None) -> np.ndarray:
        """Read offsets[v0 : v1+1] (inclusive upper fence)."""
        if v1 is None:
            v1 = self.n_vertices
        if self._offsets_cache is not None:
            return self._offsets_cache[v0 : v1 + 1]
        start, nbytes = self.header.offsets_span(v0, v1 - 1)
        raw = self._pread(start, nbytes)
        return self.header.decode_offsets(raw, v0, v1 - 1)

    def preload_offsets(self) -> None:
        self._offsets_cache = self.offsets(0, self.n_vertices)

    # -- neighbors (identical byte packing to CompBin) --------------------
    def read_edge_range(self, e0: int, e1: int) -> np.ndarray:
        """Decode neighbors[e0:e1] (global edge indices) — eq. (1)."""
        b = self.header.b
        raw = self._pread(self.header.neighbors_start + b * e0,
                          b * (e1 - e0))
        return compbin.decode_ids(np.frombuffer(raw, dtype=np.uint8), b)

    def neighbors_of(self, v: int) -> np.ndarray:
        offs = self.offsets(v, v + 1)
        return self.read_edge_range(int(offs[0]), int(offs[1]))

    def read_partition(self, v0: int, v1: int
                       ) -> tuple[np.ndarray, np.ndarray]:
        offs = self.offsets(v0, v1)
        nbrs = self.read_edge_range(int(offs[0]), int(offs[-1]))
        return (offs - offs[0]).astype(np.int64), nbrs

    def read_full(self) -> CSR:
        offs = self.offsets()
        nbrs = self.read_edge_range(0, self.n_edges)
        dtype = np.int32 if self.n_vertices <= np.iinfo(np.int32).max \
            else np.int64
        return CSR(offsets=offs.astype(np.int64),
                   neighbors=nbrs.astype(dtype))

    def raw_neighbor_bytes(self, e0: int, e1: int) -> np.ndarray:
        """Packed (undecoded) bytes for edges [e0, e1) — decodable by the
        same Pallas kernel as CompBin's stream (identical packing)."""
        b = self.header.b
        raw = self._pread(self.header.neighbors_start + b * e0,
                          b * (e1 - e0))
        return np.frombuffer(raw, dtype=np.uint8)

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self) -> "LogCSRFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_logcsr(path: Union[str, os.PathLike, BinaryIO]) -> CSR:
    """Convenience: load a whole LogCSR file into an in-memory CSR."""
    with LogCSRFile(path) as f:
        return f.read_full()


def logcsr_roundtrip_bytes(csr: CSR) -> bytes:
    """Serialize to bytes in memory (tests/benchmarks)."""
    buf = io.BytesIO()
    write_logcsr(buf, csr)
    return buf.getvalue()


# ---------------------------------------------------------------------------
# the built-in codecs
# ---------------------------------------------------------------------------

COMPBIN = register_codec(CodecSpec(
    name="compbin", magic=compbin.MAGIC, suffix="cbin", direct=True,
    write=compbin.write_compbin, open=compbin.CompBinFile,
    nbytes=compbin.compbin_nbytes))

LOGCSR = register_codec(CodecSpec(
    name="logcsr", magic=LOGCSR_MAGIC, suffix="lgsr", direct=True,
    write=write_logcsr, open=LogCSRFile, nbytes=logcsr_nbytes))

WEBGRAPH = register_codec(CodecSpec(
    name="webgraph", magic=webgraph.MAGIC, suffix="wg", direct=False,
    write=webgraph.write_webgraph, open=webgraph.WebGraphFile))
