"""WebGraph-style compressed graph codec (paper §II-A baseline).

A faithful-in-spirit reimplementation of the Boldi–Vigna WebGraph format
[WWW'04] used by ParaGrapher as its input format: per-vertex successor lists
with **gap encoding** and instantaneous codes —

  * outdegree ``d``            -> gamma(d + 1)
  * first gap ``n0 - v``       -> zigzag to a natural, then zeta_k(nat + 1)
  * following gaps ``n_i - n_{i-1} - 1`` -> zeta_k(gap + 1)

with neighbors sorted ascending per row.  ``zeta_k`` (default k=3, the
WebGraph default) is the Boldi–Vigna zeta code: unary(h+1) followed by the
minimal-binary code of ``x - 2^{hk}`` in an interval of size
``2^{(h+1)k} - 2^{hk}``, where ``h = floor(floor(log2 x) / k)``.

Simplification vs. the Java WebGraph (recorded in DESIGN.md): we omit the
reference/copy-list and interval machinery, keeping only gaps + zeta codes.
Compression ratios are therefore lower than real WebGraph, but the format
retains the property the paper studies: decoding is *sequential and
compute-bound* (bit-level unary scans + table-free minimal binary), in
contrast to CompBin's O(1) byte-aligned shift+add access.

On-disk layout (little-endian):

    magic b"WGPH" | version u16 | k u8 | flags u8 | n_vertices u64 | n_edges u64
    bit_offsets  (|V|+1) * u64   (bit position of each vertex's first code,
                                  relative to the data section; last entry =
                                  total bit length)
    data          packed bits (MSB-first within each byte)

Two decoders are provided:

  * :class:`BitReader` — scalar sequential reference decoder (oracle for
    tests, and the per-vertex random-access path).
  * wavefront decode (:meth:`WebGraphFile.read_full`) — decodes one code
    per *round* across all requested vertices simultaneously with numpy,
    giving vectorized whole-graph loads.  Round count = max degree + 1.
"""

from __future__ import annotations

import dataclasses
import io
import os
import struct
from typing import BinaryIO, Optional, Union

import numpy as np

from repro.core.csr import CSR

MAGIC = b"WGPH"
VERSION = 1
HEADER_SIZE = 24
_HEADER_STRUCT = struct.Struct("<4sHBBQQ")
assert _HEADER_STRUCT.size == HEADER_SIZE

DEFAULT_K = 3


# ---------------------------------------------------------------------------
# zigzag (WebGraph nat2int/int2nat) for the v-relative first gap
# ---------------------------------------------------------------------------

def int2nat(x: np.ndarray) -> np.ndarray:
    """Signed -> natural: 0,-1,1,-2,2,... -> 0,1,2,3,4,..."""
    x = np.asarray(x, dtype=np.int64)
    return np.where(x >= 0, 2 * x, -2 * x - 1).astype(np.uint64)


def nat2int(n: np.ndarray) -> np.ndarray:
    n = np.asarray(n, dtype=np.uint64).astype(np.int64)
    return np.where(n % 2 == 0, n // 2, -(n + 1) // 2)


# ---------------------------------------------------------------------------
# code tables: (pattern, nbits) for gamma / zeta_k, vectorized
# ---------------------------------------------------------------------------

def _floor_log2(x: np.ndarray) -> np.ndarray:
    """floor(log2 x) for x >= 1 (uint64-safe)."""
    x = np.asarray(x, dtype=np.uint64)
    if np.any(x < 1):
        raise ValueError("codes are defined for x >= 1")
    out = np.zeros(x.shape, dtype=np.int64)
    v = x.copy()
    for shift in (32, 16, 8, 4, 2, 1):
        big = v >= (np.uint64(1) << np.uint64(shift))
        out += np.where(big, shift, 0)
        v = np.where(big, v >> np.uint64(shift), v)
    return out


def gamma_code(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """gamma(x), x>=1: L zeros then the (L+1)-bit binary of x (MSB first).

    Returned as (pattern, nbits) with the zeros implicit in the MSB-aligned
    pattern (pattern == x, nbits == 2L+1).
    """
    x = np.asarray(x, dtype=np.uint64)
    L = _floor_log2(x)
    return x, (2 * L + 1)


def _minimal_binary_params(z: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(s, t) for minimal binary coding of [0, z): s=ceil(log2 z), t=2^s-z."""
    z = np.asarray(z, dtype=np.uint64)
    s = _floor_log2(z)
    s = np.where((np.uint64(1) << s.astype(np.uint64)) < z, s + 1, s)
    t = (np.uint64(1) << s.astype(np.uint64)) - z
    return s, t


def zeta_code(x: np.ndarray, k: int = DEFAULT_K) -> tuple[np.ndarray, np.ndarray]:
    """Boldi–Vigna zeta_k(x), x>=1 -> (pattern, nbits), MSB-aligned."""
    x = np.asarray(x, dtype=np.uint64)
    h = _floor_log2(x) // k
    hk = (h * k).astype(np.uint64)
    lo = np.uint64(1) << hk                      # 2^{hk}
    z = (np.uint64(1) << (hk + np.uint64(k))) - lo  # interval size
    s, t = _minimal_binary_params(z)
    m = x - lo
    short = m < t
    mb_bits = np.where(short, s - 1, s)
    mb_val = np.where(short, m, m + t)
    # unary(h+1): h zeros then a 1 -> pattern 1 in (h+1) bits, then the mb code
    nbits = (h + 1) + mb_bits
    pattern = (np.uint64(1) << mb_bits.astype(np.uint64)) | mb_val
    if np.any(nbits > 64):
        raise ValueError("zeta codeword exceeds 64 bits")
    return pattern, nbits


# ---------------------------------------------------------------------------
# bit packing: many (pattern, nbits) codes -> one packed bitstream
# ---------------------------------------------------------------------------

def pack_codes(patterns: np.ndarray, nbits: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate MSB-aligned codewords into a packed bit array.

    Returns (packed_bytes uint8, bit_starts int64[len+1]).  O(max nbits)
    vectorized passes.
    """
    patterns = np.asarray(patterns, dtype=np.uint64)
    nbits = np.asarray(nbits, dtype=np.int64)
    starts = np.zeros(len(nbits) + 1, dtype=np.int64)
    np.cumsum(nbits, out=starts[1:])
    total = int(starts[-1])
    bits = np.zeros(total, dtype=np.uint8)
    maxb = int(nbits.max(initial=0))
    for j in range(maxb):
        sel = nbits > j
        pos = starts[:-1][sel] + j
        shift = (nbits[sel] - 1 - j).astype(np.uint64)
        bits[pos] = ((patterns[sel] >> shift) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits), starts


# ---------------------------------------------------------------------------
# scalar sequential decoder (reference oracle + random access)
# ---------------------------------------------------------------------------

class BitReader:
    """Sequential bit reader over an unpacked 0/1 uint8 array."""

    def __init__(self, bits: np.ndarray, pos: int = 0):
        self.bits = bits
        self.pos = pos
        # positions of set bits, for O(log) unary scans
        self._ones = np.flatnonzero(bits).astype(np.int64)

    def read_bits(self, n: int) -> int:
        if n == 0:
            return 0
        chunk = self.bits[self.pos : self.pos + n]
        self.pos += n
        v = 0
        for bit in chunk:
            v = (v << 1) | int(bit)
        return v

    def _zeros_run(self) -> int:
        i = np.searchsorted(self._ones, self.pos)
        if i >= len(self._ones):
            raise EOFError("ran off the bitstream in a unary scan")
        nxt = int(self._ones[i])
        run = nxt - self.pos
        self.pos = nxt + 1  # consume the terminating 1
        return run

    def read_gamma(self) -> int:
        L = self._zeros_run()
        return (1 << L) | self.read_bits(L)

    def read_minimal_binary(self, z: int) -> int:
        s = max(1, (z - 1).bit_length()) if z > 1 else 0
        if z == 1:
            return 0
        t = (1 << s) - z
        m = self.read_bits(s - 1)
        if m < t:
            return m
        return ((m << 1) | self.read_bits(1)) - t

    def read_zeta(self, k: int = DEFAULT_K) -> int:
        h = self._zeros_run()
        lo = 1 << (h * k)
        z = (1 << ((h + 1) * k)) - lo
        return lo + self.read_minimal_binary(z)


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode_graph(csr: CSR, k: int = DEFAULT_K) -> tuple[np.ndarray, np.ndarray]:
    """Encode a CSR graph. Returns (packed_bytes, bit_offsets[|V|+1]).

    Neighbor lists are sorted ascending (required by gap encoding).
    """
    n_v = csr.n_vertices
    degrees = csr.degrees()
    offsets = csr.offsets
    if n_v == 0:  # empty graph: no codes, a single zero bit offset
        return np.zeros(0, dtype=np.uint8), np.zeros(1, dtype=np.int64)

    # Sort each row ascending (vectorized: stable sort by (row, neighbor)).
    row = np.repeat(np.arange(n_v, dtype=np.int64), degrees)
    nbr = csr.neighbors.astype(np.int64, copy=False)
    order = np.lexsort((nbr, row))
    nbr = nbr[order]

    # Gap encoding requires strictly increasing successor lists (as in real
    # web graphs). Duplicate edges are not representable.
    same_row = row[1:] == row[:-1]
    if np.any(same_row & (nbr[1:] == nbr[:-1])):
        raise ValueError(
            "duplicate (src, dst) edge: WebGraph-style gap encoding requires "
            "strictly increasing successor lists; build the CSR with "
            "csr_from_edges(..., dedupe=True)")

    # Per-edge gap values (vectorized over all rows at once).
    is_first = np.zeros(len(nbr), dtype=bool)
    is_first[offsets[:-1][degrees > 0]] = True
    prev = np.empty_like(nbr)
    if len(nbr):  # edge-less graphs still carry their degree codes
        prev[1:] = nbr[:-1]
        prev[0] = 0
    first_nat = int2nat(nbr - row)            # first gap: zigzag(n0 - v)
    rest_gap = (nbr - prev - 1).astype(np.uint64)  # subsequent: n_i - n_{i-1} - 1
    nat = np.where(is_first, first_nat, rest_gap)

    # Build the interleaved code stream: gamma(d+1) then d zeta codes per row.
    n_codes = n_v + len(nbr)
    patterns = np.empty(n_codes, dtype=np.uint64)
    nbits = np.empty(n_codes, dtype=np.int64)
    # index of each vertex's degree code in the stream
    deg_idx = np.arange(n_v, dtype=np.int64) + offsets[:-1]
    pat_d, bits_d = gamma_code(degrees.astype(np.uint64) + 1)
    patterns[deg_idx] = pat_d
    nbits[deg_idx] = bits_d
    # index of each edge's code: edge e of row r lands at r + 1 + e_global
    edge_idx = row + 1 + np.arange(len(nbr), dtype=np.int64)
    pat_e, bits_e = zeta_code(nat + 1, k)
    patterns[edge_idx] = pat_e
    nbits[edge_idx] = bits_e

    packed, starts = pack_codes(patterns, nbits)
    bit_offsets = np.empty(n_v + 1, dtype=np.int64)
    bit_offsets[:-1] = starts[deg_idx]
    bit_offsets[-1] = starts[-1]
    return packed, bit_offsets


def write_webgraph(path_or_file: Union[str, os.PathLike, BinaryIO], csr: CSR,
                   k: int = DEFAULT_K) -> int:
    packed, bit_offsets = encode_graph(csr, k)
    header = _HEADER_STRUCT.pack(MAGIC, VERSION, k, 0, csr.n_vertices, csr.n_edges)
    own = False
    if isinstance(path_or_file, (str, os.PathLike)):
        f: BinaryIO = open(path_or_file, "wb")
        own = True
    else:
        f = path_or_file
    try:
        n = f.write(header)
        n += f.write(bit_offsets.astype("<u8").tobytes())
        n += f.write(packed.tobytes())
    finally:
        if own:
            f.close()
    return n


def webgraph_nbytes(csr: CSR, k: int = DEFAULT_K) -> int:
    packed, _ = encode_graph(csr, k)
    return HEADER_SIZE + 8 * (csr.n_vertices + 1) + packed.nbytes


# ---------------------------------------------------------------------------
# file reader with wavefront (vectorized) decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WebGraphHeader:
    k: int
    flags: int
    n_vertices: int
    n_edges: int

    @property
    def offsets_start(self) -> int:
        return HEADER_SIZE

    @property
    def data_start(self) -> int:
        return HEADER_SIZE + 8 * (self.n_vertices + 1)


def read_wg_header(f) -> WebGraphHeader:
    f.seek(0)
    raw = f.read(HEADER_SIZE)
    magic, version, k, flags, n_v, n_e = _HEADER_STRUCT.unpack(raw)
    if magic != MAGIC:
        raise ValueError(f"bad magic {magic!r}; not a WebGraph-style file")
    if version != VERSION:
        raise ValueError(f"unsupported version {version}")
    return WebGraphHeader(k=k, flags=flags, n_vertices=n_v, n_edges=n_e)


class _Wavefront:
    """Vectorized multi-cursor decoder: one code per round across vertices."""

    def __init__(self, bits: np.ndarray, k: int):
        self.bits = bits
        self.k = k
        self.ones = np.flatnonzero(bits).astype(np.int64)

    def _unary(self, pos: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Zero-run lengths at ``pos``; returns (run, next_pos_after_the_1)."""
        i = np.searchsorted(self.ones, pos)
        nxt = self.ones[i]
        return nxt - pos, nxt + 1

    def _read_fixed(self, pos: np.ndarray, width: int) -> np.ndarray:
        """Read ``width`` MSB-first bits at each ``pos`` (uniform width)."""
        if width == 0:
            return np.zeros(len(pos), dtype=np.uint64)
        idx = pos[:, None] + np.arange(width, dtype=np.int64)[None, :]
        gathered = self.bits[idx].astype(np.uint64)
        weights = np.uint64(1) << np.arange(width - 1, -1, -1, dtype=np.uint64)
        return gathered @ weights

    def gamma_many(self, pos: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        L, after = self._unary(pos)
        out = np.empty(len(pos), dtype=np.uint64)
        new_pos = after + L
        for Lv in np.unique(L):
            sel = L == Lv
            rest = self._read_fixed(after[sel], int(Lv))
            out[sel] = (np.uint64(1) << np.uint64(Lv)) | rest
        return out, new_pos

    def zeta_many(self, pos: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        k = self.k
        h, after = self._unary(pos)
        out = np.empty(len(pos), dtype=np.uint64)
        new_pos = np.empty(len(pos), dtype=np.int64)
        for hv in np.unique(h):
            sel = h == hv
            lo = np.uint64(1) << np.uint64(hv * k)
            z = int((np.uint64(1) << np.uint64((hv + 1) * k)) - lo)
            s = max(1, (z - 1).bit_length()) if z > 1 else 0
            if z == 1:
                out[sel] = lo
                new_pos[sel] = after[sel]
                continue
            t = (1 << s) - z
            p = after[sel]
            m = self._read_fixed(p, s - 1)
            long = m >= t
            extra = np.zeros(m.shape, dtype=np.uint64)
            if np.any(long):
                extra[long] = self.bits[p[long] + (s - 1)].astype(np.uint64)
            val = np.where(long, (m << np.uint64(1) | extra) - np.uint64(t), m)
            out[sel] = lo + val
            new_pos[sel] = p + (s - 1) + long.astype(np.int64)
        return out, new_pos


class WebGraphFile:
    """Reader over any seek/read file-like object (incl. PG-Fuse CachedFile)."""

    def __init__(self, file: Union[str, os.PathLike, BinaryIO]):
        if isinstance(file, (str, os.PathLike)):
            self._f: BinaryIO = open(file, "rb")
            self._own = True
        else:
            self._f = file
            self._own = False
        self.header = read_wg_header(self._f)
        self._bit_offsets: Optional[np.ndarray] = None

    @property
    def n_vertices(self) -> int:
        return self.header.n_vertices

    @property
    def n_edges(self) -> int:
        return self.header.n_edges

    def bit_offsets(self) -> np.ndarray:
        if self._bit_offsets is None:
            self._f.seek(self.header.offsets_start)
            raw = self._f.read(8 * (self.n_vertices + 1))
            self._bit_offsets = np.frombuffer(raw, dtype="<u8").astype(np.int64)
        return self._bit_offsets

    def _load_bits(self, bit0: int, bit1: int) -> tuple[np.ndarray, int]:
        """Unpacked bits covering [bit0, bit1); returns (bits, base_bit)."""
        byte0, byte1 = bit0 // 8, (bit1 + 7) // 8
        self._f.seek(self.header.data_start + byte0)
        raw = self._f.read(byte1 - byte0)
        bits = np.unpackbits(np.frombuffer(raw, dtype=np.uint8))
        return bits, byte0 * 8

    def decode_vertices(self, v0: int, v1: int) -> tuple[np.ndarray, np.ndarray]:
        """Wavefront-decode vertices [v0, v1) -> (local offsets, neighbors)."""
        offs = self.bit_offsets()
        bits, base = self._load_bits(int(offs[v0]), int(offs[v1]))
        wf = _Wavefront(bits, self.header.k)
        n = v1 - v0
        pos = offs[v0:v1] - base
        vid = np.arange(v0, v1, dtype=np.int64)

        dplus1, pos = wf.gamma_many(pos)
        degrees = (dplus1 - 1).astype(np.int64)
        out_offsets = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(degrees, out=out_offsets[1:])
        neighbors = np.empty(int(out_offsets[-1]), dtype=np.int64)

        # Round r decodes the r-th neighbor for all rows with degree > r.
        active = np.flatnonzero(degrees > 0)
        prev = np.zeros(n, dtype=np.int64)
        r = 0
        while len(active):
            code, new_pos = wf.zeta_many(pos[active])
            nat = code.astype(np.int64) - 1
            if r == 0:
                val = vid[active] + nat2int(nat)
            else:
                val = prev[active] + nat + 1
            neighbors[out_offsets[active] + r] = val
            prev[active] = val
            pos[active] = new_pos
            r += 1
            active = active[degrees[active] > r]
        return out_offsets, neighbors

    def neighbors_of(self, v: int) -> np.ndarray:
        """Scalar random access via the sequential reference decoder."""
        offs = self.bit_offsets()
        bits, base = self._load_bits(int(offs[v]), int(offs[v + 1]))
        rd = BitReader(bits, int(offs[v]) - base)
        d = rd.read_gamma() - 1
        out = np.empty(d, dtype=np.int64)
        prev = 0
        for i in range(d):
            nat = rd.read_zeta(self.header.k) - 1
            prev = v + int(nat2int(np.array([nat]))[0]) if i == 0 else prev + nat + 1
            out[i] = prev
        return out

    def read_partition(self, v0: int, v1: int) -> tuple[np.ndarray, np.ndarray]:
        return self.decode_vertices(v0, v1)

    def read_full(self) -> CSR:
        offs, nbrs = self.decode_vertices(0, self.n_vertices)
        dtype = np.int32 if self.n_vertices <= np.iinfo(np.int32).max else np.int64
        return CSR(offsets=offs, neighbors=nbrs.astype(dtype))

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self) -> "WebGraphFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_webgraph(path: Union[str, os.PathLike, BinaryIO]) -> CSR:
    with WebGraphFile(path) as f:
        return f.read_full()


def roundtrip_bytes(csr: CSR, k: int = DEFAULT_K) -> bytes:
    buf = io.BytesIO()
    write_webgraph(buf, csr, k)
    return buf.getvalue()
