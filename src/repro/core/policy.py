"""Hybrid format-selection policy (paper §V-D / future work §VI).

Figure 4 of the paper shows a crossover: when the storage-size difference
``compbin_size - webgraph_size`` is small (< ~50 GiB on the paper's
system), CompBin/binary CSR loads faster; when it approaches/exceeds
~100 GiB, WebGraph + PG-Fuse wins because the read becomes storage-
bandwidth limited.  The thresholds depend on storage bandwidth and
decompression throughput, so we model loading time explicitly and let the
constants be calibrated on the running system:

    t_compbin  = compbin_size / storage_bw + |E| / compbin_decode_rate
    t_webgraph = webgraph_size / storage_bw + |E| / webgraph_decode_rate

and choose the smaller.  ``calibrate()`` measures the two decode rates and
the storage bandwidth with short probes on generated data.
"""

from __future__ import annotations

import dataclasses
import io
import time
from typing import Optional

import numpy as np

from repro.core import compbin, webgraph
from repro.core.csr import CSR


@dataclasses.dataclass
class SystemModel:
    storage_bw: float = 2e9            # bytes/s sequential read
    compbin_decode_rate: float = 2e8   # edges/s (shift+add, eq. 1)
    webgraph_decode_rate: float = 2e6  # edges/s (bit-level gamma/zeta)

    def load_time_compbin(self, n_vertices: int, n_edges: int) -> float:
        size = compbin.compbin_nbytes(n_vertices, n_edges)
        return size / self.storage_bw + n_edges / self.compbin_decode_rate

    def load_time_webgraph(self, webgraph_size: int, n_edges: int) -> float:
        return webgraph_size / self.storage_bw + n_edges / self.webgraph_decode_rate


def choose_format(n_vertices: int, n_edges: int, webgraph_size: int,
                  model: SystemModel | None = None) -> str:
    """Return 'compbin' or 'webgraph' — whichever the model predicts faster.

    ``webgraph_size`` must be the actual compressed size on storage (it is
    graph-dependent: web graphs compress far better than social/bio graphs).
    """
    model = model or SystemModel()
    t_cb = model.load_time_compbin(n_vertices, n_edges)
    t_wg = model.load_time_webgraph(webgraph_size, n_edges)
    return "compbin" if t_cb <= t_wg else "webgraph"


def crossover_size_difference(model: SystemModel, n_edges: int,
                              n_vertices: int) -> float:
    """Size difference (bytes) at which the two formats tie (paper Fig. 4).

    Setting t_cb == t_wg:  (cb_size - wg_size) / storage_bw ==
    |E|/wg_rate - |E|/cb_rate, i.e. the extra read time of the fat format
    must equal the extra decode time of the compressed one.
    """
    extra_decode = n_edges / model.webgraph_decode_rate - n_edges / model.compbin_decode_rate
    return extra_decode * model.storage_bw


@dataclasses.dataclass
class StreamDecodePlan:
    """Where the streaming loader (data/graph_stream.py) runs eq. (1)."""

    mode: str      # "device" (Pallas kernel) | "host" (numpy decode)
    reason: str

    @property
    def device(self) -> bool:
        return self.mode == "device"


def choose_stream_decode(format: str, b: int = 0,
                         model: SystemModel | None = None) -> StreamDecodePlan:
    """Per-graph decode placement for the streaming loader.

    Direct-addressing codecs (CompBin, LogCSR — both pack neighbors as
    eq. (1) byte streams) with b <= 4 ship the *packed* bytes and decode
    on device — the (4-b)/4 byte saving then applies to host->HBM
    traffic too, and the VPU shift+adds are free next to the gather they
    feed.  b > 4 means |V| >= 2^32: IDs overflow the kernel's int32
    lanes, so the host decodes to int64.  WebGraph's gamma/zeta bit
    codes are inherently sequential (paper §II-A) and always decode on
    host; whether WebGraph is worth reading at all is
    :func:`choose_format`'s job, which trades its smaller storage
    footprint against its ~100x slower decode.
    """
    if format in ("compbin", "logcsr"):
        fmt = "CompBin" if format == "compbin" else "LogCSR"
        if 1 <= b <= 4:
            return StreamDecodePlan(
                "device", f"{fmt} b={b}: packed stream fits int32 lanes; "
                          f"H2D moves {b}/4 of the decoded bytes")
        return StreamDecodePlan(
            "host", f"{fmt} b={b}: IDs exceed int32; host decodes to int64")
    if format == "webgraph":
        return StreamDecodePlan(
            "host", "WebGraph gamma/zeta codes are bit-sequential; no device path")
    raise ValueError(f"unknown graph format {format!r}")


@dataclasses.dataclass
class AccessModePlan:
    """PG-Fuse configuration matched to an access pattern.

    Feed the fields into :func:`repro.core.paragrapher.open_graph`
    (``pgfuse_readahead=plan.readahead, pgfuse_eviction=plan.eviction``)
    and, when ``churn_budget_fraction`` is set, cap the churning byte
    stream's file with ``fs.set_file_budget(path, int(frac * budget))``.
    """

    mode: str                 # "sequential" | "random"
    readahead: int            # PG-Fuse blocks prefetched per miss
    eviction: str             # pgfuse.EVICT_LRU | pgfuse.EVICT_CLOCK
    churn_budget_fraction: Optional[float]   # per-file cap for the bulk
                              # byte stream (None: no cap needed)
    reason: str

    @property
    def random(self) -> bool:
        return self.mode == "random"


def choose_access_mode(workload: str, *,
                       touch_fraction: Optional[float] = None
                       ) -> AccessModePlan:
    """Sequential-vs-random PG-Fuse policy from workload hints.

    The streaming loaders scan every byte once in order: always-on
    readahead turns ~every miss into one enlarged multi-block request,
    and exact LRU is the right replacement (a block is dead the moment
    the scan passes it).  Random adjacency queries (sampled minibatch
    training, online inference serving) invert both assumptions —
    "Making Caches Work for Graph Analytics" (arXiv:1608.01362) shows
    random graph access needs a policy that protects the re-referenced
    hot set rather than raw recency:

    * readahead OFF — the block after a queried adjacency list carries
      no locality, so prefetching it just churns the cache;
    * clock/second-chance eviction — hot blocks (offset array, hub
      vertices) are re-touched every batch and survive sweeps, while a
      strict recency order would evict them behind any large batch of
      cold packed-byte reads;
    * a per-file cap on the bulk/churning stream (packed neighbors rows
      vs. the offsets region's working set, feature store vs. topology)
      so churn reclaims from itself first.

    ``workload`` is "stream"/"scan" (sequential) or "sample"/"serve"
    (random).  ``touch_fraction`` (expected fraction of the file touched
    per epoch) overrides the keyword when given: a "sampler" that visits
    ~every vertex each epoch is effectively sequential.
    """
    sequential = {"stream", "scan", "sequential", "full"}
    random_ = {"sample", "serve", "query", "random"}
    if workload not in sequential | random_:
        raise ValueError(f"unknown workload {workload!r} "
                         f"(expected one of {sorted(sequential | random_)})")
    is_random = workload in random_
    if touch_fraction is not None:
        if not 0 <= touch_fraction <= 1:
            raise ValueError(f"touch_fraction must be in [0,1], "
                             f"got {touch_fraction}")
        # visiting most of the file per epoch amortizes like a scan even
        # if individual requests look random
        is_random = touch_fraction < 0.5
    if is_random:
        return AccessModePlan(
            mode="random", readahead=0, eviction="clock",
            churn_budget_fraction=0.5,
            reason=f"workload {workload!r}: no next-block locality; "
                   f"second-chance keeps the re-touched hot set; cap the "
                   f"packed/feature churn at half the budget")
    return AccessModePlan(
        mode="sequential", readahead=2, eviction="lru",
        churn_budget_fraction=None,
        reason=f"workload {workload!r}: one-pass scan wants enlarged "
               f"prefetch and exact recency eviction")


@dataclasses.dataclass
class QueryDecodePlan:
    """Where the query engine runs eq. (1) for ONE micro-batch."""

    mode: str      # "device" (one H2D + Pallas kernel) | "host" (numpy)
    reason: str

    @property
    def device(self) -> bool:
        return self.mode == "device"


#: below this many edges per micro-batch the device dispatch + transfer
#: overhead exceeds the host shift+adds it replaces (per-batch fixed cost
#: ~tens of microseconds vs ~5 ns/edge host decode)
QUERY_DEVICE_MIN_EDGES = 4096


def choose_query_decode(n_edges: int, b: int, *,
                        n_vertices: Optional[int] = None,
                        min_edges: int = QUERY_DEVICE_MIN_EDGES
                        ) -> QueryDecodePlan:
    """Per-micro-batch decode placement for the random-access query path.

    The serving engine knows each batch's exact edge mass AFTER the
    offsets gather and BEFORE any packed byte is decoded, so placement
    is a per-batch decision, not a per-engine one: large-fanout batches
    (hub-heavy frontiers, whole sampler layers) ship their merged packed
    runs to the device in one transfer and decode next to the gathers
    they feed — the H2D moves ``b/4`` of the decoded bytes, same as the
    streaming loader — while small batches stay on host, where eq. (1)
    costs less than a device dispatch.  Mirrors
    :func:`choose_stream_decode`'s lane constraint: IDs must fit int32
    lanes, so ``b > 4`` or ``|V| > 2^31`` always decodes on host.
    """
    if n_edges < 0:
        raise ValueError(f"n_edges must be >= 0, got {n_edges}")
    if not 1 <= b <= 8:
        raise ValueError(f"b must be in [1,8], got {b}")
    if b > 4:
        return QueryDecodePlan(
            "host", f"CompBin b={b}: IDs exceed int32 lanes; host decodes")
    if n_vertices is not None and n_vertices > (1 << 31):
        return QueryDecodePlan(
            "host", f"|V|={n_vertices} overflows int32 lanes; host decodes")
    if n_edges < min_edges:
        return QueryDecodePlan(
            "host", f"batch of {n_edges} edges < {min_edges}: device "
                    f"dispatch+transfer overhead exceeds the shift+adds")
    return QueryDecodePlan(
        "device", f"batch of {n_edges} edges: one H2D of {b}*{n_edges} "
                  f"packed bytes, VPU decode next to the gathers it feeds")


@dataclasses.dataclass
class AdmissionPlan:
    """Load-shedding gate sizing for the traversal/serving layer.

    The gate admits at most ``max_inflight`` requests (being served OR
    queued) and at most ``max_edges_inflight`` of summed per-request
    edge budgets at any instant; everything beyond is SHED immediately
    (fast-fail, so overload surfaces as an explicit signal the client
    can back off on, never as unbounded queueing delay).  ``servers``
    is the number of requests the service executes concurrently —
    the quantity the queue-depth arithmetic below divides by.
    """

    max_inflight: int         # admitted (served + queued) request cap
    max_edges_inflight: int   # summed admitted edge budgets cap
    servers: int              # concurrent executors behind the gate
    slo_s: float              # the latency objective the sizing protects
    reason: str


def choose_admission(slo_s: float, *, edge_budget: int,
                     service_edges_per_s: float, servers: int = 1,
                     overshoot_factor: float = 2.0) -> AdmissionPlan:
    """Size the admission gate so every ADMITTED request meets the SLO.

    Classic bounded-queue arithmetic: one request costs at most
    ``t_req = overshoot_factor * edge_budget / service_edges_per_s``
    (the traversal loop stops at the first frontier that crosses the
    edge budget, so a request can overshoot its budget by up to one
    frontier — ``overshoot_factor`` covers that).  A request admitted
    behind ``q`` others waits at most ``ceil(q / servers) * t_req``
    before its own ``t_req`` of service, so admitting at most

        max_inflight = floor(slo_s * servers / t_req)

    keeps worst-case admitted latency inside ``slo_s``.  Shedding is
    then the ONLY overload response: p99 of admitted requests is a
    sizing invariant, and the shed rate — not the tail — absorbs the
    excess (the deterministic load test pins exactly this).
    """
    if slo_s <= 0 or edge_budget < 1 or service_edges_per_s <= 0:
        raise ValueError("slo_s, edge_budget and service_edges_per_s must "
                         "be positive")
    if servers < 1 or overshoot_factor < 1:
        raise ValueError("servers must be >= 1 and overshoot_factor >= 1")
    t_req = overshoot_factor * edge_budget / service_edges_per_s
    max_inflight = max(1, int(slo_s * servers / t_req))
    return AdmissionPlan(
        max_inflight=max_inflight,
        max_edges_inflight=max_inflight * edge_budget,
        servers=servers, slo_s=slo_s,
        reason=f"worst-case request {t_req * 1e3:.2f} ms "
               f"({overshoot_factor}x overshoot on {edge_budget} edges); "
               f"{max_inflight} in flight across {servers} server(s) keeps "
               f"admitted latency <= {slo_s * 1e3:.1f} ms; excess sheds")


@dataclasses.dataclass
class ShardPlan:
    """Scale-out layout for the sharded serving path
    (:class:`repro.query.sharded.ShardedQueryService`).

    ``n_shards`` contiguous vertex-range shards, each replicated
    ``replication`` times (every replica owns its own PG-Fuse mount and
    engine, simulated-process style).  ``routing`` is how a request's
    per-shard slice picks among that shard's replicas: ``"direct"``
    (single replica) or ``"rr"`` (deterministic round-robin — the
    load-balancing mode hub-heavy zipf traffic needs).
    """

    n_shards: int
    replication: int
    routing: str      # "direct" | "rr"
    reason: str


def choose_shard_plan(file_bytes: int, *, cache_budget_bytes: int,
                      hot_fraction: float = 0.0,
                      offered_edges_per_s: Optional[float] = None,
                      shard_edges_per_s: Optional[float] = None,
                      max_shards: int = 16) -> ShardPlan:
    """Shard count / replication / routing from cache budgets and trace
    skew.

    Two quantities size the shard count, and the larger wins:

    * **working set vs cache budget** — each shard serves one
      contiguous vertex range, so its PG-Fuse working set is roughly
      ``file_bytes / n_shards``; at least
      ``ceil(file_bytes / cache_budget_bytes)`` shards keep every
      shard's hot set resident in its own budget (the per-shard
      locality lever: smaller working set per worker, the same effect
      "Making Caches Work for Graph Analytics" gets from cache-
      segmented hot sets);
    * **offered load vs per-shard service rate** — when both are
      known, at least ``ceil(offered_edges_per_s / shard_edges_per_s)``
      shards carry the traffic.

    ``hot_fraction`` is the measured fraction of routed traffic landing
    on the HOTTEST shard's range (read it off a trace via the sharded
    service's router counters).  Range sharding cannot balance a trace
    whose hubs concentrate in one range: once one shard absorbs >= half
    the traffic, the plan replicates every shard 2x and routes
    round-robin so the hub shard's replicas split its load.
    """
    if file_bytes < 0:
        raise ValueError(f"file_bytes must be >= 0, got {file_bytes}")
    if cache_budget_bytes < 1:
        raise ValueError(f"cache_budget_bytes must be >= 1, "
                         f"got {cache_budget_bytes}")
    if not 0 <= hot_fraction <= 1:
        raise ValueError(f"hot_fraction must be in [0, 1], "
                         f"got {hot_fraction}")
    if max_shards < 1:
        raise ValueError(f"max_shards must be >= 1, got {max_shards}")
    if (offered_edges_per_s is None) != (shard_edges_per_s is None):
        raise ValueError("offered_edges_per_s and shard_edges_per_s "
                         "must be given together")
    n_cache = max(1, -(-file_bytes // cache_budget_bytes))
    n_load = 1
    if offered_edges_per_s is not None:
        if offered_edges_per_s < 0 or shard_edges_per_s <= 0:
            raise ValueError("offered_edges_per_s must be >= 0 and "
                             "shard_edges_per_s > 0")
        n_load = max(1, -(-int(offered_edges_per_s)
                          // max(1, int(shard_edges_per_s))))
    n_shards = min(max(n_cache, n_load), max_shards)
    replication = 2 if hot_fraction >= 0.5 else 1
    routing = "rr" if replication > 1 else "direct"
    return ShardPlan(
        n_shards=n_shards, replication=replication, routing=routing,
        reason=f"{n_cache} shard(s) fit {file_bytes} B working set into "
               f"{cache_budget_bytes} B/shard cache budgets, {n_load} "
               f"carry the offered load (capped at {max_shards}); "
               f"hottest range takes {hot_fraction:.0%} of traffic -> "
               f"{replication}x replicas, {routing} routing")


@dataclasses.dataclass
class HotSetPlan:
    """Admission/placement config for the HBM-resident hot-set tier
    (:class:`repro.query.hotset.HotSetCache`) — cache tier 3, above
    PG-Fuse's host-RAM packed blocks.

    An entry costs ``8 * degree`` budget bytes (a decoded int64 run),
    so every threshold below is a *degree*: the tier exists for the
    hub vertices zipf traffic concentrates on, and the arithmetic keeps
    the cold tail out of their way.
    """

    budget_bytes: int      # resident cap, EngineShare-style byte budget
    min_degree: int        # below: BYPASS the tier (cold tail)
    pin_degree: int        # at/above: PIN (the clock sweep never takes it)
    pin_fraction: float    # budget fraction pinned entries may occupy
    place: str             # "device" (HBM int32 runs) | "host" (numpy)
    prefetch_min_hits: int  # trace hits before a vertex is predicted hot
    prefetch_batch: int    # predicted vertices fetched per request batch
    reason: str

    @property
    def device(self) -> bool:
        return self.place == "device"


def choose_hotset_admission(n_vertices: int, n_edges: int,
                            budget_bytes: int, *,
                            pin_fraction: float = 0.5,
                            prefetch_min_hits: int = 3,
                            prefetch_batch: int = 8) -> HotSetPlan:
    """Degree-aware admission for the device-resident hot-set tier.

    Power-law graphs put almost all query traffic on vertices whose
    degree is a large multiple of the mean ("Making Caches Work for
    Graph Analytics": frequency-clustered hot sets), while the tail —
    most vertices — is touched rarely and decodes cheaply anyway.  The
    thresholds follow directly:

    * ``min_degree = max(2, 2 * mean_degree)`` — an entry below twice
      the mean is tail, not hub: admitting it spends budget (and an
      eviction later) to save a decode that was already near-free, and
      Slim Graph's lossy-tier argument applies one tier down — let the
      tail fall through to PG-Fuse;
    * ``pin_degree = max(min_degree, 16 * mean_degree)`` — an order of
      magnitude above the mean the re-reference probability under zipf
      traffic is ~1 per batch, so second-chance bookkeeping is wasted
      motion: pin it (up to ``pin_fraction`` of the budget) and let the
      clock sweep manage only the warm middle;
    * ``place`` mirrors :func:`choose_query_decode`'s lane constraint:
      ids fit the device's int32 lanes only while ``|V| <= 2^31``, so
      larger graphs keep the tier host-resident (still skipping decode
      — just not the H2D).
    """
    if n_vertices < 0 or n_edges < 0:
        raise ValueError("n_vertices and n_edges must be >= 0")
    if budget_bytes < 1:
        raise ValueError(f"budget_bytes must be >= 1, got {budget_bytes}")
    if not 0.0 <= pin_fraction <= 1.0:
        raise ValueError(f"pin_fraction must be in [0, 1], "
                         f"got {pin_fraction}")
    mean = n_edges / n_vertices if n_vertices else 0.0
    min_degree = max(2, int(2 * mean))
    pin_degree = max(min_degree, int(16 * mean))
    place = "device" if n_vertices <= (1 << 31) else "host"
    return HotSetPlan(
        budget_bytes=int(budget_bytes),
        min_degree=min_degree, pin_degree=pin_degree,
        pin_fraction=float(pin_fraction), place=place,
        prefetch_min_hits=int(prefetch_min_hits),
        prefetch_batch=int(prefetch_batch),
        reason=f"mean degree {mean:.1f}: bypass < {min_degree}, pin >= "
               f"{pin_degree} (<= {pin_fraction:.0%} of {budget_bytes} B); "
               f"{place}-resident runs "
               f"({'ids fit int32 lanes' if place == 'device' else 'ids overflow int32 lanes'})")


@dataclasses.dataclass
class ReorderPlan:
    """Vertex-ordering strategy for the offline graph compiler
    (:func:`repro.graph.reorder.compile_graph`).

    ``strategy`` is one of ``"bfs"`` (level order from a max-degree
    root — the locality permutation that clusters each neighborhood's
    ids), ``"degree"`` (hubs first — the cheap frequency clustering),
    or ``"identity"`` (keep the input order).
    """

    strategy: str   # "bfs" | "degree" | "identity"
    reason: str


REORDER_STRATEGIES = ("bfs", "degree", "identity")


def choose_reorder(n_vertices: int, n_edges: int, *,
                   strategy: Optional[str] = None) -> ReorderPlan:
    """Pick the locality permutation the graph compiler applies.

    BFS order from a max-degree root is the default: it places each
    neighborhood's vertices near each other, so a query's packed-byte
    reads land in fewer PG-Fuse blocks and the ids inside a row become
    numerically close (the property Log(Graph)/Zuckerli-style encodings
    exploit; see PAPERS.md).  Degree order is the fallback when the
    graph is too sparse for BFS levels to mean anything — with mean
    degree < 1 most components are singletons and BFS degenerates to
    the component scan, so the cheap hubs-first sort (frequency
    clustering: the hot set lands in the first blocks) wins on compile
    time.  Edgeless graphs keep their order — any permutation is noise.
    An explicit ``strategy`` overrides the heuristic (the CLI flag).
    """
    if n_vertices < 0 or n_edges < 0:
        raise ValueError("n_vertices and n_edges must be >= 0")
    if strategy is not None:
        if strategy not in REORDER_STRATEGIES:
            raise ValueError(f"unknown reorder strategy {strategy!r} "
                             f"(expected one of {REORDER_STRATEGIES})")
        return ReorderPlan(strategy=strategy,
                           reason=f"explicit strategy {strategy!r}")
    if n_edges == 0:
        return ReorderPlan(
            strategy="identity",
            reason="edgeless graph: no locality to recover")
    mean = n_edges / max(1, n_vertices)
    if mean < 1.0:
        return ReorderPlan(
            strategy="degree",
            reason=f"mean degree {mean:.2f} < 1: BFS levels degenerate; "
                   f"hubs-first sort clusters the hot set cheaply")
    return ReorderPlan(
        strategy="bfs",
        reason=f"mean degree {mean:.2f}: level order from a max-degree "
               f"root clusters neighborhoods into few blocks")


def choose_stream_parts(n_devices_total: int = 1, process_count: int = 1,
                        min_parts_per_process: int = 8) -> int:
    """Global partition count for a (possibly multi-host) streamed load.

    Each process should see enough partitions to keep its pipeline's
    double-buffering busy (at least ``min_parts_per_process``) and enough
    to cover its slice of the mesh's devices 4x over (so the edge-balanced
    plan can absorb skew).  The returned count is the GLOBAL plan size:
    every process computes the same plan from the same file and takes its
    ``split_plan`` slice, so the cut points agree without communication.
    """
    if process_count < 1:
        raise ValueError(f"process_count must be >= 1, got {process_count}")
    devices_per_process = max(1, n_devices_total // process_count)
    per = max(min_parts_per_process, 4 * devices_per_process)
    return per * process_count


def choose_feature_align(block_size: int, row_bytes: int,
                         n_vertices: Optional[int] = None,
                         process_count: int = 1,
                         min_cuts_per_host: int = 2) -> int:
    """Vertex alignment for block-disjoint per-host feature reads.

    Cut vertices that are multiples of ``block_size // row_bytes`` land
    on feature-store block boundaries (given a block-aligned data
    section), so neighboring hosts never double-fetch a boundary block.
    But alignment is an *optimization*: when the grid is coarser than
    ``min_cuts_per_host`` grid points per host, snapping would starve
    whole hosts (a 1024-vertex graph with 1024-vertex blocks has exactly
    one interior grid point), so the policy degrades to 1 — unaligned
    cuts and one shared boundary block per host pair, the pre-alignment
    behavior.
    """
    if block_size < 1 or process_count < 1:
        raise ValueError("block_size and process_count must be >= 1")
    if row_bytes <= 0:
        return 1
    align = max(1, block_size // row_bytes)
    if (n_vertices is not None
            and align * process_count * min_cuts_per_host > n_vertices):
        return 1
    return align


def calibrate(n_vertices: int = 1 << 16, n_edges: int = 1 << 18,
              seed: int = 0) -> SystemModel:
    """Measure decode rates (and a proxy storage bandwidth) on this host."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    from repro.core.csr import csr_from_edges
    csr = csr_from_edges(src, dst, n_vertices, dedupe=True)
    n_edges = csr.n_edges

    cb_blob = io.BytesIO()
    compbin.write_compbin(cb_blob, csr)
    t0 = time.perf_counter()
    compbin.read_compbin(io.BytesIO(cb_blob.getvalue()))
    cb_rate = n_edges / max(1e-9, time.perf_counter() - t0)

    wg_blob = io.BytesIO()
    webgraph.write_webgraph(wg_blob, csr)
    t0 = time.perf_counter()
    webgraph.read_webgraph(io.BytesIO(wg_blob.getvalue()))
    wg_rate = n_edges / max(1e-9, time.perf_counter() - t0)

    # memory-to-memory copy as an upper-bound "storage" bandwidth proxy on
    # this container; real deployments should pass a measured device figure.
    blob = cb_blob.getvalue()
    t0 = time.perf_counter()
    _ = bytes(blob)
    bw = len(blob) / max(1e-9, time.perf_counter() - t0)

    return SystemModel(storage_bw=bw, compbin_decode_rate=cb_rate,
                       webgraph_decode_rate=wg_rate)
