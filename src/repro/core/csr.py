"""CSR/CSC graph container (paper §II).

A graph G=(V,E) in Compressed Sparse Row/Column form: an ``offsets`` array of
|V|+1 elements and a ``neighbors`` array of |E| elements.  ``offsets[v]`` is
the index of the first neighbor of ``v`` in ``neighbors``.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    """In-memory CSR graph. ``offsets`` is int64[|V|+1], ``neighbors`` holds
    vertex IDs (int32 when |V| < 2^31, else int64)."""

    offsets: np.ndarray
    neighbors: np.ndarray

    def __post_init__(self) -> None:
        self.offsets = np.ascontiguousarray(self.offsets, dtype=np.int64)
        if self.offsets.ndim != 1 or self.offsets.shape[0] < 1:
            raise ValueError("offsets must be a 1-D array of |V|+1 elements")
        if int(self.offsets[0]) != 0:
            raise ValueError("offsets[0] must be 0")
        if self.neighbors.ndim != 1:
            raise ValueError("neighbors must be 1-D")
        if int(self.offsets[-1]) != self.neighbors.shape[0]:
            raise ValueError(
                f"offsets[-1]={int(self.offsets[-1])} != |E|={self.neighbors.shape[0]}"
            )

    @property
    def n_vertices(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return self.neighbors.shape[0]

    def degree(self, v: int) -> int:
        return int(self.offsets[v + 1] - self.offsets[v])

    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets)

    def neighbors_of(self, v: int) -> np.ndarray:
        return self.neighbors[int(self.offsets[v]) : int(self.offsets[v + 1])]

    def edge_index(self) -> np.ndarray:
        """Return (2, |E|) [src; dst] COO edge index (row-major expansion)."""
        src = np.repeat(np.arange(self.n_vertices, dtype=self.neighbors.dtype), self.degrees())
        return np.stack([src, self.neighbors.astype(src.dtype)])

    def __eq__(self, other: object) -> bool:  # pragma: no cover - convenience
        if not isinstance(other, CSR):
            return NotImplemented
        return bool(
            np.array_equal(self.offsets, other.offsets)
            and np.array_equal(self.neighbors, other.neighbors)
        )


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_vertices: int, *,
                   sort_neighbors: bool = True, dedupe: bool = False) -> CSR:
    """Build CSR from a COO edge list.

    ``dedupe=True`` drops duplicate (src, dst) pairs — required before
    WebGraph-style encoding, which assumes strictly increasing successor
    lists (real web graphs carry no duplicate links)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError("src/dst shape mismatch")
    if sort_neighbors:
        order = np.lexsort((dst, src))  # group rows, neighbors ascending in-row
    else:
        order = np.argsort(src, kind="stable")
    src, dst_s = src[order], dst[order]
    if dedupe:
        keep = np.ones(len(src), dtype=bool)
        keep[1:] = (src[1:] != src[:-1]) | (dst_s[1:] != dst_s[:-1])
        src, dst_s = src[keep], dst_s[keep]
    counts = np.bincount(src, minlength=n_vertices)
    offsets = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    dtype = np.int32 if n_vertices <= np.iinfo(np.int32).max else np.int64
    return CSR(offsets=offsets, neighbors=dst_s.astype(dtype))
