"""ParaGrapher — the graph-loading API (paper §II-A).

ParaGrapher lets graph frameworks load large compressed graphs with minimal
overhead, offering

  * **full** or **partition** loads,
  * **synchronous** (blocking) or **asynchronous** (non-blocking, callback)
    reads, and
  * a **producer/consumer** architecture with reusable bounded buffers: the
    producers decode partitions into a fixed pool of buffers; the consumer's
    callback hands each buffer to the user, who copies into the framework's
    preferred memory, after which the buffer returns to the pool.

In the original system the consumer side is C and the producer side is the
Java WebGraph process communicating over shared memory; here both sides are
Python threads sharing numpy buffers, which preserves the architecture
(bounded reusable buffers, backpressure when the consumer is slow) without
the JVM.  Formats: CompBin (paper §IV) and the WebGraph-style codec
(paper §II-A); PG-Fuse (paper §III) is interposed when requested.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
from typing import Callable, Iterable, Optional, Sequence, Union

import numpy as np

from repro.core import codec, pgfuse, webgraph
from repro.core.csr import CSR

FORMAT_COMPBIN = "compbin"
FORMAT_WEBGRAPH = "webgraph"
FORMAT_LOGCSR = "logcsr"


def detect_format(path: Union[str, os.PathLike]) -> str:
    """Codec name for ``path``, dispatched on the 4-byte magic through
    the :mod:`repro.core.codec` registry."""
    with open(path, "rb") as f:
        magic = f.read(4)
    spec = codec.codec_for_magic(magic)
    if spec is None:
        raise ValueError(f"{path}: unknown graph format (magic {magic!r})")
    return spec.name


@dataclasses.dataclass
class PartitionBuffer:
    """One reusable producer->consumer buffer (paper's shared buffers)."""

    v0: int = 0
    v1: int = 0
    offsets: Optional[np.ndarray] = None    # local, rebased to 0
    neighbors: Optional[np.ndarray] = None  # decoded IDs (raw=False)
    packed: Optional[np.ndarray] = None     # undecoded CompBin bytes (raw=True)
    b: int = 0                              # bytes/ID of ``packed``
    error: Optional[BaseException] = None


class GraphHandle:
    """An open graph. Thread-safe: each reader op opens its own file handle."""

    def __init__(self, path: Union[str, os.PathLike], *,
                 format: str = "auto",
                 use_pgfuse: bool = False,
                 pgfuse_block_size: int = pgfuse.DEFAULT_BLOCK_SIZE,
                 pgfuse_max_resident_bytes: Optional[int] = None,
                 pgfuse_readahead: Optional[int] = None,
                 pgfuse_pread_fn=None,
                 pgfuse_eviction: str = pgfuse.EVICT_LRU,
                 pgfuse_retries: int = 0,
                 pgfuse_retry_backoff_s: float = 0.005,
                 pgfuse_fs: Optional[pgfuse.PGFuseFS] = None,
                 pgfuse_engine=None):
        self.path = os.fspath(path)
        self.format = detect_format(path) if format == "auto" else format
        self._fs: Optional[pgfuse.PGFuseFS] = None
        self._owns_fs = False
        if pgfuse_fs is not None:
            # multi-tenant: join an existing mount (several serving
            # models under one budget); this graph's file takes the
            # caller's readahead ONLY when explicitly given (None
            # inherits the mount default and never clobbers a live
            # file's setting), and closing the handle unmounts only
            # this file, never the other tenants'
            self._fs = pgfuse_fs
            self._fs.mount(self.path, readahead=pgfuse_readahead,
                           engine=pgfuse_engine)
            # refcounted: another handle over the SAME file (two tenants,
            # one topology) keeps the cache warm past our close()
            self._fs.retain(self.path)
        elif use_pgfuse:
            self._fs = pgfuse.PGFuseFS(
                block_size=pgfuse_block_size,
                max_resident_bytes=pgfuse_max_resident_bytes,
                readahead=pgfuse_readahead or 0,
                pread_fn=pgfuse_pread_fn,
                eviction=pgfuse_eviction,
                retries=pgfuse_retries,
                retry_backoff_s=pgfuse_retry_backoff_s,
            )
            self._owns_fs = True
            self._fs.mount(self.path, engine=pgfuse_engine)
        self._closed = False
        try:
            rdr = self._reader()  # validates header eagerly
            self.n_vertices = rdr.n_vertices
            self.n_edges = rdr.n_edges
            # fixed bytes/ID of direct codecs (§IV packing); 0 for
            # formats without fixed-width IDs (bit-coded WebGraph)
            self.bytes_per_id = getattr(rdr, "b", 0)
            rdr.close()
        except BaseException:
            # a failed open must not strand the mount: unwind the retain
            # (shared fs) / the whole private fs, or the refcount and any
            # share membership leak with no handle left to release them
            if self._fs is not None:
                if self._owns_fs:
                    self._fs.unmount()
                else:
                    self._fs.unmount(self.path)
            raise

    # -- internals ----------------------------------------------------------
    def _open_file(self):
        if self._fs is not None:
            return self._fs.open(self.path)
        return open(self.path, "rb")

    def _reader(self):
        f = self._open_file()
        try:
            return codec.get_codec(self.format).open(f)
        except BaseException:
            f.close()
            raise

    # -- synchronous (blocking) API ------------------------------------------
    def read_full(self) -> CSR:
        if self._closed:
            raise ValueError("read on closed graph")
        rdr = self._reader()
        try:
            return rdr.read_full()
        finally:
            rdr.close()

    def read_partition(self, v0: int, v1: int) -> tuple[np.ndarray, np.ndarray]:
        """Load vertices [v0, v1): (rebased offsets[v1-v0+1], neighbors)."""
        if not 0 <= v0 <= v1 <= self.n_vertices:
            raise ValueError(f"bad partition [{v0},{v1}) for |V|={self.n_vertices}")
        rdr = self._reader()
        try:
            return rdr.read_partition(v0, v1)
        finally:
            rdr.close()

    def read_partition_raw(self, v0: int, v1: int
                           ) -> tuple[np.ndarray, np.ndarray, int]:
        """Like :meth:`read_partition` but WITHOUT host decode: returns
        (rebased offsets, packed neighbor bytes, bytes-per-ID).

        Only direct-addressing codecs (CompBin, LogCSR) support this —
        their packed streams are decodable on device
        (kernels/compbin_decode), so the (4-b)/4 byte saving extends to
        the host->device transfer.  WebGraph's bit-level codes need the
        sequential host decoder; callers should route through
        :func:`repro.core.policy.choose_stream_decode`.
        """
        if not 0 <= v0 <= v1 <= self.n_vertices:
            raise ValueError(f"bad partition [{v0},{v1}) for |V|={self.n_vertices}")
        rdr = self._reader()
        try:
            if not hasattr(rdr, "raw_neighbor_bytes"):
                raise ValueError(f"raw partition reads require a "
                                 f"direct-addressing codec, "
                                 f"not {self.format!r}")
            offs = rdr.offsets(v0, v1)
            raw = rdr.raw_neighbor_bytes(int(offs[0]), int(offs[-1]))
            return (offs - offs[0]).astype(np.int64), raw, rdr.b
        finally:
            rdr.close()

    def neighbors_of(self, v: int) -> np.ndarray:
        rdr = self._reader()
        try:
            return np.asarray(rdr.neighbors_of(v))
        finally:
            rdr.close()

    # -- asynchronous (non-blocking) API --------------------------------------
    def read_async(
        self,
        partitions: Sequence[tuple[int, int]],
        callback: Callable[[PartitionBuffer], None],
        *,
        n_buffers: int = 4,
        n_workers: int = 4,
        raw: bool = False,
    ) -> "AsyncRead":
        """Decode ``partitions`` concurrently; invoke ``callback(buffer)`` for
        each as it completes (possibly out of order).  The pool of
        ``n_buffers`` bounds memory and applies backpressure: producers block
        until the consumer returns a buffer (i.e. the callback finishes).

        ``raw=True`` (CompBin only) skips host decode: each buffer carries
        ``packed``/``b`` instead of ``neighbors`` — the streaming loader's
        storage stage (data/graph_stream.py)."""
        return AsyncRead(self, list(partitions), callback,
                         n_buffers=n_buffers, n_workers=n_workers, raw=raw)

    def partition_plan(self, n_parts: int) -> list[tuple[int, int]]:
        """Edge-balanced contiguous vertex ranges (for distributed loaders)."""
        rdr = self._reader()
        try:
            if hasattr(rdr, "offsets"):
                offs = rdr.offsets()
            else:
                offs = rdr.bit_offsets()  # bit offsets ~ edge mass proxy
        finally:
            rdr.close()
        total = int(offs[-1])
        targets = [(total * (i + 1)) // n_parts for i in range(n_parts)]
        cuts = np.searchsorted(offs, targets, side="left")
        cuts = np.clip(cuts, 1, self.n_vertices)
        bounds = [0] + sorted(set(int(c) for c in cuts))
        if bounds[-1] != self.n_vertices:
            bounds.append(self.n_vertices)
        return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]

    # -- stats / lifecycle -----------------------------------------------------
    @property
    def fs(self) -> Optional[pgfuse.PGFuseFS]:
        """The PG-Fuse mount (None without ``use_pgfuse``).  Auxiliary
        stores — e.g. a :class:`repro.core.featstore.FeatureStoreHandle` —
        mount here to share the graph's memory budget and readahead
        policy while keeping their own per-file block cache and stats."""
        return self._fs

    def pgfuse_stats(self) -> Optional[pgfuse.PGFuseStats]:
        """Aggregate stats of the whole mount (every file on it)."""
        return self._fs.stats() if self._fs is not None else None

    def pgfuse_file_stats(self) -> Optional[pgfuse.PGFuseStats]:
        """This graph FILE's cache stats only — unlike
        :meth:`pgfuse_stats` these stay attributable to topology traffic
        when auxiliary files (feature stores) share the mount."""
        if self._fs is None:
            return None
        return dataclasses.replace(self._fs.mount(self.path).stats)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._fs is not None:
            if self._owns_fs:
                self._fs.unmount()  # releases every cached block (§III)
            else:
                # shared mount: release only OUR file; other tenants'
                # caches stay warm
                self._fs.unmount(self.path)

    def __enter__(self) -> "GraphHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class AsyncRead:
    """In-flight asynchronous load (paper's non-blocking mode)."""

    def __init__(self, g: GraphHandle, partitions: list[tuple[int, int]],
                 callback: Callable[[PartitionBuffer], None], *,
                 n_buffers: int, n_workers: int, raw: bool = False):
        self._g = g
        self._callback = callback
        self._raw = raw
        self._work: "queue.Queue[Optional[tuple[int,int]]]" = queue.Queue()
        self._pool: "queue.Queue[PartitionBuffer]" = queue.Queue()
        for _ in range(max(1, n_buffers)):
            self._pool.put(PartitionBuffer())
        for p in partitions:
            self._work.put(p)
        self._n_left = len(partitions)
        self._done = threading.Event()
        if not partitions:
            self._done.set()
        self._cb_lock = threading.Lock()
        self._err_lock = threading.Lock()
        self._errors: list[BaseException] = []
        self._threads = [
            threading.Thread(target=self._producer, daemon=True,
                             name=f"paragrapher-producer-{i}")
            for i in range(max(1, n_workers))
        ]
        for t in self._threads:
            t.start()

    def _record_error(self, e: BaseException) -> None:
        with self._err_lock:  # producers race here; list.append alone is not
            self._errors.append(e)  # a guaranteed atomic publication point

    def _producer(self) -> None:
        while True:
            try:
                part = self._work.get_nowait()
            except queue.Empty:
                return
            buf = self._pool.get()  # backpressure: wait for a free buffer
            try:
                buf.v0, buf.v1 = part
                if self._raw:
                    offs, packed, b = self._g.read_partition_raw(*part)
                    buf.offsets, buf.packed, buf.b = offs, packed, b
                    buf.neighbors = None
                else:
                    offs, nbrs = self._g.read_partition(*part)
                    buf.offsets, buf.neighbors = offs, nbrs
                    buf.packed = None
                buf.error = None
            except BaseException as e:  # surfaced via wait()
                buf.error = e
                self._record_error(e)
            try:
                with self._cb_lock:
                    self._callback(buf)
            except BaseException as e:
                self._record_error(e)
            finally:
                buf.offsets = buf.neighbors = buf.packed = None  # -> pool
                self._pool.put(buf)
                if self._decr() == 0:
                    self._done.set()

    def _decr(self) -> int:
        with self._cb_lock:
            self._n_left -= 1
            return self._n_left

    def wait(self, timeout: Optional[float] = None) -> None:
        if not self._done.wait(timeout):
            raise TimeoutError("async read did not complete in time")
        with self._err_lock:
            if self._errors:
                raise self._errors[0]

    @property
    def done(self) -> bool:
        return self._done.is_set()


def open_graph(path: Union[str, os.PathLike], *, format: str = "auto",
               use_pgfuse: bool = False,
               pgfuse_block_size: int = pgfuse.DEFAULT_BLOCK_SIZE,
               pgfuse_max_resident_bytes: Optional[int] = None,
               pgfuse_readahead: Optional[int] = None,
               pgfuse_pread_fn=None,
               pgfuse_eviction: str = pgfuse.EVICT_LRU,
               pgfuse_retries: int = 0,
               pgfuse_retry_backoff_s: float = 0.005,
               pgfuse_fs: Optional[pgfuse.PGFuseFS] = None,
               pgfuse_engine=None) -> GraphHandle:
    """Open a graph for loading (the ParaGrapher entry point).

    ``use_pgfuse=True`` mounts the file in the PG-Fuse block cache
    (paper §III); ``format`` is auto-detected from the magic by default.
    ``pgfuse_readahead`` loads that many extra blocks per miss in one
    enlarged request (sequential-scan prefetch for the streaming loader);
    ``pgfuse_pread_fn`` injects a storage backend (benchmarks/tests).
    ``pgfuse_eviction`` picks the replacement policy ("lru" for
    sequential scans, "clock" for random adjacency queries — see
    :func:`repro.core.policy.choose_access_mode`) and ``pgfuse_retries``
    bounds transient-EIO retries per underlying read (deterministic
    ``pgfuse_retry_backoff_s * attempt`` backoff).

    Multi-tenant serving passes ``pgfuse_fs=`` (an existing
    :class:`repro.core.pgfuse.PGFuseFS` several models share — closing
    the handle then unmounts only this graph's file) and optionally
    ``pgfuse_engine=`` (an :class:`repro.core.pgfuse.EngineShare` or its
    name) to claim the file for that tenant's cache share.
    """
    return GraphHandle(
        path, format=format, use_pgfuse=use_pgfuse,
        pgfuse_block_size=pgfuse_block_size,
        pgfuse_max_resident_bytes=pgfuse_max_resident_bytes,
        pgfuse_readahead=pgfuse_readahead,
        pgfuse_pread_fn=pgfuse_pread_fn,
        pgfuse_eviction=pgfuse_eviction,
        pgfuse_retries=pgfuse_retries,
        pgfuse_retry_backoff_s=pgfuse_retry_backoff_s,
        pgfuse_fs=pgfuse_fs,
        pgfuse_engine=pgfuse_engine,
    )


def save_graph(path: Union[str, os.PathLike], csr: CSR, *,
               format: str = FORMAT_COMPBIN, k: int = webgraph.DEFAULT_K) -> int:
    if format == FORMAT_WEBGRAPH:  # k is a WebGraph-only knob
        return webgraph.write_webgraph(path, csr, k)
    return codec.get_codec(format).write(path, csr)
