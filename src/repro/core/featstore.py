"""FeatStore — binary node-feature store (the bulk byte stream of GNNs).

For GNN workloads the node-feature matrix, not the topology, is the
dominant byte stream (ogbn-papers100M: ~53 GiB of float16 features vs
~13 GiB of CompBin edges), yet the reproduction so far synthesized
features on the host — bypassing the very storage path the paper
accelerates.  FeatStore closes that gap: a fixed-stride binary row store
read through the SAME PG-Fuse :class:`~repro.core.pgfuse.CachedFile`
layer as CompBin, so enlarged block reads, in-memory caching, and
sequential readahead apply to feature traffic too.

Design mirrors CompBin (paper §IV): no per-row framing, no compression —
the byte address of row ``v`` is ``data_start + v * row_stride``, giving
O(1) random access for sampled minibatches and purely sequential reads
for full-graph streaming.  ``row_stride`` is stored explicitly so padded
strides (e.g. rows rounded up to a cache line) stay decodable, and
``data_start`` is stored explicitly so the writer can align the data
section to the deployment's PG-Fuse block size: with
``data_align == block_size`` and cut vertices that are multiples of
``block_size // row_stride`` (see ``graph.partition.split_plan(align=)``)
neighboring hosts' private caches never fetch the same feature block.

On-disk layout (little-endian):

    +---------------------+------------------------------------------+
    | magic      4 bytes  | b"FSTR"                                  |
    | version    u16      | 1                                        |
    | dtype      u8       | 0=float32, 1=float16, 2=bfloat16, 3=u8   |
    | flags      u8       | reserved (0)                             |
    | n_rows     u64      | number of feature rows (== |V|)          |
    | d          u32      | feature dimension                        |
    | row_stride u32      | bytes per row (>= d * itemsize)          |
    | data_start u64      | byte offset of row 0                     |
    +---------------------+------------------------------------------+
    | zero padding up to data_start                                  |
    +----------------------------------------------------------------+
    | rows: n_rows * row_stride bytes                                |
    +----------------------------------------------------------------+
"""

from __future__ import annotations

import dataclasses
import io
import os
import struct
from typing import BinaryIO, Optional, Union

import numpy as np

from repro.core import pgfuse

MAGIC = b"FSTR"
VERSION = 1
HEADER_SIZE = 32
#: default data-section alignment; deployments targeting a specific
#: PG-Fuse block size pass ``data_align=block_size`` at write time
DEFAULT_DATA_ALIGN = 64

_HEADER_STRUCT = struct.Struct("<4sHBBQIIQ")
assert _HEADER_STRUCT.size == HEADER_SIZE

#: dtype codes are part of the wire format — append only, never renumber
DTYPE_CODES = {0: np.dtype(np.float32), 1: np.dtype(np.float16),
               3: np.dtype(np.uint8)}
try:  # bfloat16 needs ml_dtypes; the format slot is reserved either way
    import ml_dtypes

    DTYPE_CODES[2] = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover - environment-dependent
    pass
_CODE_FOR_DTYPE = {v: k for k, v in DTYPE_CODES.items()}


def dtype_code(dtype) -> int:
    dt = np.dtype(dtype)
    if dt not in _CODE_FOR_DTYPE:
        raise ValueError(f"unsupported feature dtype {dt} "
                         f"(supported: {sorted(map(str, _CODE_FOR_DTYPE))})")
    return _CODE_FOR_DTYPE[dt]


@dataclasses.dataclass
class FeatStoreHeader:
    dtype: np.dtype
    flags: int
    n_rows: int
    d: int
    row_stride: int
    data_start: int

    @property
    def row_bytes(self) -> int:
        """Payload bytes per row (<= row_stride when rows are padded)."""
        return self.d * self.dtype.itemsize

    @property
    def total_size(self) -> int:
        return self.data_start + self.n_rows * self.row_stride


def featstore_nbytes(n_rows: int, d: int, dtype=np.float32, *,
                     data_align: int = DEFAULT_DATA_ALIGN) -> int:
    """Total on-disk size of a FeatStore file (header + padding + rows)."""
    stride = d * np.dtype(dtype).itemsize
    start = _aligned_data_start(data_align)
    return start + n_rows * stride


def _aligned_data_start(data_align: int) -> int:
    if data_align < 1:
        raise ValueError(f"data_align must be >= 1, got {data_align}")
    return -(-HEADER_SIZE // data_align) * data_align


def write_featstore(path_or_file: Union[str, os.PathLike, BinaryIO],
                    x: np.ndarray, *, dtype=None,
                    data_align: int = DEFAULT_DATA_ALIGN) -> int:
    """Serialize feature matrix ``x`` (n_rows, d). Returns bytes written.

    ``data_align`` pads the data section start to a multiple of the given
    byte count; pass the deployment's PG-Fuse block size so per-host row
    ranges can be made block-disjoint (see module docstring).
    """
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"features must be 2-D (n_rows, d), got {x.shape}")
    if dtype is not None:
        x = x.astype(dtype, copy=False)
    code = dtype_code(x.dtype)
    n_rows, d = x.shape
    stride = d * x.dtype.itemsize
    data_start = _aligned_data_start(data_align)
    header = _HEADER_STRUCT.pack(MAGIC, VERSION, code, 0, n_rows, d,
                                 stride, data_start)

    own = False
    if isinstance(path_or_file, (str, os.PathLike)):
        f: BinaryIO = open(path_or_file, "wb")
        own = True
    else:
        f = path_or_file
    try:
        n = f.write(header)
        n += f.write(b"\0" * (data_start - HEADER_SIZE))
        n += f.write(np.ascontiguousarray(x).tobytes())
    finally:
        if own:
            f.close()
    return n


def read_header(f) -> FeatStoreHeader:
    f.seek(0)
    raw = f.read(HEADER_SIZE)
    if len(raw) != HEADER_SIZE:
        raise ValueError("truncated FeatStore header")
    magic, version, code, flags, n_rows, d, stride, start = \
        _HEADER_STRUCT.unpack(raw)
    if magic != MAGIC:
        raise ValueError(f"bad magic {magic!r}; not a FeatStore file")
    if version != VERSION:
        raise ValueError(f"unsupported FeatStore version {version}")
    if code not in DTYPE_CODES:
        raise ValueError(f"unknown FeatStore dtype code {code}")
    dt = DTYPE_CODES[code]
    if stride < d * dt.itemsize:
        raise ValueError(f"row_stride {stride} < row payload {d * dt.itemsize}")
    if start < HEADER_SIZE:
        raise ValueError(f"data_start {start} overlaps the header")
    return FeatStoreHeader(dtype=dt, flags=flags, n_rows=n_rows, d=d,
                           row_stride=stride, data_start=start)


class FeatStoreFile:
    """Row reader over any ``seek``/``read`` file-like object.

    Like :class:`repro.core.compbin.CompBinFile`, the consumer is
    unmodified whether it reads the real filesystem or a PG-Fuse
    :class:`~repro.core.pgfuse.CachedFileHandle` — the paper's
    independence argument carries over to feature traffic.
    """

    def __init__(self, file: Union[str, os.PathLike, BinaryIO]):
        if isinstance(file, (str, os.PathLike)):
            self._f: BinaryIO = open(file, "rb")
            self._own = True
        else:
            self._f = file
            self._own = False
        self.header = read_header(self._f)

    @property
    def n_rows(self) -> int:
        return self.header.n_rows

    @property
    def d(self) -> int:
        return self.header.d

    @property
    def dtype(self) -> np.dtype:
        return self.header.dtype

    def read_rows(self, v0: int, v1: int) -> np.ndarray:
        """Feature rows [v0, v1) as an (v1-v0, d) array.

        A short read raises ``IOError`` — truncated feature rows must
        surface exactly like truncated CompBin blocks do (silent zero
        padding would train on corrupt features without a trace).
        """
        h = self.header
        if not 0 <= v0 <= v1 <= h.n_rows:
            raise ValueError(f"bad row range [{v0},{v1}) for {h.n_rows} rows")
        n = v1 - v0
        if n == 0:
            return np.zeros((0, h.d), dtype=h.dtype)
        self._f.seek(h.data_start + v0 * h.row_stride)
        want = n * h.row_stride
        raw = self._f.read(want)
        if len(raw) < want:
            raise IOError(f"short read of feature rows [{v0},{v1}): got "
                          f"{len(raw)} of {want} bytes")
        rows = np.frombuffer(raw, dtype=np.uint8).reshape(n, h.row_stride)
        return rows[:, :h.row_bytes].copy().view(h.dtype).reshape(n, h.d)

    def read_full(self) -> np.ndarray:
        return self.read_rows(0, self.n_rows)

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self) -> "FeatStoreFile":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class FeatureStoreHandle:
    """An open feature store; the feature-side sibling of ``GraphHandle``.

    Thread-safe the same way: every read opens its own file handle over
    the shared block cache.  Pass ``fs=graph.fs`` to mount the store into
    an already-open graph's PG-Fuse instance — one memory budget, one
    readahead policy, separate per-file block caches and stats (so
    feature and topology traffic stay individually attributable).
    """

    def __init__(self, path: Union[str, os.PathLike], *,
                 fs: Optional[pgfuse.PGFuseFS] = None,
                 use_pgfuse: bool = False,
                 pgfuse_block_size: int = pgfuse.DEFAULT_BLOCK_SIZE,
                 pgfuse_max_resident_bytes: Optional[int] = None,
                 pgfuse_readahead: int = 0,
                 pgfuse_pread_fn=None,
                 pgfuse_file_budget: Optional[int] = None,
                 pgfuse_file_readahead: Optional[int] = None,
                 pgfuse_engine=None):
        self.path = os.fspath(path)
        self._owns_fs = False
        self._fs = fs
        if fs is None and use_pgfuse:
            self._fs = pgfuse.PGFuseFS(
                block_size=pgfuse_block_size,
                max_resident_bytes=pgfuse_max_resident_bytes,
                readahead=pgfuse_readahead,
                pread_fn=pgfuse_pread_fn)
            self._owns_fs = True
        self._cf: Optional[pgfuse.CachedFile] = None
        if self._fs is not None:
            # ``pgfuse_file_budget`` caps THIS store's share of the shared
            # mount (so feature churn cannot evict the graph's hot offset
            # blocks), ``pgfuse_file_readahead`` overrides the mount's
            # readahead for this file only (0 for random row gathers),
            # and ``pgfuse_engine`` claims the store for one tenant's
            # EngineShare on a multi-model mount
            self._cf = self._fs.mount(
                self.path, max_resident_bytes=pgfuse_file_budget,
                readahead=pgfuse_file_readahead, engine=pgfuse_engine)
            if not self._owns_fs:
                # shared mount: refcounted like GraphHandle, so two
                # handles over the SAME store (model replicas) can close
                # independently without dropping each other's cache
                self._fs.retain(self.path)
        self._closed = False
        try:
            rdr = self._reader()  # validates the header eagerly
            self.header = rdr.header
            self.n_rows = rdr.n_rows
            self.d = rdr.d
            self.dtype = rdr.dtype
            rdr.close()
        except BaseException:
            # unwind the mount on a failed open (mirrors GraphHandle):
            # otherwise the retain/share membership leaks handle-less
            if self._fs is not None:
                if self._owns_fs:
                    self._fs.unmount()
                else:
                    self._fs.unmount(self.path)
            raise

    @property
    def cached_file(self) -> Optional[pgfuse.CachedFile]:
        """The store's own PG-Fuse block cache (None when unmounted)."""
        return self._cf

    def _reader(self) -> FeatStoreFile:
        if self._cf is not None:
            return FeatStoreFile(self._cf.open())
        return FeatStoreFile(open(self.path, "rb"))

    def read_rows(self, v0: int, v1: int) -> np.ndarray:
        if self._closed:
            raise ValueError("read on closed feature store")
        rdr = self._reader()
        try:
            return rdr.read_rows(v0, v1)
        finally:
            rdr.close()

    def pgfuse_stats(self) -> Optional[pgfuse.PGFuseStats]:
        """This FILE's cache stats (not the whole mount's aggregate)."""
        if self._cf is None:
            return None
        return dataclasses.replace(self._cf.stats)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._fs is not None:
            if self._owns_fs:
                self._fs.unmount()
            else:
                # release OUR retain of this store's file; the shared fs
                # itself is owned by whoever created it, and the file
                # truly unmounts only when its last retainer closes
                self._fs.unmount(self.path)

    def __enter__(self) -> "FeatureStoreHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def open_featstore(path: Union[str, os.PathLike], **kwargs
                   ) -> FeatureStoreHandle:
    """Open a feature store (see :class:`FeatureStoreHandle`)."""
    return FeatureStoreHandle(path, **kwargs)


def read_featstore(path: Union[str, os.PathLike, BinaryIO]) -> np.ndarray:
    """Convenience: load a whole store into one (n_rows, d) array."""
    with FeatStoreFile(path) as f:
        return f.read_full()


def roundtrip_bytes(x: np.ndarray, **kwargs) -> bytes:
    """Serialize to bytes in memory (tests/benchmarks)."""
    buf = io.BytesIO()
    write_featstore(buf, x, **kwargs)
    return buf.getvalue()
