"""PG-Fuse — large-block caching file layer (paper §III).

The paper observes that the Java WebGraph reader issues frequent small
(<=128 kB) reads, under-utilizing high-bandwidth storage (SSD pools, Lustre)
and defeating read-ahead prefetchers.  PG-Fuse interposes a *filesystem in
user space* that (i) enlarges requested blocks (default **32 MiB**),
(ii) reduces the number of calls into the underlying filesystem, and
(iii) caches received blocks in memory for future calls.

Hardware adaptation (DESIGN.md §2): inside a managed TPU pod we cannot (and
need not) mount a kernel VFS layer, so the interposition point moves from
FUSE/VFS to the loader's file abstraction: :class:`CachedFile` implements
the same ``pread``/file interface every consumer in this framework uses
(CompBin reader, WebGraph reader, token-shard reader), which preserves the
paper's independence argument — the consumer is unmodified.

Block state machine (paper Fig. 1), one integer status per block, all
transitions via compare-and-swap:

      0   loaded and accessible (idle)
      >0  number of concurrent reader threads (counter)
     -1   not loaded
     -2   a thread is loading the block; others must wait
     -3   the block is being revoked (eviction by last-access time)

Transitions::

     -1 --cas--> -2 --load--> 1 --release--> 0 --acquire--> 1,2,3,...
      0 --cas--> -3 --free--> -1
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import BinaryIO, Dict, Optional, Union

import numpy as np

# Block states (paper Fig. 1)
LOADED = 0        # >= 0: reader count
NOT_LOADED = -1
LOADING = -2
REVOKING = -3

DEFAULT_BLOCK_SIZE = 32 * 2**20  # 32 MiB (paper §III)


@dataclasses.dataclass
class PGFuseStats:
    underlying_reads: int = 0      # calls into the underlying filesystem
    underlying_bytes: int = 0      # bytes fetched from it
    cache_hits: int = 0            # block acquisitions served from memory
    cache_misses: int = 0          # block acquisitions that triggered a load
    waits: int = 0                 # acquisitions that had to wait (-2/-3)
    evictions: int = 0             # blocks revoked
    bytes_served: int = 0          # bytes returned to consumers

    def merge(self, other: "PGFuseStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


class _StatusArray:
    """CAS-protected per-block status words.

    The paper uses C atomics; under the GIL we realize the identical
    transition diagram with striped mutexes guarding a numpy int64 array —
    every state change goes through :meth:`cas`, so the diagram of Fig. 1 is
    enforced verbatim (stress-tested in tests/test_pgfuse.py).
    """

    N_STRIPES = 64

    def __init__(self, n_blocks: int):
        self._status = np.full(n_blocks, NOT_LOADED, dtype=np.int64)
        self._locks = [threading.Lock() for _ in range(self.N_STRIPES)]

    def load(self, i: int) -> int:
        return int(self._status[i])

    def cas(self, i: int, expected: int, new: int) -> bool:
        with self._locks[i % self.N_STRIPES]:
            if self._status[i] == expected:
                self._status[i] = new
                return True
            return False

    def add_reader(self, i: int) -> bool:
        """Atomically increment a non-negative status (0->1, n->n+1)."""
        with self._locks[i % self.N_STRIPES]:
            s = int(self._status[i])
            if s >= 0:
                self._status[i] = s + 1
                return True
            return False

    def release_reader(self, i: int) -> int:
        with self._locks[i % self.N_STRIPES]:
            s = int(self._status[i])
            assert s >= 1, f"release on block {i} in state {s}"
            self._status[i] = s - 1
            return s - 1

    def snapshot(self) -> np.ndarray:
        return self._status.copy()


class CachedFile:
    """One file's block cache; shared by any number of reader handles."""

    def __init__(self, path: Union[str, os.PathLike], *,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 fs: Optional["PGFuseFS"] = None,
                 pread_fn=None):
        self.path = os.fspath(path)
        self.block_size = int(block_size)
        if self.block_size <= 0:
            raise ValueError("block_size must be positive")
        self._fd = os.open(self.path, os.O_RDONLY)
        self.size = os.fstat(self._fd).st_size
        # injectable storage backend (benchmarks emulate Lustre/HDD
        # latency+bandwidth through here); default: the real filesystem
        self._pread_fn = pread_fn or (lambda fd, n, off: os.pread(fd, n, off))
        self.n_blocks = max(1, -(-self.size // self.block_size))
        self._statuses = _StatusArray(self.n_blocks)
        self._blocks: list[Optional[bytes]] = [None] * self.n_blocks
        self._last_access = np.zeros(self.n_blocks, dtype=np.float64)
        self._cond = threading.Condition()
        self.stats = PGFuseStats()
        self._stats_lock = threading.Lock()
        self._fs = fs
        self._closed = False

    # -- block acquisition (Fig. 1) ---------------------------------------
    def _read_underlying(self, b: int) -> bytes:
        off = b * self.block_size
        n = min(self.block_size, self.size - off)
        data = self._pread_fn(self._fd, n, off)  # ONE large-granularity request
        with self._stats_lock:
            self.stats.underlying_reads += 1
            self.stats.underlying_bytes += len(data)
        return data

    def acquire_block(self, b: int) -> bytes:
        """Pin block ``b`` for reading, loading it if necessary."""
        waited = False
        while True:
            if self._statuses.add_reader(b):          # s >= 0 -> s+1
                data = self._blocks[b]
                assert data is not None
                with self._stats_lock:
                    self.stats.cache_hits += 1
                    if waited:
                        self.stats.waits += 1
                return data
            if self._statuses.cas(b, NOT_LOADED, LOADING):  # -1 -> -2
                try:
                    data = self._read_underlying(b)
                except BaseException:
                    ok = self._statuses.cas(b, LOADING, NOT_LOADED)
                    assert ok
                    with self._cond:
                        self._cond.notify_all()
                    raise
                self._blocks[b] = data
                self._last_access[b] = time.monotonic()
                if self._fs is not None:
                    self._fs._resident_delta(len(data))
                ok = self._statuses.cas(b, LOADING, 1)  # loader is reader #1
                assert ok, "nobody else may touch a LOADING block"
                with self._stats_lock:
                    self.stats.cache_misses += 1
                    if waited:
                        self.stats.waits += 1
                with self._cond:
                    self._cond.notify_all()
                return data
            # s is LOADING or REVOKING: wait for the owning thread
            waited = True
            with self._cond:
                s = self._statuses.load(b)
                if s in (LOADING, REVOKING):
                    self._cond.wait(timeout=0.05)

    def release_block(self, b: int) -> None:
        self._last_access[b] = time.monotonic()
        self._statuses.release_reader(b)
        if self._fs is not None:
            self._fs._maybe_evict()

    # -- eviction (revocation by last-access time) -------------------------
    def try_revoke(self, b: int) -> int:
        """Attempt 0 -> -3 -> free -> -1.  Returns bytes freed (0 if busy)."""
        if not self._statuses.cas(b, LOADED, REVOKING):
            return 0
        data = self._blocks[b]
        self._blocks[b] = None
        freed = len(data) if data is not None else 0
        ok = self._statuses.cas(b, REVOKING, NOT_LOADED)
        assert ok
        with self._stats_lock:
            self.stats.evictions += 1
        with self._cond:
            self._cond.notify_all()
        return freed

    def resident_blocks(self) -> np.ndarray:
        return np.flatnonzero([blk is not None for blk in self._blocks])

    # -- the consumer-facing read interface --------------------------------
    def pread(self, offset: int, size: int) -> bytes:
        """Positional read assembled from cached blocks."""
        if self._closed:
            raise ValueError("read on closed CachedFile")
        offset = max(0, offset)
        size = max(0, min(size, self.size - offset))
        if size == 0:
            return b""
        out = bytearray(size)
        pos = 0
        off = offset
        end = offset + size
        while off < end:
            b = off // self.block_size
            data = self.acquire_block(b)
            try:
                lo = off - b * self.block_size
                take = min(end - off, len(data) - lo)
                out[pos : pos + take] = data[lo : lo + take]
            finally:
                self.release_block(b)
            pos += take
            off += take
        with self._stats_lock:
            self.stats.bytes_served += size
        return bytes(out)

    def open(self) -> "CachedFileHandle":
        """A seekable file-like handle (one per consumer thread)."""
        return CachedFileHandle(self)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        freed = 0
        for b in range(self.n_blocks):
            # drain: blocks pinned by leaked readers are freed unconditionally
            data = self._blocks[b]
            if data is not None:
                freed += len(data)
                self._blocks[b] = None
        if self._fs is not None and freed:
            self._fs._resident_delta(-freed)
        os.close(self._fd)


class CachedFileHandle:
    """Seek/read file-object adapter over a shared :class:`CachedFile`."""

    def __init__(self, cf: CachedFile):
        self._cf = cf
        self._pos = 0

    def seek(self, offset: int, whence: int = os.SEEK_SET) -> int:
        if whence == os.SEEK_SET:
            self._pos = offset
        elif whence == os.SEEK_CUR:
            self._pos += offset
        elif whence == os.SEEK_END:
            self._pos = self._cf.size + offset
        else:
            raise ValueError(f"bad whence {whence}")
        return self._pos

    def tell(self) -> int:
        return self._pos

    def read(self, size: int = -1) -> bytes:
        if size < 0:
            size = self._cf.size - self._pos
        data = self._cf.pread(self._pos, size)
        self._pos += len(data)
        return data

    def close(self) -> None:  # the underlying cache outlives handles
        pass

    def __enter__(self) -> "CachedFileHandle":
        return self

    def __exit__(self, *exc) -> None:
        pass


class PGFuseFS:
    """The "mount": a set of cached files under one shared memory budget.

    ``ParaGrapher`` mounts graph files here when the user passes
    ``use_pgfuse=True`` to :func:`repro.core.paragrapher.open_graph`, and
    unmounts (releasing all blocks) when the graph is closed — mirroring the
    paper's mount/unmount lifecycle.
    """

    def __init__(self, *, block_size: int = DEFAULT_BLOCK_SIZE,
                 max_resident_bytes: Optional[int] = None,
                 pread_fn=None):
        self.block_size = block_size
        self.max_resident_bytes = max_resident_bytes
        self.pread_fn = pread_fn
        self._files: Dict[str, CachedFile] = {}
        self._lock = threading.Lock()
        self._resident = 0

    def _resident_delta(self, d: int) -> None:
        with self._lock:
            self._resident += d

    @property
    def resident_bytes(self) -> int:
        return self._resident

    def _maybe_evict(self) -> None:
        """Revoke least-recently-used idle blocks while over budget."""
        if self.max_resident_bytes is None or self._resident <= self.max_resident_bytes:
            return
        # Gather (last_access, file, block) for all resident idle candidates.
        candidates = []
        with self._lock:
            files = list(self._files.values())
        for cf in files:
            for b in cf.resident_blocks():
                candidates.append((cf._last_access[b], cf, int(b)))
        candidates.sort(key=lambda t: t[0])
        for _, cf, b in candidates:
            if self._resident <= self.max_resident_bytes:
                break
            freed = cf.try_revoke(b)
            if freed:
                self._resident_delta(-freed)

    def mount(self, path: Union[str, os.PathLike]) -> CachedFile:
        key = os.fspath(path)
        with self._lock:
            cf = self._files.get(key)
            if cf is None:
                cf = CachedFile(key, block_size=self.block_size, fs=self,
                                pread_fn=self.pread_fn)
                self._files[key] = cf
            return cf

    def open(self, path: Union[str, os.PathLike]) -> CachedFileHandle:
        return self.mount(path).open()

    def stats(self) -> PGFuseStats:
        agg = PGFuseStats()
        with self._lock:
            for cf in self._files.values():
                agg.merge(cf.stats)
        return agg

    def unmount(self, path: Optional[Union[str, os.PathLike]] = None) -> None:
        with self._lock:
            if path is None:
                files, self._files = list(self._files.values()), {}
            else:
                cf = self._files.pop(os.fspath(path), None)
                files = [cf] if cf else []
        for cf in files:
            cf.close()

    def __enter__(self) -> "PGFuseFS":
        return self

    def __exit__(self, *exc) -> None:
        self.unmount()
